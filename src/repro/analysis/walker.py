"""File walking, suppression parsing, and the per-module analysis driver.

Suppression grammar (tokenizer-based, so trailing comments on any code
line work):

    x = float(loss)  # repro-lint: disable=RL001     <- this line only
    # repro-lint: disable=RL001,RL003               <- next line
    # repro-lint: skip-file                          <- whole file
                                                        (fixture corpora)

A standalone directive comment applies to the next CODE line (blank and
comment-only lines between are skipped, so a reason may continue over
several comment lines); a trailing directive applies to its own line.
``skip-file`` (anywhere in
the first 20 lines) removes the file from directory walks — it marks
fixture corpora and generated code as *input data*, not code under the
invariants. Explicit analysis of such files (the fixture tests) passes
``honor_markers=False``.
"""
from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

from repro.analysis import scopes
from repro.analysis.registry import Finding, RuleInfo

DIRECTIVE = "repro-lint:"
SKIP_FILE = "skip-file"
SKIP_SCAN_LINES = 20


class ModuleContext:
    """Parsed view of one source file handed to every rule."""

    def __init__(self, path: str, relpath: str, text: str):
        self.path = path
        self.relpath = relpath.replace(os.sep, "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree = scopes.add_parents(ast.parse(text))
        self.imports = scopes.Imports(self.tree)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0) + 1
        return Finding(rule, self.relpath, line, col, message,
                       self.line_text(line))


def parse_directives(text: str):
    """-> (suppressions: {line: set(rule_ids)}, skip_file: bool).

    Malformed directives (no ``disable=``, unknown verb) are reported by
    the CLI via :func:`directive_problems`, not silently ignored here —
    a typo'd suppression that silently suppresses nothing is exactly the
    kind of defect this linter exists to prevent.
    """
    suppressions: Dict[int, Set[str]] = {}
    skip_file = False
    lines = text.splitlines()
    for line_no, is_standalone, body in _directive_comments(text):
        if body.startswith(SKIP_FILE):
            if line_no <= SKIP_SCAN_LINES:
                skip_file = True
            continue
        if body.startswith("disable="):
            ids = {r for r in _disable_ids(body) if _RULE_ID_RE.match(r)}
            target = line_no
            if is_standalone:
                target += 1
                while target <= len(lines) and (
                        not lines[target - 1].strip()
                        or lines[target - 1].lstrip().startswith("#")):
                    target += 1
            suppressions.setdefault(target, set()).update(ids)
    return suppressions, skip_file


_RULE_ID_RE = re.compile(r"^(RL\d{3}|\*)$")


def _disable_ids(body: str) -> List[str]:
    """Rule ids of a ``disable=...`` body: the first whitespace token
    holds the comma list, anything after it is the human reason."""
    rest = body[len("disable="):].split()
    return [t.strip() for t in (rest[0] if rest else "").split(",")]


def directive_problems(text: str) -> List[tuple]:
    """(line, message) for malformed ``repro-lint:`` directives."""
    problems = []
    for line_no, _, body in _directive_comments(text):
        if body.startswith(SKIP_FILE):
            continue
        if body.startswith("disable="):
            from repro.analysis.registry import all_rules

            known = {r.id for r in all_rules()} | {"*"}
            ids = _disable_ids(body)
            bad = [t for t in ids if t not in known]
            if bad or not any(ids):
                problems.append(
                    (line_no,
                     f"malformed repro-lint disable list {','.join(ids)!r}"
                     " (expected comma-joined registered RL00x ids)"))
            continue
        problems.append(
            (line_no,
             f"malformed repro-lint directive {body.split()[0] if body else ''!r}"
             " (expected 'disable=RL00x[,...]' or 'skip-file')")
        )
    return problems


def _directive_comments(text: str) -> Iterator[tuple]:
    """Yield (line, is_standalone, directive_body) for each
    ``# repro-lint:`` comment, via the tokenizer (string literals that
    merely contain the marker are not comments)."""
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return
    lines = text.splitlines()
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        comment = tok.string.lstrip("#").strip()
        if not comment.startswith(DIRECTIVE):
            continue
        body = comment[len(DIRECTIVE):].strip()
        line = tok.start[0]
        prefix = lines[line - 1][: tok.start[1]] if line <= len(lines) else ""
        yield line, not prefix.strip(), body


def is_suppressed(finding: Finding, suppressions: Dict[int, Set[str]],
                  end_line: Optional[int] = None) -> bool:
    span = range(finding.line, (end_line or finding.line) + 1)
    for line in span:
        ids = suppressions.get(line)
        if ids and (finding.rule in ids or "*" in ids):
            return True
    return False


def analyze_source(path: str, relpath: str, text: str,
                   rules: Sequence[RuleInfo]) -> List[Finding]:
    """Run ``rules`` over one file's text; suppressions applied."""
    suppressions, _ = parse_directives(text)
    try:
        ctx = ModuleContext(path, relpath, text)
    except SyntaxError as e:
        return [Finding("RL000", relpath.replace(os.sep, "/"),
                        e.lineno or 1, (e.offset or 0) or 1,
                        f"syntax error: {e.msg}", "")]
    findings: List[Finding] = []
    for rule in rules:
        for f in rule.check(ctx):
            node_end = None
            # a multi-line statement may carry its trailing suppression
            # on any physical line of the finding's anchor statement
            if f.line <= len(ctx.lines):
                node_end = _statement_end_line(ctx, f.line)
            if not is_suppressed(f, suppressions, node_end):
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def _statement_end_line(ctx: ModuleContext, line: int) -> int:
    """End line of the smallest statement starting at ``line`` (so a
    suppression trailing a wrapped call still lands)."""
    best = line
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.stmt) and node.lineno == line:
            end = getattr(node, "end_lineno", line) or line
            best = max(best, end)
    return best


# ---------------------------------------------------------------------------
# file discovery

DEFAULT_ROOTS = ("src", "benchmarks", "tests")
EXCLUDED_DIRS = {"__pycache__", ".git", ".github", "node_modules"}


def iter_py_files(paths: Iterable[str], honor_markers: bool = True
                  ) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py") and not (
                    honor_markers and _has_skip_marker(p)):
                yield p
        elif os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(
                    d for d in dirnames if d not in EXCLUDED_DIRS)
                for fn in sorted(filenames):
                    if not fn.endswith(".py"):
                        continue
                    full = os.path.join(dirpath, fn)
                    if honor_markers and _has_skip_marker(full):
                        continue
                    yield full


def _has_skip_marker(path: str) -> bool:
    try:
        with open(path, encoding="utf-8", errors="replace") as f:
            for _ in range(SKIP_SCAN_LINES):
                line = f.readline()
                if not line:
                    break
                if DIRECTIVE in line and SKIP_FILE in line and \
                        line.lstrip().startswith("#"):
                    return True
    except OSError:
        pass
    return False


def analyze_paths(paths: Sequence[str], rules: Optional[Sequence[RuleInfo]]
                  = None, root: Optional[str] = None,
                  honor_markers: bool = True) -> List[Finding]:
    """Analyze files/directories; paths in findings are relative to
    ``root`` (default: the current working directory)."""
    from repro.analysis.registry import all_rules

    rules = list(rules) if rules is not None else all_rules()
    root = root or os.getcwd()
    findings: List[Finding] = []
    for path in iter_py_files(paths, honor_markers=honor_markers):
        try:
            with open(path, encoding="utf-8", errors="replace") as f:
                text = f.read()
        except OSError as e:
            findings.append(Finding("RL000", os.path.relpath(path, root),
                                    1, 1, f"unreadable file: {e}", ""))
            continue
        rel = os.path.relpath(os.path.abspath(path), root)
        findings.extend(analyze_source(path, rel, text, rules))
    return findings
