"""CLI for the repro invariant linter.

    python -m repro.analysis [paths...] [--format=text|json|github]
                             [--baseline PATH | --no-baseline]
                             [--write-baseline] [--list-rules]

Default paths are ``src benchmarks tests`` (those that exist under the
current directory). Exit status: 0 when no non-baselined findings, 1
when new findings (or malformed suppression directives) exist, 2 on
usage errors. Stdlib-only — the CI lint job runs this without jax.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from repro.analysis import baseline as bl
from repro.analysis import walker
from repro.analysis.registry import Finding, all_rules


def _default_paths(root: str) -> List[str]:
    found = [p for p in walker.DEFAULT_ROOTS
             if os.path.isdir(os.path.join(root, p))]
    return found or ["."]


def _format_text(findings: List[Finding]) -> str:
    return "\n".join(
        f"{f.location()}: {f.rule} {f.message}" for f in findings)


def _format_json(findings: List[Finding], grandfathered: List[Finding],
                 stale: List[str]) -> str:
    return json.dumps(
        {
            "findings": [
                {"rule": f.rule, "path": f.path, "line": f.line,
                 "col": f.col, "message": f.message, "text": f.text}
                for f in findings
            ],
            "grandfathered": len(grandfathered),
            "stale_baseline_entries": stale,
        },
        indent=2,
    )


def _format_github(findings: List[Finding]) -> str:
    # workflow-command annotations render inline on the PR diff; the
    # message field must not contain raw newlines or '::'
    out = []
    for f in findings:
        msg = f.message.replace("%", "%25").replace("\n", "%0A")
        out.append(
            f"::error file={f.path},line={f.line},col={f.col},"
            f"title={f.rule} {_rule_name(f.rule)}::{msg}"
        )
    return "\n".join(out)


def _rule_name(rule_id: str) -> str:
    from repro.analysis.registry import get_rule

    info = get_rule(rule_id)
    return info.name if info else ""


def _list_rules() -> str:
    lines = []
    for r in all_rules():
        lines.append(f"{r.id}  {r.name}")
        lines.append(f"    guards: {r.invariant}")
        doc = " ".join(r.doc.split())
        lines.append(f"    {doc}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant linter for the repro codebase "
                    "(see DESIGN.md 'Invariant registry').",
    )
    ap.add_argument("paths", nargs="*",
                    help="files/directories to analyze (default: "
                         "src benchmarks tests)")
    ap.add_argument("--format", choices=("text", "json", "github"),
                    default="text")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="baseline file of grandfathered findings "
                         f"(default: {bl.DEFAULT_BASELINE} at the repo "
                         "root when present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, baselined or not")
    ap.add_argument("--write-baseline", action="store_true",
                    help="grandfather the current findings into the "
                         "baseline file and exit 0")
    ap.add_argument("--rules", default=None, metavar="RL001,RL002",
                    help="run only these rule ids")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--include-skipped", action="store_true",
                    help="analyze files carrying a 'repro-lint: "
                         "skip-file' marker too (fixture corpora)")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0

    rules = all_rules()
    if args.rules:
        wanted = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = wanted - {r.id for r in rules}
        if unknown:
            print(f"unknown rule ids: {sorted(unknown)}", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.id in wanted]

    root = os.getcwd()
    paths = args.paths or _default_paths(root)
    findings = walker.analyze_paths(
        paths, rules=rules, root=root,
        honor_markers=not args.include_skipped)

    # malformed suppression directives are findings too: a typo'd
    # disable= suppresses nothing, silently
    for path in walker.iter_py_files(
            paths, honor_markers=not args.include_skipped):
        try:
            with open(path, encoding="utf-8", errors="replace") as fh:
                text = fh.read()
        except OSError:
            continue
        rel = os.path.relpath(os.path.abspath(path), root).replace(
            os.sep, "/")
        for line, msg in walker.directive_problems(text):
            findings.append(Finding("RL000", rel, line, 1, msg, ""))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))

    baseline_path = args.baseline or bl.default_baseline_path(root)
    if args.write_baseline:
        n = bl.write_baseline(findings, baseline_path)
        print(f"baseline written: {n} finding(s) grandfathered in "
              f"{baseline_path}")
        return 0

    grandfathered: List[Finding] = []
    stale: List[str] = []
    if not args.no_baseline and os.path.exists(baseline_path):
        try:
            entries = bl.load_baseline(baseline_path)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        findings, grandfathered, stale = bl.split_by_baseline(
            findings, entries)

    if args.format == "json":
        print(_format_json(findings, grandfathered, stale))
    elif args.format == "github":
        out = _format_github(findings)
        if out:
            print(out)
    else:
        out = _format_text(findings)
        if out:
            print(out)

    summary = (f"{len(findings)} finding(s)"
               + (f", {len(grandfathered)} baselined" if grandfathered else "")
               + (f", {len(stale)} stale baseline entrie(s) — "
                  "rerun --write-baseline to shrink the file"
                  if stale else ""))
    print(summary, file=sys.stderr)
    return 1 if findings else 0
