"""``python -m repro.analysis`` — the CI-invoked linter entry point."""
import sys

from repro.analysis.cli import main

if __name__ == "__main__":
    sys.exit(main())
