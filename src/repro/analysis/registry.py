"""Checker registry for the repro invariant linter.

Every rule is a plain function ``check(ctx) -> list[Finding]`` registered
under a stable ``RL00x`` id via the :func:`register` decorator. The ids
are part of the repo's public surface: suppression comments
(``# repro-lint: disable=RL001``), the committed baseline file, and the
DESIGN.md invariant registry all key on them, so an id is never reused
for a different class of defect.

The registry is intentionally stdlib-only (``ast`` + friends): the CI
``lint`` job runs the analyzer on a bare runner with no jax installed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    """One reported defect, anchored to a source location.

    ``text`` carries the stripped source line so the baseline can match
    grandfathered findings across line-number drift (see
    ``repro.analysis.baseline.fingerprint``).
    """

    rule: str
    path: str  # repo-relative posix path
    line: int
    col: int
    message: str
    text: str = ""

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"


@dataclasses.dataclass(frozen=True)
class RuleInfo:
    """A registered checker: id, human name, the DESIGN.md invariant it
    guards, a one-paragraph doc, and the check callable."""

    id: str
    name: str
    invariant: str
    doc: str
    check: Callable  # (walker.ModuleContext) -> List[Finding]


REGISTRY: Dict[str, RuleInfo] = {}


def register(rule_id: str, name: str, invariant: str, doc: str):
    """Class decorator-free registration: ``@register("RL001", ...)`` on
    a ``check(ctx)`` function."""

    def deco(fn: Callable) -> Callable:
        if rule_id in REGISTRY:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        REGISTRY[rule_id] = RuleInfo(rule_id, name, invariant, doc, fn)
        return fn

    return deco


def all_rules() -> List[RuleInfo]:
    """Registered rules in id order (import ``rules`` first)."""
    # the import is deferred so `registry` has no import-time dependency
    # on the rule implementations (tests register throwaway rules too)
    from repro.analysis import rules  # noqa: F401

    return [REGISTRY[k] for k in sorted(REGISTRY)]


def get_rule(rule_id: str) -> Optional[RuleInfo]:
    from repro.analysis import rules  # noqa: F401

    return REGISTRY.get(rule_id)
