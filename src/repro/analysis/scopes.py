"""Scope and dataflow helpers shared by the RL00x rules.

Everything here is deliberately *approximate*: the rules trade soundness
for a near-zero false-positive rate on this repo's idioms, because a
linter that cries wolf gets suppressed wholesale. The helpers provide:

* parent links + ancestor iteration over an ``ast`` tree,
* import-alias resolution (``import jax.random as jr`` makes
  ``jr.split`` resolve to the canonical ``jax.random.split``),
* name extraction for assignment targets,
* a linear, execution-ordered statement walk that visits loop bodies
  twice (the cheap abstract unrolling that catches loop-carried
  use-after-donate and PRNG reuse), and
* detection of "traced" functions — defs that are jit-compiled or used
  as ``shard_map`` bodies, where a host sync is always a defect.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set

FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)

# ---------------------------------------------------------------------------
# parent links


def add_parents(tree: ast.AST) -> ast.AST:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._rl_parent = node  # type: ignore[attr-defined]
    return tree


def parent(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "_rl_parent", None)


def ancestors(node: ast.AST) -> Iterator[ast.AST]:
    cur = parent(node)
    while cur is not None:
        yield cur
        cur = parent(cur)


def enclosing_function(node: ast.AST) -> Optional[ast.AST]:
    for anc in ancestors(node):
        if isinstance(anc, FUNC_NODES):
            return anc
    return None


def is_inside(node: ast.AST, container: ast.AST) -> bool:
    return any(anc is container for anc in ancestors(node))


# ---------------------------------------------------------------------------
# names


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted_name(call.func)


def assigned_names(target: ast.AST) -> List[str]:
    """Plain names bound by an assignment target (tuples flattened;
    subscript/attribute targets are ignored — they mutate, not bind)."""
    out: List[str] = []

    def rec(t: ast.AST) -> None:
        if isinstance(t, ast.Name):
            out.append(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                rec(e)
        elif isinstance(t, ast.Starred):
            rec(t.value)

    rec(target)
    return out


def statement_bound_names(stmt: ast.stmt) -> List[str]:
    """Names (re)bound by a single statement, for taint clearing."""
    if isinstance(stmt, ast.Assign):
        return [n for t in stmt.targets for n in assigned_names(t)]
    if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        return assigned_names(stmt.target)
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return assigned_names(stmt.target)
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [
            n
            for item in stmt.items
            if item.optional_vars is not None
            for n in assigned_names(item.optional_vars)
        ]
    if isinstance(stmt, FUNC_NODES + (ast.ClassDef,)):
        return [stmt.name]
    return []


# ---------------------------------------------------------------------------
# imports


class Imports:
    """Resolve local aliases to canonical dotted names.

    ``import jax.random as jr``          -> jr        => jax.random
    ``from jax import random``           -> random    => jax.random
    ``from jax.random import fold_in``   -> fold_in   => jax.random.fold_in
    ``from repro.utils import compat``   -> compat    => repro.utils.compat

    ``resolve("jr.split")`` => ``"jax.random.split"``. Unknown roots
    resolve to themselves, so builtins pass through unchanged.
    """

    def __init__(self, tree: ast.AST):
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        self.aliases[a.asname] = a.name
                    else:
                        root = a.name.split(".")[0]
                        self.aliases[root] = root
            elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.aliases[a.asname or a.name] = f"{node.module}.{a.name}"

    def resolve(self, dotted: Optional[str]) -> Optional[str]:
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        base = self.aliases.get(head, head)
        return f"{base}.{rest}" if rest else base

    def resolve_call(self, call: ast.Call) -> Optional[str]:
        return self.resolve(call_name(call))


# ---------------------------------------------------------------------------
# linear statement walks


def child_blocks(stmt: ast.stmt) -> List[Sequence[ast.stmt]]:
    """Nested statement blocks of a compound statement, in source order.
    Function/class bodies are NOT descended into — they run later."""
    if isinstance(stmt, FUNC_NODES + (ast.ClassDef,)):
        return []
    blocks: List[Sequence[ast.stmt]] = []
    for field in ("body", "orelse", "finalbody"):
        b = getattr(stmt, field, None)
        if b:
            blocks.append(b)
    for h in getattr(stmt, "handlers", None) or []:
        blocks.append(h.body)
    return blocks


def stmt_header_nodes(stmt: ast.stmt) -> Iterator[ast.AST]:
    """Nodes evaluated by the statement ITSELF.

    For compound statements that is only the header expression (the
    ``if``/``while`` test, the ``for`` iterable and target, the ``with``
    items) — their nested blocks are visited as statements of their own
    by :class:`LinearWalker`, and pre-scanning them here would break
    execution order (a donation deep in a loop body must not be
    processed before the statements above it have run)."""
    if isinstance(stmt, (ast.If, ast.While)):
        yield from ast.walk(stmt.test)
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        yield from ast.walk(stmt.iter)
        yield from ast.walk(stmt.target)
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        for item in stmt.items:
            yield from ast.walk(item.context_expr)
            if item.optional_vars is not None:
                yield from ast.walk(item.optional_vars)
    elif isinstance(stmt, ast.Try):
        return
    elif isinstance(stmt, FUNC_NODES + (ast.ClassDef,)):
        for dec in stmt.decorator_list:
            yield from ast.walk(dec)
    else:
        yield from ast.walk(stmt)


def linear_statements(body: Sequence[ast.stmt]) -> Iterator[ast.stmt]:
    """All statements in execution order, recursing into compound
    bodies but not into nested function/class definitions."""
    for stmt in body:
        yield stmt
        for block in child_blocks(stmt):
            yield from linear_statements(block)


class LinearWalker:
    """Execution-ordered walk with loop bodies visited twice.

    Subclasses override :meth:`visit_statement`; the double pass over
    ``for``/``while`` bodies is the one-line abstract interpretation
    that surfaces loop-carried defects (a buffer donated at the bottom
    of the body and read at the top of the next iteration, a PRNG key
    consumed once per iteration). Findings must therefore be deduped by
    location — use :meth:`report`.

    ``if``/``else`` blocks are mutually exclusive at runtime; stateful
    subclasses override :meth:`snapshot` / :meth:`restore` /
    :meth:`merge` so state from the taken branch does not leak into the
    analysis of the other (a key consumed once in each arm is consumed
    once, not twice). The default hooks are no-ops, giving the plain
    sequential walk.
    """

    def __init__(self) -> None:
        self._seen: Set[tuple] = set()
        self.findings: List = []

    def report(self, finding) -> None:
        key = (finding.rule, finding.line, finding.col)
        if key not in self._seen:
            self._seen.add(key)
            self.findings.append(finding)

    def visit_statement(self, stmt: ast.stmt) -> None:  # pragma: no cover
        raise NotImplementedError

    def snapshot(self):
        """Capture mutable analysis state before a branch (override)."""
        return None

    def restore(self, snap) -> None:
        """Reset analysis state to a :meth:`snapshot` (override)."""

    def merge(self, branch_snaps) -> None:
        """Join the post-states of mutually exclusive branches
        (override; must-semantics — intersection — is the usual choice
        here, since repeated branch conditions correlate)."""

    def walk(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            self.visit_statement(stmt)
            if isinstance(stmt, ast.If):
                before = self.snapshot()
                self.walk(stmt.body)
                taken = self.snapshot()
                self.restore(before)
                if stmt.orelse:
                    self.walk(stmt.orelse)
                self.merge([taken, self.snapshot()])
            elif isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
                for _ in range(2):  # unroll twice: loop-carried state
                    for block in child_blocks(stmt):
                        self.walk(block)
            else:
                for block in child_blocks(stmt):
                    self.walk(block)


# ---------------------------------------------------------------------------
# traced (jit / shard_map) functions

JIT_NAMES = {"jax.jit", "jax.pjit", "jit", "pjit"}
SHARD_MAP_SUFFIX = ".shard_map"


def _is_jit_callee(canon: Optional[str]) -> bool:
    return canon in JIT_NAMES


def _is_shard_map_callee(canon: Optional[str]) -> bool:
    return canon is not None and (
        canon == "shard_map" or canon.endswith(SHARD_MAP_SUFFIX)
    )


def jit_decorated(func: ast.AST, imports: Imports) -> bool:
    """True for ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorations."""
    for dec in getattr(func, "decorator_list", []):
        canon = imports.resolve(dotted_name(dec))
        if _is_jit_callee(canon):
            return True
        if isinstance(dec, ast.Call):
            canon = imports.resolve_call(dec)
            if _is_jit_callee(canon):
                return True
            if canon in ("functools.partial", "partial") and dec.args:
                inner = imports.resolve(dotted_name(dec.args[0]))
                if _is_jit_callee(inner):
                    return True
    return False


def traced_function_defs(tree: ast.AST, imports: Imports) -> List[ast.AST]:
    """Defs whose bodies run under a trace: jit-decorated, or passed by
    name to ``jax.jit(...)`` / ``*.shard_map(...)`` anywhere in the
    module (names are matched textually — good enough at module scale,
    where a def and its wrapping share a function or module scope)."""
    defs_by_name: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, FUNC_NODES):
            defs_by_name.setdefault(node.name, []).append(node)

    traced: List[ast.AST] = []
    traced_ids: Set[int] = set()

    def mark(name: str) -> None:
        for d in defs_by_name.get(name, []):
            if id(d) not in traced_ids:
                traced_ids.add(id(d))
                traced.append(d)

    for node in ast.walk(tree):
        if isinstance(node, FUNC_NODES) and jit_decorated(node, imports):
            if id(node) not in traced_ids:
                traced_ids.add(id(node))
                traced.append(node)
        elif isinstance(node, ast.Call):
            canon = imports.resolve_call(node)
            if _is_jit_callee(canon) or _is_shard_map_callee(canon):
                if node.args and isinstance(node.args[0], ast.Name):
                    mark(node.args[0].id)
    return traced


def donate_argnums_of(call: ast.Call) -> Optional[tuple]:
    """Literal ``donate_argnums`` of a jit call, else None."""
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = []
                for e in v.elts:
                    if isinstance(e, ast.Constant) and isinstance(e.value, int):
                        out.append(e.value)
                return tuple(out) if out else None
    return None
