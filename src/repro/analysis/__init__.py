"""``repro.analysis`` — AST-based invariant linter for this repo.

Stdlib-only (the CI lint job runs it without jax). Public surface:

* :func:`analyze_paths` / :func:`analyze_source` — run the rules.
* :class:`Finding`, :func:`all_rules`, :func:`register` — the registry.
* ``baseline`` — grandfathered-finding bookkeeping.
* CLI: ``python -m repro.analysis`` (see ``repro.analysis.cli``).

Rule ids (each guards a DESIGN.md invariant — see the "Invariant
registry" table there):

* RL001 host-sync-in-hot-path
* RL002 use-after-donate
* RL003 prng-key-reuse
* RL004 recompile-hazard
* RL005 wire-header-literal
* RL006 silent-fallback
"""
from repro.analysis.registry import Finding, RuleInfo, all_rules, register
from repro.analysis.walker import (
    ModuleContext,
    analyze_paths,
    analyze_source,
    iter_py_files,
)

__all__ = [
    "Finding",
    "RuleInfo",
    "all_rules",
    "register",
    "ModuleContext",
    "analyze_paths",
    "analyze_source",
    "iter_py_files",
]
