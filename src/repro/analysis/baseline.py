"""Committed baseline of grandfathered findings.

The baseline lets the linter land with a hard ``exit 1`` on NEW findings
while legacy ones are tracked (not blessed) in a committed JSON file.
Entries are keyed by a content fingerprint — rule id + repo-relative
path + the stripped source line text + an occurrence counter — so line
drift from unrelated edits does not invalidate the baseline, while any
edit to the offending line itself surfaces the finding again (the edit
is the natural moment to fix it).

Workflow:
    python -m repro.analysis --write-baseline   # grandfather current
    python -m repro.analysis                    # fails only on NEW
Fixing a baselined finding leaves a stale entry behind; the CLI reports
stale entries so the file shrinks monotonically toward empty.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, Iterable, List, Tuple

from repro.analysis.registry import Finding

DEFAULT_BASELINE = ".repro-lint-baseline.json"
_VERSION = 1


def fingerprint(finding: Finding, occurrence: int) -> str:
    """Stable id for one finding: line-number independent, content
    dependent. ``occurrence`` disambiguates identical lines in one file
    (two textually equal offending lines get entries 0 and 1)."""
    h = hashlib.sha256()
    key = "\x1f".join(
        (finding.rule, finding.path, finding.text, str(occurrence)))
    h.update(key.encode("utf-8"))
    return h.hexdigest()[:16]


def _fingerprint_all(findings: Iterable[Finding]) -> List[Tuple[str, Finding]]:
    counts: Dict[Tuple[str, str, str], int] = {}
    out = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule)):
        key = (f.rule, f.path, f.text)
        occ = counts.get(key, 0)
        counts[key] = occ + 1
        out.append((fingerprint(f, occ), f))
    return out


def write_baseline(findings: Iterable[Finding], path: str) -> int:
    entries = {
        fp: {"rule": f.rule, "path": f.path, "line": f.line,
             "text": f.text, "message": f.message}
        for fp, f in _fingerprint_all(findings)
    }
    payload = {"version": _VERSION, "findings": entries}
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return len(entries)


def load_baseline(path: str) -> Dict[str, dict]:
    """Fingerprint -> entry. Corrupt baselines raise a named error (a
    silently-ignored baseline would wave every finding through)."""
    with open(path, encoding="utf-8") as fh:
        try:
            payload = json.load(fh)
        except json.JSONDecodeError as e:
            raise ValueError(
                f"corrupt baseline file {path!r}: {e} — regenerate with "
                "--write-baseline") from e
    if not isinstance(payload, dict) or "findings" not in payload:
        raise ValueError(
            f"baseline file {path!r} has no 'findings' key — regenerate "
            "with --write-baseline")
    return dict(payload["findings"])


def split_by_baseline(findings: Iterable[Finding], baseline: Dict[str, dict]
                      ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """-> (new, grandfathered, stale_fingerprints)."""
    new: List[Finding] = []
    old: List[Finding] = []
    seen = set()
    for fp, f in _fingerprint_all(findings):
        if fp in baseline:
            seen.add(fp)
            old.append(f)
        else:
            new.append(f)
    stale = sorted(set(baseline) - seen)
    return new, old, stale


def default_baseline_path(root: str) -> str:
    return os.path.join(root, DEFAULT_BASELINE)
