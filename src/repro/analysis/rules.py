"""The RL00x checkers: repo-specific JAX-discipline invariants.

Each rule cross-references the DESIGN.md invariant it guards (see the
"Invariant registry" table there). The rules are deliberately tuned to
THIS repo's idioms — ``make_train_step`` factories, bucket-space wire
buffers, the sanctioned one-step-late telemetry drain — rather than
being a general JAX linter: the last two PRs each shipped a bug from one
of these mechanically-detectable classes, and the goal is to catch the
next one at lint time instead of review time.

False-positive policy: every heuristic here errs toward silence. The
suppression comment (``# repro-lint: disable=RL00x``) is the blessed
escape for *deliberate* violations (bench timing loops that sync on
purpose, determinism tests that reuse a key on purpose) and must carry
a human reason next to it; the committed baseline grandfathers legacy
findings without blessing them.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis import scopes
from repro.analysis.registry import Finding, register
from repro.analysis.scopes import (
    FUNC_NODES,
    LinearWalker,
    assigned_names,
    donate_argnums_of,
    dotted_name,
)

# ---------------------------------------------------------------------------
# shared repo knowledge

# step factories: module-level functions known (or discovered) to return
# a donating jitted step callable. make_train_step is the canonical one;
# per-module discovery (a function that returns a name bound from
# jax.jit(..., donate_argnums=...)) extends this set file-locally.
KNOWN_STEP_FACTORIES: Dict[str, tuple] = {
    "make_train_step": (0, 1, 2, 3),
}

# names treated as step-like even without visible provenance: the repo's
# train-step naming convention (a loop dispatching `step(...)` is a hot
# loop whether or not the factory call is in view)
STEP_LIKE_NAMES = {"step", "train_step", "sync_step", "accum_step"}

# host-sync callables: each blocks the dispatch queue on a device value.
# jax.block_until_ready is deliberately absent — it is the sanctioned
# explicit sync (bench timing); syncing *implicitly* via float()/item()
# is the defect class.
HOST_SYNC_BUILTINS = {"float", "bool", "int"}
HOST_SYNC_CANONICAL = {
    "numpy.asarray",
    "numpy.array",
    "numpy.float32",
    "numpy.float64",
    "jax.device_get",
}
HOST_SYNC_METHODS = {"item", "tolist", "__float__", "__bool__"}

# the one sanctioned deep-copy escape at the donation boundary
# (DESIGN.md invariant 7)
REPLICA_COPY_SUFFIXES = ("replica_copy",)

# jitted-callable methods that inspect rather than execute: calling them
# donates nothing (they take ShapeDtypeStructs, not live buffers)
AOT_METHODS = {"lower", "trace", "eval_shape"}


def _module_step_factories(ctx) -> Dict[str, tuple]:
    """KNOWN_STEP_FACTORIES plus per-module discovery: any function that
    jit-wraps with ``donate_argnums`` a name it later returns is a
    donating-step factory (the union of argnums across branches — a
    factory with an H>1 variant donates at least the intersection, and
    for defect *detection* over-marking is the safe direction)."""
    out = dict(KNOWN_STEP_FACTORIES)
    for func in ast.walk(ctx.tree):
        if not isinstance(func, FUNC_NODES):
            continue
        jit_bound: Dict[str, Set[int]] = {}
        for node in ast.walk(func):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                canon = ctx.imports.resolve_call(node.value)
                if canon in scopes.JIT_NAMES:
                    nums = donate_argnums_of(node.value)
                    if nums:
                        for name in (n for t in node.targets
                                     for n in assigned_names(t)):
                            jit_bound.setdefault(name, set()).update(nums)
        if not jit_bound:
            continue
        for node in ast.walk(func):
            if isinstance(node, ast.Return) and isinstance(node.value, ast.Name):
                nums = jit_bound.get(node.value.id)
                if nums:
                    merged = set(out.get(func.name, ())) | nums
                    out[func.name] = tuple(sorted(merged))
    return out


def _is_host_sync_call(call: ast.Call, imports) -> Optional[str]:
    """Return a short label when ``call`` is a host-sync, else None."""
    name = dotted_name(call.func)
    if name in HOST_SYNC_BUILTINS:
        return f"{name}()"
    canon = imports.resolve(name)
    if canon in HOST_SYNC_CANONICAL:
        return f"{canon}()"
    if isinstance(call.func, ast.Attribute) and \
            call.func.attr in HOST_SYNC_METHODS and not call.args:
        return f".{call.func.attr}()"
    return None


class _Taint:
    """A monotone set of device-tainted names with expression queries."""

    def __init__(self, imports, seeds: Optional[Set[str]] = None):
        self.imports = imports
        self.names: Set[str] = set(seeds or ())

    def expr_tainted(self, node: ast.AST) -> bool:
        """Conservative-but-quiet taint for an expression: names in the
        set, subscripts/attributes of tainted values, jnp/jax calls, and
        containers/ops over tainted operands. Calls to *unknown*
        functions never propagate taint — host-side helpers (autotune,
        calibration) return host values, and flagging through them
        drowned the signal when tried."""
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Subscript):
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Attribute):
            # step.pod_k_max etc. are host metadata on the callable —
            # only taint attribute reads of tainted VALUES, and the
            # step-like callables themselves are never in the set
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Call):
            canon = self.imports.resolve(dotted_name(node.func))
            if canon and (canon.startswith("jax.numpy.")
                          or canon.startswith("jnp.")
                          or canon.startswith("jax.lax.")
                          or canon in ("jax.grad", "jax.value_and_grad")):
                return True
            # method call ON a tainted object (m.astype(...), x.sum())
            if isinstance(node.func, ast.Attribute) and \
                    self.expr_tainted(node.func.value):
                return True
            return False
        if isinstance(node, (ast.BinOp,)):
            return self.expr_tainted(node.left) or self.expr_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr_tainted(node.operand)
        if isinstance(node, ast.IfExp):
            return self.expr_tainted(node.body) or self.expr_tainted(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr_tainted(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.expr_tainted(node.value)
        return False

    def absorb_assignments(self, func: ast.AST, step_like: Set[str]) -> None:
        """Two fixpoint passes over ``func``'s assignments: names bound
        from step-like call results or tainted expressions join the set
        (flow-insensitive — quiet in practice because step outputs are
        rebound every iteration by the repo's loop idiom)."""
        for _ in range(2):
            for node in ast.walk(func):
                if not isinstance(node, ast.Assign):
                    continue
                v = node.value
                from_step = (
                    isinstance(v, ast.Call)
                    and _callee_root_in(v, step_like)
                ) or (
                    isinstance(v, ast.IfExp)
                    and any(isinstance(b, ast.Call)
                            and _callee_root_in(b, step_like)
                            for b in (v.body, v.orelse))
                )
                if from_step or self.expr_tainted(v):
                    for t in node.targets:
                        self.names.update(assigned_names(t))


def _callee_root_in(call: ast.Call, names: Set[str]) -> bool:
    """True when the call's root name (``step`` in ``step.accum(...)``)
    is in ``names``."""
    f = call.func
    while isinstance(f, ast.Attribute):
        f = f.value
    return isinstance(f, ast.Name) and f.id in names


# ---------------------------------------------------------------------------
# RL001 — host-sync-in-hot-path


@register(
    "RL001",
    "host-sync-in-hot-path",
    "async dispatch (PR-9 review class; DESIGN.md invariant 13 note)",
    "float()/bool()/.item()/np.asarray on device values inside a jitted/"
    "shard_map body or inside a train-step dispatch loop serializes the "
    "async dispatch queue (or breaks tracing outright). The sanctioned "
    "pattern is the one-step-late drain: hold the device scalar, sync it "
    "only after the NEXT step is dispatched.",
)
def check_host_sync(ctx) -> List[Finding]:
    findings: List[Finding] = []
    imports = ctx.imports
    factories = _module_step_factories(ctx)

    # --- A: inside traced (jit / shard_map) bodies -----------------------
    traced = scopes.traced_function_defs(ctx.tree, imports)
    traced_ids = {id(t) for t in traced}
    for func in traced:
        taint = _Taint(imports, seeds={a.arg for a in _all_args(func)})
        taint.absorb_assignments(func, step_like=set())
        for node in ast.walk(func):
            # nested defs inside a traced def are traced too (closures
            # built per-trace), so no need to skip them here
            if isinstance(node, ast.Call):
                label = _is_host_sync_call(node, imports)
                if label and _sync_arg_tainted(node, taint):
                    findings.append(ctx.finding(
                        "RL001", node,
                        f"host sync {label} on a traced value inside "
                        f"jitted/shard_map body '{func.name}' — device "
                        "values never cross to host under a trace",
                    ))

    # --- B: inside train-step dispatch loops -----------------------------
    for func in ast.walk(ctx.tree):
        if not isinstance(func, FUNC_NODES) or id(func) in traced_ids:
            continue
        step_like = _step_like_names(func, imports, factories)
        if not step_like:
            continue
        hot_loops = [
            loop for loop in ast.walk(func)
            if isinstance(loop, (ast.For, ast.While))
            and scopes.enclosing_function(loop) is func
            and any(isinstance(c, ast.Call) and _callee_root_in(c, step_like)
                    for c in ast.walk(loop))
        ]
        if not hot_loops:
            continue
        taint = _Taint(imports)
        taint.absorb_assignments(func, step_like=step_like)
        for loop in hot_loops:
            for node in ast.walk(loop):
                if not isinstance(node, ast.Call):
                    continue
                if _in_nested_def(node, loop, func):
                    continue  # closures (the sanctioned drain) run later
                label = _is_host_sync_call(node, imports)
                if label and _sync_arg_tainted(node, taint):
                    findings.append(ctx.finding(
                        "RL001", node,
                        f"host sync {label} on a step output inside the "
                        "step-dispatch loop — this blocks async dispatch "
                        "every step; drain one step late instead (see "
                        "launch/train.py's pending/_drain pattern)",
                    ))
    return findings


def _all_args(func: ast.AST):
    a = func.args
    return (list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
            + ([a.vararg] if a.vararg else [])
            + ([a.kwarg] if a.kwarg else []))


def _sync_arg_tainted(call: ast.Call, taint: _Taint) -> bool:
    if isinstance(call.func, ast.Attribute) and not call.args:
        return taint.expr_tainted(call.func.value)  # x.item()
    for a in call.args:
        if taint.expr_tainted(a):
            return True
        # a tainted NAME anywhere inside the arg — float(f(count)) —
        # still forces the device value across to host for this call;
        # general expressions stay shallow (unknown calls launder
        # taint on purpose), names do not
        for n in ast.walk(a):
            if isinstance(n, ast.Name) and n.id in taint.names:
                return True
    return False


def _in_nested_def(node: ast.AST, loop: ast.AST, func: ast.AST) -> bool:
    for anc in scopes.ancestors(node):
        if anc is loop or anc is func:
            return False
        if isinstance(anc, FUNC_NODES + (ast.Lambda,)):
            return True
    return False


def _step_like_names(func: ast.AST, imports, factories: Dict[str, tuple]
                     ) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            canon = imports.resolve_call(node.value)
            callee = dotted_name(node.value.func)
            is_factory = callee in factories or (
                canon is not None and canon.split(".")[-1] in factories)
            is_jit = canon in scopes.JIT_NAMES
            if is_factory or is_jit:
                for t in node.targets:
                    names.update(assigned_names(t))
    # naming-convention fallback: loops calling step(...) are hot even
    # when the factory call is out of view (helper functions, tests)
    for node in ast.walk(func):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in STEP_LIKE_NAMES:
            names.add(node.func.id)
    return names


# ---------------------------------------------------------------------------
# RL002 — use-after-donate


@register(
    "RL002",
    "use-after-donate",
    "DESIGN.md invariant 7 (replica_copy is the one sanctioned escape)",
    "A buffer passed at a donated position of a donate_argnums-jitted "
    "step is dead after the call — XLA may have aliased its memory into "
    "the outputs. Reading it afterwards returns garbage (or crashes on "
    "some backends). Copy it with serve.replica_copy BEFORE the call if "
    "it must survive.",
)
def check_use_after_donate(ctx) -> List[Finding]:
    findings: List[Finding] = []
    imports = ctx.imports
    factories = _module_step_factories(ctx)

    for func in ast.walk(ctx.tree):
        if not isinstance(func, FUNC_NODES):
            continue

        class W(LinearWalker):
            def __init__(self):
                super().__init__()
                self.donating: Dict[str, tuple] = {}
                self.dead: Dict[str, int] = {}  # name -> donation line

            def snapshot(self):
                return dict(self.donating), dict(self.dead)

            def restore(self, snap) -> None:
                self.donating = dict(snap[0])
                self.dead = dict(snap[1])

            def merge(self, branch_snaps) -> None:
                # must-be-dead: dead on EVERY exclusive path. Branch
                # conditions in this repo correlate (the same `H > 1`
                # guards both the donating call and the rebinding
                # unpack), so 'may' union manufactures infeasible
                # donate-in-A / no-rebind-in-B paths; intersection errs
                # toward silence per the rule policy, and the loop
                # double-pass still catches real loop-carried bugs
                self.donating = {}
                for donating, _ in branch_snaps:
                    self.donating.update(donating)
                common = set.intersection(
                    *(set(dead) for _, dead in branch_snaps))
                self.dead = {
                    name: min(dead[name] for _, dead in branch_snaps)
                    for name in common
                }

            def visit_statement(self, stmt: ast.stmt) -> None:
                bound = set(scopes.statement_bound_names(stmt))
                # 1) reads of dead names in this statement
                for node in self._stmt_loads(stmt):
                    if node.id in self.dead and not self._sanctioned(node):
                        self.report(ctx.finding(
                            "RL002", node,
                            f"'{node.id}' was donated to a jitted step at "
                            f"line {self.dead[node.id]} and read here — "
                            "its buffer may be aliased into the step's "
                            "outputs; replica_copy it before the call "
                            "(DESIGN.md invariant 7)",
                        ))
                # 2) donations performed by this statement
                for call in (n for n in scopes.stmt_header_nodes(stmt)
                             if isinstance(n, ast.Call)
                             and not self._in_nested(n, stmt)):
                    nums = self._donation_argnums(call)
                    if nums is None:
                        continue
                    for i in nums:
                        if i < len(call.args) and \
                                isinstance(call.args[i], ast.Name):
                            name = call.args[i].id
                            if name not in bound:  # simultaneous rebind
                                self.dead[name] = call.lineno
                # 3) rebinding resurrects
                for name in bound:
                    self.dead.pop(name, None)
                # 4) track donating callables
                if isinstance(stmt, ast.Assign) and \
                        isinstance(stmt.value, ast.Call):
                    canon = imports.resolve_call(stmt.value)
                    nums = None
                    if canon in scopes.JIT_NAMES:
                        nums = donate_argnums_of(stmt.value)
                    else:
                        callee = dotted_name(stmt.value.func)
                        tail = (canon or callee or "").split(".")[-1]
                        if callee in factories:
                            nums = factories[callee]
                        elif tail in factories:
                            nums = factories[tail]
                    if nums:
                        for t in stmt.targets:
                            for n in assigned_names(t):
                                self.donating[n] = nums

            def _donation_argnums(self, call: ast.Call):
                f = call.func
                # AOT inspection (step.lower/.trace/.eval_shape) takes
                # abstract shapes and executes nothing — no donation
                if isinstance(f, ast.Attribute) and f.attr in AOT_METHODS:
                    return None
                while isinstance(f, ast.Attribute):
                    f = f.value  # step.accum(...) donates like step(...)
                if isinstance(f, ast.Name) and f.id in self.donating:
                    return self.donating[f.id]
                return None

            def _stmt_loads(self, stmt: ast.stmt):
                for node in scopes.stmt_header_nodes(stmt):
                    if isinstance(node, ast.Name) and \
                            isinstance(node.ctx, ast.Load) and \
                            not self._in_nested(node, stmt):
                        yield node

            @staticmethod
            def _in_nested(node: ast.AST, stmt: ast.stmt) -> bool:
                for anc in scopes.ancestors(node):
                    if anc is stmt:
                        return False
                    if isinstance(anc, FUNC_NODES + (ast.Lambda,)):
                        return True
                return False

            def _sanctioned(self, node: ast.Name) -> bool:
                for anc in scopes.ancestors(node):
                    if isinstance(anc, ast.Call):
                        canon = imports.resolve(dotted_name(anc.func)) or ""
                        if canon.endswith(REPLICA_COPY_SUFFIXES):
                            return True
                return False

        w = W()
        w.walk(func.body)
        findings.extend(w.findings)
    return findings


# ---------------------------------------------------------------------------
# RL003 — PRNG-key-reuse

KEY_PRODUCERS = {"jax.random.PRNGKey", "jax.random.key"}
KEY_DERIVERS = {"jax.random.split", "jax.random.fold_in", "jax.random.clone"}
KEY_PARAM_NAMES = {"rng", "key", "prng", "prng_key"}


@register(
    "RL003",
    "prng-key-reuse",
    "DESIGN.md invariant 12 (QSGD stochastic-rounding reproducibility)",
    "A jax.random key consumed twice (two sampling calls, or one call "
    "per loop iteration without split/fold_in) draws the SAME noise "
    "twice — correlated stochastic rounding silently biases the QSGD "
    "wire and breaks seeded reproducibility. Derive a fresh key with "
    "split/fold_in before every consumption.",
)
def check_prng_reuse(ctx) -> List[Finding]:
    findings: List[Finding] = []
    imports = ctx.imports

    # the param-NAME heuristic ('key', 'rng', ...) only makes sense in
    # modules that actually use jax — in stdlib-only tooling 'key' is a
    # dict key, and flagging it drowned the signal when tried
    uses_jax = any(v == "jax" or v.startswith("jax.")
                   for v in imports.aliases.values())

    for func in ast.walk(ctx.tree):
        if not isinstance(func, FUNC_NODES):
            continue

        param_keys = {a.arg for a in _all_args(func)
                      if a.arg.lower() in KEY_PARAM_NAMES
                      or a.arg.lower().endswith("_key")} if uses_jax else set()

        class W(LinearWalker):
            def __init__(self):
                super().__init__()
                self.keys: Set[str] = set(param_keys)
                self.consumed: Dict[str, int] = {}
                self.literal_sampled: Dict[object, int] = {}

            def snapshot(self):
                return (set(self.keys), dict(self.consumed),
                        dict(self.literal_sampled))

            def restore(self, snap) -> None:
                self.keys = set(snap[0])
                self.consumed = dict(snap[1])
                self.literal_sampled = dict(snap[2])

            def merge(self, branch_snaps) -> None:
                # a key consumed once in each exclusive arm is consumed
                # once at runtime — no flag — but it still counts as
                # consumed after the If (earliest line wins) so a LATER
                # reuse flags. Must-semantics (intersection): only keys
                # consumed on every path stay marked, erring silent on
                # half-path reuse like RL002 does
                self.keys = set().union(*(s[0] for s in branch_snaps))
                common = set.intersection(
                    *(set(s[1]) for s in branch_snaps))
                self.consumed = {
                    n: min(s[1][n] for s in branch_snaps) for n in common}
                lit_common = set.intersection(
                    *(set(s[2]) for s in branch_snaps))
                self.literal_sampled = {
                    k: min(s[2][k] for s in branch_snaps)
                    for k in lit_common}

            def visit_statement(self, stmt: ast.stmt) -> None:
                for call in (n for n in scopes.stmt_header_nodes(stmt)
                             if isinstance(n, ast.Call)
                             and not _in_nested_stmt(n, stmt)):
                    self._check_call(call)
                # assignment handling AFTER uses in the statement value
                if isinstance(stmt, ast.Assign):
                    names = [n for t in stmt.targets
                             for n in assigned_names(t)]
                    canon = (imports.resolve_call(stmt.value)
                             if isinstance(stmt.value, ast.Call) else None)
                    produces = canon in KEY_PRODUCERS | KEY_DERIVERS
                    for n in names:
                        self.consumed.pop(n, None)  # rebound: fresh value
                        if produces:
                            self.keys.add(n)
                        elif n in self.keys and not self._key_expr(stmt.value):
                            self.keys.discard(n)

            def _key_expr(self, v: ast.AST) -> bool:
                # key, sub = split(key) unpacks to key-typed names;
                # subscripts of split results are keys too
                if isinstance(v, ast.Subscript):
                    return self._key_expr(v.value)
                if isinstance(v, ast.Call):
                    return imports.resolve_call(v) in (
                        KEY_PRODUCERS | KEY_DERIVERS)
                if isinstance(v, ast.Name):
                    return v.id in self.keys
                return False

            def _check_call(self, call: ast.Call) -> None:
                canon = imports.resolve(dotted_name(call.func)) or ""
                if canon in KEY_DERIVERS:
                    return  # derivation, not consumption
                # (a) a key VARIABLE passed whole into any call
                args = list(call.args) + [kw.value for kw in call.keywords]
                for a in args:
                    if isinstance(a, ast.Name) and a.id in self.keys:
                        prev = self.consumed.get(a.id)
                        if prev is not None:
                            self.report(ctx.finding(
                                "RL003", a,
                                f"PRNG key '{a.id}' consumed again (first "
                                f"consumed at line {prev}) without an "
                                "intervening split/fold_in — the same "
                                "random stream is drawn twice",
                            ))
                        else:
                            self.consumed[a.id] = a.lineno
                # (b) two samplings from the same LITERAL PRNGKey(c)
                if canon.startswith("jax.random.") and \
                        canon not in KEY_PRODUCERS and call.args:
                    first = call.args[0]
                    if isinstance(first, ast.Call) and \
                            imports.resolve_call(first) in KEY_PRODUCERS \
                            and len(first.args) == 1 and \
                            isinstance(first.args[0], ast.Constant):
                        seed = first.args[0].value
                        prev = self.literal_sampled.get(seed)
                        if prev is not None:
                            self.report(ctx.finding(
                                "RL003", first,
                                f"PRNGKey({seed!r}) sampled again (first "
                                f"sampled at line {prev}) — two draws from "
                                "one literal seed are the same stream; "
                                "split or fold_in a step index",
                            ))
                        else:
                            self.literal_sampled[seed] = call.lineno

        w = W()
        w.walk(func.body)
        # literal-reuse dedupe across the double loop pass is handled by
        # LinearWalker.report; single-pass literal map persists on purpose
        findings.extend(w.findings)
    return findings


def _in_nested_stmt(node: ast.AST, stmt: ast.stmt) -> bool:
    for anc in scopes.ancestors(node):
        if anc is stmt:
            return False
        if isinstance(anc, FUNC_NODES + (ast.Lambda,)):
            return True
    return False


# ---------------------------------------------------------------------------
# RL004 — recompile-hazard


@register(
    "RL004",
    "recompile-hazard",
    "DESIGN.md invariants 9/10 (zero-recompile refresh)",
    "Two shapes: (a) jax.jit/shard_map built inside a loop compiles a "
    "fresh callable per iteration (the cache keys on function identity); "
    "(b) a jitted closure capturing a variable the enclosing scope "
    "rebinds later bakes a stale Python value into the trace — runtime-"
    "varying inputs (live pod ks!) must ride as traced arguments, never "
    "as closure state.",
)
def check_recompile_hazard(ctx) -> List[Finding]:
    findings: List[Finding] = []
    imports = ctx.imports

    # (a) jit/shard_map constructed lexically inside a loop
    for loop in ast.walk(ctx.tree):
        if not isinstance(loop, (ast.For, ast.While)):
            continue
        for call in ast.walk(loop):
            if not isinstance(call, ast.Call):
                continue
            canon = imports.resolve_call(call)
            if canon in scopes.JIT_NAMES or (
                    canon is not None
                    and (canon == "shard_map"
                         or canon.endswith(scopes.SHARD_MAP_SUFFIX))):
                if _in_nested_stmt(call, loop) or not scopes.is_inside(
                        call, loop):
                    continue
                findings.append(ctx.finding(
                    "RL004", call,
                    f"{canon} constructed inside a loop — the jit cache "
                    "keys on function identity, so every iteration "
                    "compiles from scratch; hoist the wrapped callable "
                    "out of the loop and pass varying values as traced "
                    "arguments",
                ))

    # (b) traced closure captures a name rebound after its definition
    for func in scopes.traced_function_defs(ctx.tree, imports):
        enclosing = scopes.enclosing_function(func)
        if enclosing is None:
            continue
        free = _free_loads(func)
        if not free:
            continue
        end = getattr(func, "end_lineno", func.lineno) or func.lineno
        for stmt in scopes.linear_statements(enclosing.body):
            if stmt.lineno <= end or scopes.is_inside(stmt, func):
                continue
            rebound = set(scopes.statement_bound_names(stmt)) & free
            rebound.discard(func.name)  # f = jax.jit(f) is the idiom
            for name in sorted(rebound):
                findings.append(ctx.finding(
                    "RL004", stmt,
                    f"'{name}' is rebound here but captured by the "
                    f"traced closure '{func.name}' defined at line "
                    f"{func.lineno} — the trace baked in the OLD value; "
                    "pass it as a traced argument instead (zero-"
                    "recompile refresh, DESIGN.md invariants 9/10)",
                ))
    return findings


def _free_loads(func: ast.AST) -> Set[str]:
    """Names read by ``func`` that it neither binds nor receives."""
    bound = {a.arg for a in _all_args(func)}
    for node in ast.walk(func):
        if isinstance(node, ast.Name) and isinstance(
                node.ctx, (ast.Store, ast.Del)):
            bound.add(node.id)
        elif isinstance(node, FUNC_NODES) and node is not func:
            bound.add(node.name)
        elif isinstance(node, (ast.Global, ast.Nonlocal)):
            bound.update(node.names)
        elif isinstance(node, ast.comprehension):
            bound.update(assigned_names(node.target))
    import builtins

    loads = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if node.id not in bound and not hasattr(builtins, node.id):
                loads.add(node.id)
    return loads


# ---------------------------------------------------------------------------
# RL005 — wire-header-literal

HEADER_NAME_PARTS = ("buf", "header", "hdr", "wire", "msg", "packed")
ENCODING_MODULE = "core/encoding.py"
HEADER_WORDS = 8  # mirror of encoding.HEADER_WORDS (stdlib-only linter)


@register(
    "RL005",
    "wire-header-literal",
    "DESIGN.md invariants 1/3/11 (self-describing packed wire layout)",
    "Integer-literal indexing into the packed wire header outside "
    "core/encoding.py hardcodes the word layout — the next header "
    "reshuffle silently reads the wrong field (the live_n word moved "
    "once already). Use the named encoding.*_WORD constants, or better, "
    "the accessor helpers (live_n_of, spec_of).",
)
def check_wire_header_literal(ctx) -> List[Finding]:
    if ctx.relpath.endswith(ENCODING_MODULE):
        return []  # the layout's single home defines the constants
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Subscript):
            continue
        base = node.value
        if not isinstance(base, ast.Name):
            continue
        name = base.id.lower()
        if not any(p in name for p in HEADER_NAME_PARTS):
            continue
        if name.endswith("s"):
            continue  # bufs/msgs are bucket LISTS, not wire buffers
        sl = node.slice
        if isinstance(sl, ast.Constant) and isinstance(sl.value, int) \
                and 0 <= sl.value < HEADER_WORDS:
            findings.append(ctx.finding(
                "RL005", node,
                f"'{base.id}[{sl.value}]' indexes a packed header word "
                "by integer literal outside core/encoding.py — use the "
                "named encoding constants (MAGIC/LIVE_N_WORD/...) or the "
                "accessor helpers",
            ))
        elif isinstance(sl, ast.Slice) and sl.lower is None and \
                isinstance(sl.upper, ast.Constant) and \
                sl.upper.value == HEADER_WORDS:
            findings.append(ctx.finding(
                "RL005", node,
                f"'{base.id}[:{HEADER_WORDS}]' slices the packed header "
                "by literal width outside core/encoding.py — use "
                "encoding.HEADER_WORDS",
            ))
    return findings


# ---------------------------------------------------------------------------
# RL006 — silent-fallback


@register(
    "RL006",
    "silent-fallback",
    "DESIGN.md invariant 9 note (named errors over silent defaults)",
    "A bare except, or an except Exception whose handler neither raises "
    "nor references the caught error, silently converts a real failure "
    "into a default value — the pod_k_for_bucket global-ratio fallback "
    "class (fixed in PR 5). Catch the narrowest type and raise a named "
    "error, or at minimum report what was swallowed.",
)
def check_silent_fallback(ctx) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            findings.append(ctx.finding(
                "RL006", node,
                "bare 'except:' swallows every failure (including "
                "KeyboardInterrupt) — catch the narrowest type and "
                "raise a named error",
            ))
            continue
        broad = {"Exception", "BaseException"}
        types = [node.type] if not isinstance(node.type, ast.Tuple) \
            else list(node.type.elts)
        if not any(dotted_name(t) in broad for t in types):
            continue
        has_raise = any(isinstance(n, ast.Raise) for n in ast.walk(node))
        uses_err = node.name is not None and any(
            isinstance(n, ast.Name) and n.id == node.name
            and isinstance(n.ctx, ast.Load)
            for n in ast.walk(node)
        )
        if not has_raise and not uses_err:
            findings.append(ctx.finding(
                "RL006", node,
                "'except Exception' that neither re-raises nor reports "
                "the caught error is a silent fallback — the "
                "pod_k_for_bucket class of bug; raise a named error or "
                "log what was swallowed",
            ))
    return findings
