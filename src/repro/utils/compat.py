"""jax version-compatibility shims.

The framework targets the current jax API (``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)``, ``jax.shard_map``) but must also run
on older releases (this container ships 0.4.x) where those names live
elsewhere or don't exist:

* ``AxisType``   — absent before 0.5; meshes are implicitly Auto.
* ``make_mesh``  — older signature has no ``axis_types`` kwarg.
* ``shard_map``  — ``jax.experimental.shard_map.shard_map`` with the manual
  axes expressed through the complementary ``auto=`` frozenset and
  ``check_vma`` spelled ``check_rep``.

Everything that builds meshes or shard_maps goes through this module so
version drift is handled in exactly one place.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore  # noqa: F401
except ImportError:  # pragma: no cover - depends on installed jax

    class AxisType:  # minimal stand-in; old meshes are implicitly Auto
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_MAKE_MESH_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters
)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates the missing ``axis_types`` kwarg.

    ``axis_types=None`` means all-Auto (the only mode this codebase uses;
    older jax without the kwarg behaves that way implicitly).
    """
    kw = {} if devices is None else {"devices": devices}
    if _MAKE_MESH_AXIS_TYPES:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types,
                             **kw)
    return jax.make_mesh(axis_shapes, axis_names, **kw)


if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=False):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)

else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=False):
        manual = frozenset(axis_names) if axis_names is not None else (
            frozenset(mesh.axis_names))
        auto = frozenset(mesh.axis_names) - manual
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=bool(check_vma),
                                 auto=auto)


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:  # pragma: no cover - depends on installed jax

    def axis_size(axis_name):
        """Size of a mapped mesh axis (usable inside shard_map)."""
        return jax.lax.psum(1, axis_name)


__all__ = ["AxisType", "make_mesh", "shard_map", "axis_size"]
