"""Training telemetry sink: loss health, bytes, and compile accounting.

``Telemetry`` is the one observer ``launch.train.train()`` feeds every
step (in the style of HomebrewNLP's ``wandblog.py``): it keeps a
rolling-window loss median, flags spikes (loss far above the window
median) and non-finite losses — raising a NAMED error instead of
letting the loop train to its step budget on garbage — tracks the
exact per-step cross-worker/cross-pod byte accounting
(``distributed.bucketed_message_bytes`` values, fed by the driver),
records the per-bucket pod ks after every live refresh, and samples the
jit-cache population each step (absorbing the driver's historical
ad-hoc ``diagnostics=`` dict, whose keys it still emits verbatim).

Telemetry is **observe-only** (DESIGN.md invariant 13): it reads host
floats after the step has already been dispatched and never touches
params, memory, or the traced computation — enabling it is bitwise
inert on training state (``tests/test_telemetry.py`` pins this with a
selfcheck-style probe) AND inert on wall-clock: the driver drains each
step's device loss only after the next step is dispatched, so the
blocking host read never stalls JAX async dispatch (step records in
the JSONL series therefore lag events like ``pod_refresh`` by one
step; every record carries its own ``step`` field).

Series go to a JSONL file when ``TelemetryConfig.jsonl_path`` is set
(one record per step, one per event), and ``summary()`` returns the
scenario-health dict the ``matrix`` bench gates in CI.
"""
from __future__ import annotations

import dataclasses
import json
import math
import statistics
from collections import deque
from typing import Callable, List, Optional, Sequence


class NonFiniteLossError(RuntimeError):
    """Loss went NaN/inf. Carries the offending step index; when raised
    out of ``launch.train.train()``, ``history`` additionally carries
    the partial ``(step, loss)`` log accumulated before the stop."""

    def __init__(self, step: int, loss: float):
        self.step = step
        self.loss = loss
        self.history: Optional[list] = None
        super().__init__(
            f"non-finite loss {loss!r} at step {step} — stopping instead "
            "of training to the step budget on garbage (pass "
            "TelemetryConfig(stop_on_nonfinite=False) to observe only)"
        )


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    # rolling loss-median window (also the first/last summary window)
    window: int = 8
    # a loss is a SPIKE when it exceeds spike_factor * (window median);
    # detection arms once the window holds >= min_history samples
    spike_factor: float = 4.0
    min_history: int = 3
    # non-finite loss raises NonFiniteLossError (the named early stop);
    # False records it and keeps observing
    stop_on_nonfinite: bool = True
    # optional spike early-stop budget: after this many spikes,
    # ``stop_reason`` is set and the driver breaks out of the loop
    # (None = never stop on spikes, they are only counted)
    max_spikes: Optional[int] = None
    # one JSON record per step/event appended here (None = in-memory only)
    jsonl_path: Optional[str] = None


class RollingMedian:
    """Median over the last ``window`` pushed values.

    Tiny windows (telemetry uses <= ~16) make the O(window log window)
    re-sort per read irrelevant; correctness and zero deps win.
    """

    def __init__(self, window: int):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self._buf: deque = deque(maxlen=window)

    def push(self, x: float) -> float:
        self._buf.append(float(x))
        return self.value

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def value(self) -> Optional[float]:
        if not self._buf:
            return None
        return float(statistics.median(self._buf))


def is_spike(x: float, median: Optional[float], factor: float) -> bool:
    """True iff ``x`` is in excess of ``factor`` times the window median.

    A non-finite ``x`` is a *non-finite* event, not a spike; an empty or
    non-finite median (no history yet) can never flag.
    """
    if median is None or not math.isfinite(median) or not math.isfinite(x):
        return False
    return x > factor * median


class SpikeDetector:
    """Rolling-median spike detector: ``observe(x)`` -> flagged?

    Every finite observation enters the window AFTER detection, so a
    value is always judged against the median of its predecessors and
    the properties the tests pin hold: a constant stream keeps a
    constant median and never flags; a value is flagged iff it exceeds
    ``factor`` times the current window median (once ``min_history``
    samples arrived).
    """

    def __init__(self, window: int = 8, factor: float = 4.0,
                 min_history: int = 3):
        self.median = RollingMedian(window)
        self.factor = factor
        self.min_history = min_history

    def observe(self, x: float) -> bool:
        armed = len(self.median) >= self.min_history
        flagged = armed and is_spike(x, self.median.value, self.factor)
        if math.isfinite(x):
            self.median.push(x)
        return flagged


class Telemetry:
    """Per-run telemetry sink. The driver calls ``step()`` every
    optimizer/local step and ``pod_refresh()`` at each live pod-k
    refresh; ``summary()``/``diagnostics()`` read everything back."""

    def __init__(self, config: TelemetryConfig = TelemetryConfig(),
                 printer: Callable[[str], None] = print):
        self.config = config
        self._print = printer
        self._detector = SpikeDetector(config.window, config.spike_factor,
                                       config.min_history)
        self.losses: List[float] = []
        self.spike_steps: List[int] = []
        self.nonfinite_step: Optional[int] = None
        self.stop_reason: Optional[str] = None
        self.cache_sizes: List[Optional[int]] = []
        self.refresh_schedule: List[tuple] = []
        self.initial_pod_ks: Optional[tuple] = None
        self.bytes_per_step: Optional[dict] = None
        self._bytes_total: dict = {}
        self._fh = None
        if config.jsonl_path:
            self._fh = open(config.jsonl_path, "w")

    # -- ingestion ----------------------------------------------------------

    def _write(self, rec: dict) -> None:
        if self._fh is not None:
            self._fh.write(json.dumps(rec) + "\n")

    def set_bytes_per_step(self, acct: Optional[dict]) -> None:
        """Install the CURRENT per-step byte accounting (the exact
        ``bucketed_message_bytes`` / ``amortized_bytes_per_step`` dict,
        e.g. ``{"intra", "cross", "total"}``). The driver refreshes it
        whenever the live pod ks change; ``step()`` accumulates it."""
        self.bytes_per_step = dict(acct) if acct is not None else None

    def step(self, i: int, loss: float, *, aux: Optional[float] = None,
             cache_size: Optional[int] = None, log: bool = False) -> dict:
        """Record one step. Returns the record; raises
        ``NonFiniteLossError`` on NaN/inf loss when configured to."""
        loss = float(loss)
        median_before = self._detector.median.value
        spike = self._detector.observe(loss)
        finite = math.isfinite(loss)
        self.losses.append(loss)
        self.cache_sizes.append(cache_size)
        if self.bytes_per_step is not None:
            for k, v in self.bytes_per_step.items():
                self._bytes_total[k] = self._bytes_total.get(k, 0) + v
        if spike:
            self.spike_steps.append(i)
            self._print(
                f"telemetry: loss spike at step {i}: {loss:.4f} vs "
                f"window median {median_before:.4f} "
                f"(> x{self.config.spike_factor:g})"
            )
            if (self.config.max_spikes is not None
                    and len(self.spike_steps) >= self.config.max_spikes
                    and self.stop_reason is None):
                self.stop_reason = (
                    f"loss spiked {len(self.spike_steps)} time(s) "
                    f"(max_spikes={self.config.max_spikes}), last at "
                    f"step {i}"
                )
        rec = {
            "step": i, "loss": loss,
            "median": self._detector.median.value,
            "spike": bool(spike), "finite": bool(finite),
        }
        if aux is not None:
            rec["aux"] = float(aux)
        if cache_size is not None:
            rec["cache_size"] = cache_size
        if self.bytes_per_step is not None:
            rec["bytes"] = self.bytes_per_step
        self._write(rec)
        if log:
            self._print(f"step {i:5d}  loss {loss:.4f}")
        if not finite:
            if self.nonfinite_step is None:
                self.nonfinite_step = i
            if self.stop_reason is None:
                self.stop_reason = f"non-finite loss at step {i}"
            if self.config.stop_on_nonfinite:
                # flush (not close): the record is durable on disk, but
                # a caller-owned sink stays open so it can be reused
                # across runs / keep receiving events after the raise
                if self._fh is not None:
                    self._fh.flush()
                raise NonFiniteLossError(i, loss)
        return rec

    def pod_refresh(self, i: int, pod_ks: Sequence[int],
                    cross_bytes: Optional[float] = None) -> None:
        """Record a live pod-k refresh (the applied per-bucket ks and,
        when known, the effective cross-pod bytes they buy)."""
        ks = tuple(int(k) for k in pod_ks)
        self.refresh_schedule.append((i, ks))
        rec = {"event": "pod_refresh", "step": i, "pod_ks": list(ks)}
        if cross_bytes is not None:
            rec["cross_bytes"] = cross_bytes
        self._write(rec)

    @property
    def should_stop(self) -> bool:
        """Early-stop hook for the driver: True once the spike budget
        is exhausted (non-finite stop RAISES instead, so a bare
        stop_reason — e.g. an observed non-finite loss with
        ``stop_on_nonfinite=False`` — does not stop the loop)."""
        return (self.config.max_spikes is not None
                and len(self.spike_steps) >= self.config.max_spikes)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- readback -----------------------------------------------------------

    def _window_median(self, tail: bool) -> Optional[float]:
        w = self.config.window
        finite = [x for x in self.losses if math.isfinite(x)]
        if not finite:
            return None
        chunk = finite[-w:] if tail else finite[:w]
        return float(statistics.median(chunk))

    def summary(self) -> dict:
        """Scenario-health dict: what the ``matrix`` bench records per
        (arch, preset) cell and ``check_matrix`` gates in CI."""
        first = self._window_median(tail=False)
        last = self._window_median(tail=True)
        return {
            "steps": len(self.losses),
            "loss_first_median": first,
            "loss_last_median": last,
            "median_decreased": (first is not None and last is not None
                                 and last < first),
            "spikes": len(self.spike_steps),
            "spike_steps": list(self.spike_steps),
            "nonfinite": self.nonfinite_step is not None,
            "nonfinite_step": self.nonfinite_step,
            "stop_reason": self.stop_reason,
            "bytes_per_step": self.bytes_per_step,
            "bytes_total": dict(self._bytes_total) or None,
            "pod_refreshes": len(self.refresh_schedule),
            "pod_refresh_schedule": [
                [i, list(ks)] for i, ks in self.refresh_schedule],
            "cache_size_final": (self.cache_sizes[-1]
                                 if self.cache_sizes else None),
        }

    def steady_state_recompiles(self, local_steps: int = 1) -> Optional[int]:
        """Jit-cache entries added after the first full sync round
        settles — REAL recompiles (a live pod-k refresh must never add
        one). At H == 1 the baseline sits after the second step (the
        first call traces; the second may re-trace once as donated/
        committed shardings settle); at H > 1 both the accum and sync
        steps need their trace + settle, so the baseline is the end of
        the second round (index 2H - 1)."""
        sizes = self.cache_sizes
        if not sizes or sizes[0] is None:
            return None
        base = sizes[min(2 * max(1, local_steps) - 1, len(sizes) - 1)]
        return sizes[-1] - base

    def diagnostics(self, local_steps: int = 1) -> dict:
        """The historical ``train(diagnostics=)`` dict, verbatim keys —
        benches and tests that read it keep working unchanged."""
        return {
            "step_cache_sizes": list(self.cache_sizes),
            "step_cache_size": (self.cache_sizes[-1]
                                if self.cache_sizes else None),
            "pod_refresh_schedule": list(self.refresh_schedule),
            "initial_pod_ks": self.initial_pod_ks,
            "steady_state_recompiles":
                self.steady_state_recompiles(local_steps),
        }
