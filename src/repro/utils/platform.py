"""Per-backend platform setup + performance tables.

One place that answers "what accelerator are we on and how should the
stack configure itself for it":

* ``setup_platform`` — pin the JAX platform and append the backend's
  XLA perf flags (GPU: async collectives + latency-hiding scheduler,
  the flags that let the pipelined bucket sync's all-gathers actually
  run on a separate stream — see ``repro.core.pipeline``). MUST run
  before JAX initializes its backend client; the train CLI calls it
  first thing (``--platform``).
* ``topk_loop_cutover`` — the k up to which the k-pass argmax loop
  beats the single-pass bisection threshold select, keyed by backend.
  Measured per machine by ``benchmarks/run.py kernel_topk`` (the
  ``cutover`` sweep in BENCH_topk.json) and consumed by
  ``kernels.ops.row_topk(method="auto")`` and the distributed sync's
  ``_pick_selection``.
* ``pallas_interpret_default`` — the ``interpret=None`` resolution for
  the Pallas kernels: compiled lowering on TPU *and* GPU, interpret
  fallback on CPU, overridable either way with
  ``REPRO_PALLAS_INTERPRET=0/1`` (CI on GPU runners can force
  interpret-off; a CPU box can smoke the compiled path's plumbing).

Nothing here imports the kernels (they import us), so the module stays
import-cycle-free and safe to use before any JAX computation runs.
"""
from __future__ import annotations

import os
from typing import Optional

# XLA perf flags for CUDA backends (bayespec-style setup): run
# collectives asynchronously on a dedicated high-priority stream and
# let the latency-hiding scheduler overlap them with compute — the
# backend half of the double-buffered bucket pipeline
# (core/pipeline.py supplies the schedule, these flags supply the
# concurrent execution). Only appended when a GPU platform is
# explicitly requested: an XLA build that does not know a flag treats
# XLA_FLAGS as fatal, so a CPU run must never inherit them.
GPU_PERF_FLAGS = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
)

# Largest k at which the k-pass argmax loop still beats the fixed-cost
# (O(32*C)) bisection threshold select, per backend. The CPU entry is
# MEASURED on the interpret-mode reference machine (BENCH_topk.json
# ``cutover`` sweep: at k=8 the loop is already ~1.4x slower, at k<=4
# it wins or ties); the TPU/GPU entries keep the historical
# ``LOOP_MAX_K = 8`` until a hardware sweep refreshes them.
TOPK_LOOP_CUTOVER = {
    "cpu": 4,
    "gpu": 8,
    "tpu": 8,
}
_CUTOVER_FALLBACK = 8

ENV_INTERPRET = "REPRO_PALLAS_INTERPRET"


def _merge_xla_flags(existing: str, new_flags) -> str:
    """Append ``new_flags`` to an XLA_FLAGS string without duplicating
    flags already present (matched by flag NAME, so a user's explicit
    ``--xla_gpu_enable_async_collectives=false`` is never overridden)."""
    parts = existing.split()
    have = {p.split("=", 1)[0] for p in parts}
    for f in new_flags:
        if f.split("=", 1)[0] not in have:
            parts.append(f)
            have.add(f.split("=", 1)[0])
    return " ".join(parts)


def setup_platform(platform: Optional[str] = None,
                   host_devices: Optional[int] = None,
                   perf_flags: bool = True) -> None:
    """Pin the JAX platform and set its XLA perf flags.

    Call BEFORE the first JAX computation (backend clients read
    ``XLA_FLAGS`` once, at creation). ``platform`` in {"cpu", "gpu",
    "tpu"} (None keeps auto-detection); ``host_devices`` forces that
    many virtual CPU host devices (the 8-device debug-mesh switch the
    tests/benches set by hand today); ``perf_flags=False`` skips the
    GPU flag injection for A/B runs.
    """
    new = []
    if host_devices is not None:
        new.append(
            f"--xla_force_host_platform_device_count={host_devices}")
    if perf_flags and platform in ("gpu", "cuda"):
        new.extend(GPU_PERF_FLAGS)
    if new:
        os.environ["XLA_FLAGS"] = _merge_xla_flags(
            os.environ.get("XLA_FLAGS", ""), new)
    if platform is not None:
        import jax

        jax.config.update(
            "jax_platform_name", "gpu" if platform == "cuda" else platform)


def backend() -> str:
    """The active JAX backend name ("cpu" / "gpu" / "tpu")."""
    import jax

    return jax.default_backend()


def topk_loop_cutover(backend_name: Optional[str] = None) -> int:
    """Per-backend loop-vs-threshold top-k cutover (see table above)."""
    b = backend_name if backend_name is not None else backend()
    return TOPK_LOOP_CUTOVER.get(b, _CUTOVER_FALLBACK)


def pallas_interpret_default(backend_name: Optional[str] = None) -> bool:
    """Resolve ``interpret=None`` for the Pallas kernels.

    Priority: the ``REPRO_PALLAS_INTERPRET`` env var ("1" forces
    interpret mode, "0" forces the compiled lowering — anything else
    raises), then the backend default: compiled on TPU and GPU
    (Mosaic / Triton lowerings), interpret on CPU where no compiled
    Pallas path exists.
    """
    env = os.environ.get(ENV_INTERPRET)
    if env is not None and env != "":
        if env not in ("0", "1"):
            raise ValueError(
                f"{ENV_INTERPRET} must be '0' (compiled) or '1' "
                f"(interpret), got {env!r}")
        return env == "1"
    b = backend_name if backend_name is not None else backend()
    return b not in ("tpu", "gpu")
