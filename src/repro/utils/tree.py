"""Small pytree algebra helpers used across the framework.

These are deliberately dependency-free (no optax); Mem-SGD and the optimizer
stack are built on top of them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    """Leafwise a + b."""
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    """Leafwise a - b."""
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    """Leafwise s * a for scalar s."""
    return jax.tree.map(lambda x: s * x, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_norm(a):
    """Global L2 norm over all leaves."""
    leaves = jax.tree.leaves(a)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_dot(a, b):
    """Global inner product over all leaves."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return sum(
        jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)) for x, y in zip(la, lb)
    )


def tree_size(a):
    """Total number of scalar elements across all leaves (static int)."""
    return sum(x.size for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)
