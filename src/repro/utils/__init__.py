from repro.utils.tree import (
    tree_add,
    tree_scale,
    tree_zeros_like,
    tree_norm,
    tree_size,
    tree_dot,
)
from repro.utils.shapes import parse_hlo_shape_bytes, human_bytes
from repro.utils.telemetry import (
    NonFiniteLossError,
    Telemetry,
    TelemetryConfig,
)
from repro.utils.platform import (
    backend,
    pallas_interpret_default,
    setup_platform,
    topk_loop_cutover,
)

__all__ = [
    "NonFiniteLossError",
    "Telemetry",
    "TelemetryConfig",
    "backend",
    "pallas_interpret_default",
    "setup_platform",
    "topk_loop_cutover",
    "tree_add",
    "tree_scale",
    "tree_zeros_like",
    "tree_norm",
    "tree_size",
    "tree_dot",
    "parse_hlo_shape_bytes",
    "human_bytes",
]
