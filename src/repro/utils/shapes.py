"""HLO shape-string parsing + byte formatting used by the roofline analyzer."""
from __future__ import annotations

import re

# bytes per element for HLO primitive types
_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "f16": 2,
    "bf16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def parse_hlo_shape_bytes(shape_str: str) -> int:
    """Size in bytes of one HLO shape string like ``bf16[256,1024]{1,0}``.

    Tuple shapes like ``(f32[8,2], s32[8,2])`` are summed.
    Returns 0 for token/opaque shapes.
    """
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"


def human_flops(n: float) -> str:
    for unit in ("FLOP", "KFLOP", "MFLOP", "GFLOP", "TFLOP"):
        if abs(n) < 1000.0:
            return f"{n:.2f} {unit}"
        n /= 1000.0
    return f"{n:.2f} PFLOP"
