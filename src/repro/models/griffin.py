"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU + local attention.

Repeating block pattern (default ``rec, rec, attn`` = 1 local-attention
layer per 2 recurrent layers). Each residual block:

    x -> norm -> temporal (RG-LRU recurrent block OR local MQA) -> +x
      -> norm -> gated-GeLU MLP -> +x

RG-LRU recurrent block: two input branches (D -> d_rnn); branch 1 passes a
causal depthwise conv (width 4) then the RG-LRU; branch 2 is a GeLU gate;
the product projects back D. RG-LRU recurrence (diagonal, real):

    r_t = sigmoid(W_a x_t + b_a)            # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            # input gate
    a_t = exp(-c * softplus(Lambda) * r_t)  # c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluates the diagonal recurrence with
``jax.lax.associative_scan`` (log-depth, TPU-friendly); decode carries
(h, conv window) state. Local attention uses the shared GQA layer with a
sliding window, RoPE, and kv-head count from the config (kv=1 => MQA).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array

LRU_C = 8.0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _lru_width(cfg) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


def init_recurrent_block(key, cfg, dtype) -> dict:
    D = cfg.d_model
    R = _lru_width(cfg)
    W = cfg.hybrid.conv_width
    ks = jax.random.split(key, 6)
    # Lambda init so that a^(1/c) ~ U[0.9, 0.999] as in the paper
    lam_init = jax.random.uniform(ks[0], (R,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(lam_init)))  # inverse softplus
    return {
        "w_in": L.dense_init(ks[1], D, R, dtype),
        "w_gate_in": L.dense_init(ks[2], D, R, dtype),
        "conv_w": (jax.random.normal(ks[3], (W, R)) / math.sqrt(W)).astype(dtype),
        "conv_b": jnp.zeros((R,), dtype),
        "w_a": L.dense_init(ks[4], R, R, dtype),
        "b_a": jnp.zeros((R,), dtype),
        "w_x": L.dense_init(ks[5], R, R, dtype),
        "b_x": jnp.zeros((R,), dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": L.dense_init(jax.random.fold_in(key, 7), R, D, dtype),
    }


def init_mlp(key, cfg, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w1": L.dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype),
        "w2": L.dense_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
        "w3": L.dense_init(ks[2], cfg.d_ff, cfg.d_model, dtype),
    }


def layer_kinds(cfg) -> Tuple[str, ...]:
    pat = cfg.hybrid.pattern
    kinds = tuple(pat[i % len(pat)] for i in range(cfg.n_layers))
    return kinds


def init_block(key, cfg, kind: str, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp(k2, cfg, dtype),
    }
    if kind == "rec":
        p["rec"] = init_recurrent_block(k1, cfg, dtype)
    else:
        p["attn"] = L.init_attention(k1, cfg, dtype)
    return p


def init_params(key, cfg) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    kinds = layer_kinds(cfg)
    ks = jax.random.split(key, cfg.n_layers + 2)
    blocks = [init_block(ks[i], cfg, kinds[i], dtype)
              for i in range(cfg.n_layers)]
    # hybrid blocks are heterogeneous -> keep as a per-layer list (no scan
    # stacking across different kinds; groups of identical kind are stacked
    # by the grouping below for compact HLO).
    return {
        "embed": L.embed_init(ks[-2], cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": blocks,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "lm_head": L.dense_init(ks[-1], cfg.d_model, cfg.padded_vocab, dtype),
    }


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def rg_lru(p: dict, x: Array, h0: Array) -> Tuple[Array, Array]:
    """x: (B,T,R); h0: (B,R) fp32. Returns (y (B,T,R), h_T)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r  # (B,T,R), negative
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - jnp.square(a), 1e-12)) * (i * xf)
    # prepend h0 as the t=-1 element: recurrence h_t = a_t h_{t-1} + b_t
    a_ext = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
    b_ext = jnp.concatenate([h0[:, None, :], gated], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a_ext, b_ext), axis=1)
    y = h[:, 1:]
    return y.astype(x.dtype), y[:, -1].astype(jnp.float32)


def rg_lru_step(p: dict, x: Array, h: Array) -> Tuple[Array, Array]:
    """x: (B,R) one token; h: (B,R) fp32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_x"].astype(jnp.float32) + p["b_x"])
    a = jnp.exp(-LRU_C * jax.nn.softplus(p["lam"]) * r)
    h = a * h + jnp.sqrt(jnp.clip(1.0 - jnp.square(a), 1e-12)) * (i * xf)
    return h.astype(x.dtype), h


def causal_conv(p: dict, x: Array, carry: Optional[Array] = None
                ) -> Tuple[Array, Array]:
    """Depthwise causal conv width W. x: (B,T,R); carry: (B,W-1,R)."""
    W = p["conv_w"].shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * p["conv_w"][i] for i in range(W))
    new_carry = xp[:, -(W - 1):]
    return out + p["conv_b"], new_carry


def recurrent_block(p: dict, x: Array, state: dict) -> Tuple[Array, dict]:
    """x: (B,T,D); state: {h (B,R) fp32, conv (B,W-1,R)}."""
    main = x @ p["w_in"]
    gate = jax.nn.gelu(x @ p["w_gate_in"])
    main, conv_carry = causal_conv(p, main, state["conv"])
    y, h = rg_lru(p, main, state["h"])
    out = (y * gate) @ p["w_out"]
    return out, {"h": h, "conv": conv_carry}


def recurrent_block_step(p: dict, x: Array, state: dict) -> Tuple[Array, dict]:
    """x: (B,1,D) decode step."""
    main = x[:, 0] @ p["w_in"]
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate_in"])
    W = p["conv_w"].shape[0]
    window = jnp.concatenate([state["conv"], main[:, None, :]], axis=1)  # (B,W,R)
    conv_out = jnp.sum(window * p["conv_w"][None], axis=1) + p["conv_b"]
    y, h = rg_lru_step(p, conv_out, state["h"])
    out = (y * gate) @ p["w_out"]
    return out[:, None, :], {"h": h, "conv": window[:, 1:]}


def gated_mlp(p: dict, x: Array) -> Array:
    return (jax.nn.gelu(x @ p["w1"]) * (x @ p["w2"])) @ p["w3"]


# ---------------------------------------------------------------------------
# model-level forward / decode
# ---------------------------------------------------------------------------


def init_state(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> list:
    """Per-layer state list (heterogeneous)."""
    kinds = layer_kinds(cfg)
    R = _lru_width(cfg)
    W = cfg.hybrid.conv_width
    Ca = min(max_len, cfg.hybrid.attn_window)
    states = []
    for kind in kinds:
        if kind == "rec":
            states.append({
                "h": jnp.zeros((batch, R), jnp.float32),
                "conv": jnp.zeros((batch, W - 1, R), dtype),
            })
        else:
            states.append({
                "k": jnp.zeros((batch, Ca, cfg.n_kv_heads, cfg.hd), dtype),
                "v": jnp.zeros((batch, Ca, cfg.n_kv_heads, cfg.hd), dtype),
            })
    return states


def forward(params: dict, cfg, tokens: Array, prefix_embeds=None,
            window=None, last_only: bool = False) -> Tuple[Array, Array]:
    del prefix_embeds
    B, T = tokens.shape
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(dt)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    kinds = layer_kinds(cfg)
    attn_window = window or cfg.hybrid.attn_window
    R = _lru_width(cfg)
    W = cfg.hybrid.conv_width

    def layer(x, blk, kind):
        h_in = L.rmsnorm(blk["ln1"], x, cfg.norm_eps)
        if kind == "rec":
            st = {"h": jnp.zeros((B, R), jnp.float32),
                  "conv": jnp.zeros((B, W - 1, R), h_in.dtype)}
            t_out, _ = recurrent_block(blk["rec"], h_in, st)
        else:
            t_out = L.attention(blk["attn"], cfg, h_in, positions, attn_window)
        x = x + t_out
        x = x + gated_mlp(blk["mlp"], L.rmsnorm(blk["ln2"], x, cfg.norm_eps))
        return x

    layer_fn = layer
    if cfg.remat == "full":
        layer_fn = jax.checkpoint(layer, static_argnums=(2,))
    for blk, kind in zip(params["blocks"], kinds):
        x = layer_fn(x, blk, kind)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, jnp.zeros((), jnp.float32)


def decode_step(params: dict, cfg, cache: dict, tokens: Array
                ) -> Tuple[Array, dict]:
    """cache: {'layers': [per-layer state], 'index': ()}."""
    B = tokens.shape[0]
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens][:, None, :].astype(dt)
    kinds = layer_kinds(cfg)
    idx = cache["index"]
    new_states = []
    for blk, kind, st in zip(params["blocks"], kinds, cache["layers"]):
        h_in = L.rmsnorm(blk["ln1"], x, cfg.norm_eps)
        if kind == "rec":
            t_out, nst = recurrent_block_step(blk["rec"], h_in, st)
        else:
            t_out, ck, cv = L.attention_decode(
                blk["attn"], cfg, h_in, st["k"], st["v"], idx,
                cfg.hybrid.attn_window)
            nst = {"k": ck, "v": cv}
        x = x + t_out
        x = x + gated_mlp(blk["mlp"], L.rmsnorm(blk["ln2"], x, cfg.norm_eps))
        new_states.append(nst)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = x[:, 0] @ params["lm_head"].astype(x.dtype)
    return logits, {"layers": new_states, "index": idx + 1}
