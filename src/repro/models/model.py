"""Model bundle: family dispatch, loss, input specs.

``build_model(cfg)`` returns a ``Model`` whose methods are pure functions
(usable under jit / shard_map / eval_shape):

    params = model.init(key)
    loss, metrics = model.loss(params, batch)
    cache = model.init_cache(batch_size, max_len)
    logits, cache = model.decode_step(params, cache, tokens)
    specs = model.input_specs(shape_cfg)        # ShapeDtypeStructs only
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import griffin, rwkv, transformer

Array = jax.Array


def _xent(cfg, logits: Array, labels: Array) -> Tuple[Array, Array]:
    """Masked cross-entropy. labels < 0 are ignored (prefix/pad positions).

    Padded-vocab logits are excluded from the partition function.
    """
    V = cfg.vocab_size
    Vp = logits.shape[-1]
    if Vp > V:
        pad_mask = jnp.arange(Vp) < V
        logits = jnp.where(pad_mask, logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    safe_labels = jnp.maximum(labels, 0)
    ll = jnp.take_along_axis(
        logits, safe_labels[..., None], axis=-1
    )[..., 0].astype(jnp.float32)
    per_tok = lse - ll
    mask = (labels >= 0).astype(jnp.float32)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_tok * mask) / n, n


_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "rwkv": rwkv,
    "hybrid": griffin,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def _mod(self):
        return _FAMILIES[self.cfg.family]

    def _cast(self, params):
        """Cast float params to the compute dtype (fp32 masters stay in the
        optimizer; forward/decode run in ``cfg.compute_dtype``)."""
        dt = jnp.dtype(self.cfg.compute_dtype)
        return jax.tree.map(
            lambda p: p.astype(dt) if jnp.issubdtype(p.dtype, jnp.floating) else p,
            params,
        )

    # -- parameters ---------------------------------------------------------

    def init(self, key) -> dict:
        return self._mod.init_params(key, self.cfg)

    def param_shapes(self) -> dict:
        """Abstract parameter pytree (no allocation) for the dry-run."""
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # -- training / prefill path --------------------------------------------

    def forward(self, params, batch, window: Optional[int] = None):
        return self._mod.forward(
            self._cast(params), self.cfg, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"), window=window,
        )

    def prefill_logits(self, params, batch) -> Array:
        """Last-position logits only (inference prefill; no (B,S,V) blowup)."""
        logits, _ = self._mod.forward(
            self._cast(params), self.cfg, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"), last_only=True,
        )
        return logits[:, -1]

    def loss(self, params, batch) -> Tuple[Array, dict]:
        logits, aux = self.forward(params, batch)
        xent, n_tok = _xent(self.cfg, logits, batch["labels"])
        total = xent + aux
        return total, {"xent": xent, "aux": aux, "n_tokens": n_tok}

    # -- decode path ----------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            return transformer.init_cache(cfg, batch, max_len, dtype)
        if cfg.family == "rwkv":
            return rwkv.init_state(cfg, batch)
        if cfg.family == "hybrid":
            return {
                "layers": griffin.init_state(cfg, batch, max_len, dtype),
                "index": jnp.zeros((), jnp.int32),
            }
        raise ValueError(cfg.family)

    def cache_shapes(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len, dtype))

    def decode_step(self, params, cache, tokens):
        return self._mod.decode_step(self._cast(params), self.cfg, cache, tokens)

    # -- abstract inputs ------------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for one global batch (no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if shape.is_decode:
            return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.n_prefix_embeddings:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_prefix_embeddings, cfg.d_model),
                jnp.dtype(cfg.compute_dtype),
            )
        return specs

    # -- bookkeeping ----------------------------------------------------------

    def n_params(self) -> int:
        import math

        shapes = self.param_shapes()
        return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k of n_experts count)."""
        cfg = self.cfg
        total = self.n_params()
        if cfg.moe is None:
            return total
        import math

        shapes = self.param_shapes()
        expert_total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            if "moe" in keys and any(k in ("w_gate", "w_up", "w_down") for k in keys):
                expert_total += math.prod(leaf.shape)
        active_frac = cfg.moe.top_k / cfg.moe.n_experts
        return int(total - expert_total + expert_total * active_frac)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILIES:
        raise ValueError(f"unknown family {cfg.family!r}")
    return Model(cfg)
