"""Model zoo. Import is lazy to avoid package-init cycles with submodules."""


def build_model(cfg):
    from repro.models.model import build_model as _build

    return _build(cfg)


__all__ = ["build_model"]
