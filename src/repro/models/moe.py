"""Mixture-of-Experts FFN layer (top-k router, capacity-based dispatch).

Design (TPU-native, GShard-style but scatter-based):

* Experts live in stacked arrays (E, D, F) / (E, F, D), sharded over the
  ``model`` mesh axis by the launch layer (expert parallelism).
* Tokens are processed in GROUPS (a group = one sequence for train/prefill,
  = the whole batch for single-token decode). Within a group each token's
  top-k experts get a slot in a capacity buffer (E, C, D) with
  C = ceil(G * K * capacity_factor / E); overflow tokens are dropped for
  that expert (standard GShard semantics; the router aux loss keeps load
  balanced so drops are rare).
* Dispatch/combine use scatter/gather (``.at[].add`` / advanced indexing),
  NOT one-hot einsum — so dispatch costs O(tokens * K * D) bytes and ~zero
  FLOPs instead of the O(tokens * G * K * D) FLOPs of the one-hot matmul
  formulation. Expert compute is therefore proportional to ACTIVE params
  (times the capacity factor), which is what the roofline's
  MODEL_FLOPS/HLO_FLOPs ratio checks.
* Aux loss: Shazeer-style load balancing  E * sum_e f_e * p_e.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Array = jax.Array


def init_moe(key, cfg, dtype) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 4)
    E, D, F = m.n_experts, cfg.d_model, m.d_expert
    return {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, F)) / math.sqrt(D)).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, D, F)) / math.sqrt(D)).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, F, D)) / math.sqrt(F)).astype(dtype),
    }


def _route(p, cfg, xg: Array):
    """xg: (N, G, D) grouped tokens -> (top_w, top_i, aux_loss)."""
    m = cfg.moe
    logits = xg.astype(jnp.float32) @ p["router"]  # (N,G,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, m.top_k)  # (N,G,K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # load balance: fraction of tokens whose top-1 lands on e, vs mean prob
    E = m.n_experts
    top1 = jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32)
    frac_tokens = jnp.mean(top1, axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(frac_tokens * frac_probs) * E * m.aux_loss_weight
    return top_w, top_i, aux


def moe_ffn_grouped(p: dict, cfg, xg: Array, capacity_factor: float = 1.25
                    ) -> Tuple[Array, Array]:
    """xg: (N, G, D) -> (out (N, G, D), aux ())."""
    m = cfg.moe
    N, G, D = xg.shape
    E, K = m.n_experts, m.top_k
    C = max(1, math.ceil(G * K * capacity_factor / E))
    top_w, top_i, aux = _route(p, cfg, xg)

    # position-in-expert via cumulative count of expert assignments, walking
    # the (G*K) assignment list in order. (N, G*K)
    flat_e = top_i.reshape(N, G * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (N, G*K, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos_in_e = jnp.sum(pos * onehot, axis=-1).reshape(N, G, K)
    keep = pos_in_e < C  # capacity mask (N,G,K)
    slot = jnp.where(keep, pos_in_e, 0)

    from repro.launch.sharding import (
        constrain_moe_combine,
        constrain_moe_dispatch,
        constrain_moe_tokens,
    )

    n_idx = jnp.arange(N)[:, None]
    # pin the buffer (and each scatter update) to token layout so the
    # dispatch scatter stays shard-local over tokens (§Perf C2)
    buf = constrain_moe_tokens(jnp.zeros((N, E, C, D), xg.dtype))
    for k in range(K):  # K static, small (<=8): K scatters of (N,G,D)
        contrib = constrain_moe_tokens(jnp.where(keep[:, :, k, None], xg, 0))
        buf = constrain_moe_tokens(
            buf.at[n_idx, top_i[:, :, k], slot[:, :, k]].add(contrib)
        )

    # expert-parallel resharding (hook set by the launch layer): move the
    # token-grouped buffer to expert-sharded layout (all-to-all) so the
    # einsums below are shard-local against the expert-sharded weights.
    buf = constrain_moe_dispatch(buf)

    # expert compute (N,E,C,D) x (E,D,F)
    h = jnp.einsum("necd,edf->necf", buf, p["w_gate"])
    u = jnp.einsum("necd,edf->necf", buf, p["w_up"])
    y = jnp.einsum("necf,efd->necd", jax.nn.silu(h) * u, p["w_down"])
    y = constrain_moe_combine(y)  # back to token layout (all-to-all)

    # combine: gather each token's K expert outputs, weight, sum
    out = jnp.zeros_like(xg)
    for k in range(K):
        gathered = constrain_moe_tokens(
            y[n_idx, top_i[:, :, k], slot[:, :, k]])  # (N,G,D)
        w = (top_w[:, :, k] * keep[:, :, k]).astype(gathered.dtype)
        out = constrain_moe_tokens(out + gathered * w[:, :, None])
    return out, aux


def moe_ffn(p: dict, cfg, x: Array, capacity_factor: float = 1.25
            ) -> Tuple[Array, Array]:
    """x: (B, S, D). Groups: per-sequence for S>1, whole batch for decode."""
    B, S, D = x.shape
    if S == 1:
        out, aux = moe_ffn_grouped(p, cfg, x.reshape(1, B, D), capacity_factor)
        return out.reshape(B, S, D), aux
    out, aux = moe_ffn_grouped(p, cfg, x, capacity_factor)
    return out, aux
