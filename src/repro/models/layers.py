"""Shared neural-net layers (pure functional JAX, no flax).

Parameters are nested dicts of jnp arrays. All layer functions take
``(params, inputs, ...)`` and are shape-polymorphic over batch/seq.
Stacked-layer variants (leading L axis on every leaf) are consumed via
``jax.lax.scan`` in the model builders.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(scale: Array, x: Array, eps: float = 1e-6) -> Array:
    """Fused RMSNorm with a hand-written backward.

    Autodiff through the fp32-upcast norm emits ~10 full-activation fp32
    intermediates per backward (dominant HBM traffic in the train-step
    roofline, §Perf iteration A6); the custom VJP keeps fp32 only for the
    per-row statistics and runs the wide ops in the input dtype.
    """
    out, _ = _rmsnorm_fwd(scale, x, eps)
    return out


def _rmsnorm_fwd(scale, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)  # (..., 1) fp32 — tiny
    out = (xf * rstd).astype(x.dtype) * scale.astype(x.dtype)
    return out, (scale, x, rstd)


def _rmsnorm_bwd(eps, res, g):
    scale, x, rstd = res
    D = x.shape[-1]
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    sf = scale.astype(jnp.float32)
    xhat = xf * rstd
    gs = gf * sf
    # d_x = rstd * (gs - xhat * mean(gs * xhat))
    dot = jnp.mean(gs * xhat, axis=-1, keepdims=True)  # (..., 1)
    dx = (rstd * (gs - xhat * dot)).astype(x.dtype)
    dscale = jnp.sum(gf * xhat, axis=tuple(range(x.ndim - 1))).astype(
        scale.dtype
    )
    return dscale, dx


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def groupnorm_heads(scale: Array, x: Array, eps: float = 1e-5) -> Array:
    """Per-head group norm used by RWKV wkv output. x: (..., H, hd)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional qk-norm / bias / sliding window / KV cache)
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype) -> dict:
    hd = cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(p, cfg, x):
    B, S, _ = x.shape
    hd = cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _sdpa(q, k, v, mask, n_rep: int) -> Array:
    """q: (B,S,H,hd); k,v: (B,T,KV,hd); mask: (B,1,S,T) or broadcastable."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    KV = k.shape[2]
    qh = q.reshape(B, S, KV, n_rep, hd)
    logits = jnp.einsum("bsgrh,btgh->bgrst", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    logits = logits.reshape(B, H, S, T)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = probs.reshape(B, KV, n_rep, S, T)
    out = jnp.einsum("bgrst,btgh->bsgrh", probs.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def causal_mask(S: int, window: Optional[int] = None) -> Array:
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    if window is not None:
        m = m & (j > i - window)
    return m[None, None]  # (1,1,S,S)


# -- blocked (flash-style) attention in pure JAX -----------------------------
#
# The direct _sdpa materializes (B, H, S, T) logits: fine for smoke tests,
# catastrophic at 32k+ (petabytes). The blocked form scans query blocks and,
# inside, key/value blocks with running-max/sum softmax (fp32 stats), so the
# live footprint is O(B*H*qb*kvb). The windowed form dynamic-slices the
# static-size [qstart-window, qend) key range per query block instead —
# O(S*(window+qb)) compute, which is what makes long-context sliding-window
# shapes lowerable.

_FLASH_THRESHOLD = 2048  # use direct path below this many kv positions

# Dry-run instrumentation: XLA's cost_analysis counts a while-loop body
# ONCE, not trip_count times. The dry-run therefore (a) unrolls the
# blocked-attention loops (set_unroll_blocks) so intra-layer cost is exact,
# and (b) lowers L=2/L=4 probe models with the layer scan unrolled
# (set_unroll_layers) to recover the exact per-layer slope. Normal training
# keeps the compact scan form.
_UNROLL_BLOCKS = False
_UNROLL_LAYERS = False


def set_unroll_blocks(v: bool) -> None:
    global _UNROLL_BLOCKS
    _UNROLL_BLOCKS = v


def set_unroll_layers(v: bool) -> None:
    global _UNROLL_LAYERS
    _UNROLL_LAYERS = v


def layer_scan_unroll() -> bool:
    return _UNROLL_LAYERS


def _flash_full(q, k, v, n_rep: int, q_block: int, kv_block: int) -> Array:
    """Causal blocked attention. q: (B,S,H,hd); k,v: (B,S,KV,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    NQ = S // q_block
    NK = S // kv_block
    scale = 1.0 / math.sqrt(hd)
    qb = jnp.moveaxis(q.reshape(B, NQ, q_block, H, hd), 1, 0)  # (NQ,B,qb,H,hd)
    kb = jnp.moveaxis(k.reshape(B, NK, kv_block, KV, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, NK, kv_block, KV, hd), 1, 0)

    def per_qblock(qi, q_blk):
        # q_blk: (B,qb,H,hd) -> regroup to (B,qb,KV,rep,hd). Inputs stay
        # bf16 (MXU-native); matmuls accumulate fp32 via
        # preferred_element_type; only the small running stats are fp32.
        qg = q_blk.reshape(B, q_block, KV, n_rep, hd)

        def inner(carry, inp):
            m, l, acc = carry
            kj, (k_blk, v_blk) = inp
            logits = jnp.einsum(
                "bqgrh,bkgh->bgrqk", qg, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale  # (B,KV,rep,qb,kvb) fp32
            # causal mask between absolute positions
            qpos = qi * q_block + jnp.arange(q_block)
            kpos = kj * kv_block + jnp.arange(kv_block)
            mask = kpos[None, :] <= qpos[:, None]  # (qb,kvb)
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bkgh->bgrqh", p.astype(q.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, n_rep, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, n_rep, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, n_rep, q_block, hd), jnp.float32)
        if _UNROLL_BLOCKS:
            carry = (m0, l0, a0)
            for j in range(NK):
                carry, _ = inner(carry, (jnp.asarray(j), (kb[j], vb[j])))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                inner, (m0, l0, a0), (jnp.arange(NK), (kb, vb))
            )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KV,rep,qb,hd)
        return jnp.moveaxis(out, 3, 1).reshape(B, q_block, H, hd)

    if _UNROLL_BLOCKS:
        outs = jnp.stack([per_qblock(jnp.asarray(i), qb[i]) for i in range(NQ)])
    else:
        outs = jax.lax.map(lambda inp: per_qblock(inp[0], inp[1]),
                           (jnp.arange(NQ), qb))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd).astype(q.dtype)


def _flash_windowed(q, k, v, n_rep: int, window: int, q_block: int) -> Array:
    """Sliding-window causal attention via static-size key slices."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    NQ = S // q_block
    span = window + q_block  # static kv span per query block
    scale = 1.0 / math.sqrt(hd)
    # pad keys/values on the left so every slice is in-bounds
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    qb_all = jnp.moveaxis(q.reshape(B, NQ, q_block, H, hd), 1, 0)

    def per_qblock(qi, q_blk):
        start = qi * q_block  # slice [start, start+span) of padded keys
        k_blk = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        qg = q_blk.reshape(B, q_block, KV, n_rep, hd)
        logits = jnp.einsum(
            "bqgrh,bkgh->bgrqk", qg, k_blk,
            preferred_element_type=jnp.float32,
        ) * scale
        qpos = start + jnp.arange(q_block)  # absolute (unpadded) positions
        kpos = start + jnp.arange(span) - window
        mask = (kpos[None, :] <= qpos[:, None]) & (
            kpos[None, :] > qpos[:, None] - window
        ) & (kpos[None, :] >= 0)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bgrqk,bkgh->bgrqh", p.astype(q.dtype), v_blk,
                         preferred_element_type=jnp.float32)
        return jnp.moveaxis(out, 3, 1).reshape(B, q_block, H, hd)

    if _UNROLL_BLOCKS:
        outs = jnp.stack(
            [per_qblock(jnp.asarray(i), qb_all[i]) for i in range(NQ)]
        )
    else:
        outs = jax.lax.map(lambda inp: per_qblock(inp[0], inp[1]),
                           (jnp.arange(NQ), qb_all))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd).astype(q.dtype)


def attention(
    p: dict,
    cfg,
    x: Array,
    positions: Array,
    window: Optional[int] = None,
) -> Array:
    """Causal (training/prefill) attention; picks direct/blocked/windowed."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    if S <= _FLASH_THRESHOLD:
        out = _sdpa(q, k, v, causal_mask(S, window), n_rep)
    elif window is not None and window < S:
        # unrolled (dry-run probe) mode: cap the block count so the HLO
        # stays compilable; runtime mode keeps MXU-friendly 1024 blocks.
        qb = max(1024, S // 16) if _UNROLL_BLOCKS else min(1024, S)
        out = _flash_windowed(q, k, v, n_rep, window, qb)
    else:
        qb = kvb = (max(1024, S // 8) if _UNROLL_BLOCKS else min(1024, S))
        out = _flash_full(q, k, v, n_rep, qb, kvb)
    return out.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]


def attention_decode(
    p: dict,
    cfg,
    x: Array,
    cache_k: Array,
    cache_v: Array,
    cur_index: Array,
    window: Optional[int] = None,
) -> Tuple[Array, Array, Array]:
    """One-token decode against a (ring-buffered when windowed) KV cache.

    x: (B, 1, D). cache_k/v: (B, C, KV, hd) where C = window or max_len.
    cur_index: () int32 — number of tokens already in the cache.
    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    C = cache_k.shape[1]
    q, k, v = _qkv(p, cfg, x)
    pos = jnp.full((B, 1), cur_index, jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    slot = (cur_index % C).astype(jnp.int32)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, axis=1)
    # valid slots: written and (if windowed) within the window
    j = jnp.arange(C)
    n_written = jnp.minimum(cur_index + 1, C)
    if window is None:
        valid = j < n_written
    else:
        # ring buffer: all C slots valid once full; before that, first n slots
        valid = j < n_written
    mask = valid[None, None, None, :]  # (1,1,1,C)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    out = _sdpa(q, cache_k, cache_v, mask, n_rep)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_swiglu(key, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d_model, dtype),
    }


def swiglu(p: dict, x: Array) -> Array:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
