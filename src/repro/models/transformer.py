"""Decoder-only transformer (dense GQA + MoE variants).

Covers the assigned families:
  dense: qwen1.5-4b (qkv bias), yi-9b, qwen3-4b (qk_norm), granite-3-8b,
         musicgen-medium (audio prefix embeds), internvl2-26b (vision
         prefix embeds)
  moe:   qwen3-moe-30b-a3b, granite-moe-3b-a800m

Layers are STACKED (leading L axis on every parameter leaf) and consumed
with ``jax.lax.scan`` so the lowered HLO stays compact for 40-50 layer
models on 512 dry-run devices. Optional full remat via cfg.remat.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(key, cfg, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, cfg) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, cfg.n_layers + 3)
    blocks = [init_block(ks[i], cfg, dtype) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    p = {
        "embed": L.embed_init(ks[-3], cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": stacked,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[-2], cfg.d_model, cfg.padded_vocab, dtype)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _block_apply(cfg, p, x, positions, window):
    h = x + L.attention(p["attn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                        positions, window)
    hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    if cfg.moe is not None:
        ff, aux = moe_lib.moe_ffn(p["moe"], cfg, hn)
    else:
        ff, aux = L.swiglu(p["mlp"], hn), jnp.zeros((), jnp.float32)
    return h + ff, aux


def embed_tokens(cfg, params, tokens: Array, prefix_embeds: Optional[Array]) -> Array:
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(dt)
    if cfg.n_prefix_embeddings and prefix_embeds is not None:
        P = cfg.n_prefix_embeddings
        x = jnp.concatenate([prefix_embeds.astype(dt), x[:, P:]], axis=1)
    return x


def forward(params: dict, cfg, tokens: Array,
            prefix_embeds: Optional[Array] = None,
            window: Optional[int] = None,
            last_only: bool = False) -> Tuple[Array, Array]:
    """tokens: (B, S) -> (logits (B,S,V_padded), aux_loss ()).

    ``last_only`` applies the LM head to the final position only (prefill:
    avoids materializing (B, S, V) logits at 32k+)."""
    B, S = tokens.shape
    window = window if window is not None else cfg.sliding_window
    x = embed_tokens(cfg, params, tokens, prefix_embeds)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(carry, blk):
        from repro.launch.sharding import shard_activations

        h, aux = carry
        h, a = _block_apply(cfg, blk, h, positions, window)
        return (shard_activations(h), aux + a), None

    body_fn = body
    if cfg.remat == "full":
        body_fn = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"],
                               unroll=cfg.n_layers if L.layer_scan_unroll() else 1)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    return logits, aux


# ---------------------------------------------------------------------------
# decode (single token against KV cache)
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    C = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kvshape = (cfg.n_layers, batch, C, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(kvshape, dtype),
        "v": jnp.zeros(kvshape, dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def decode_step(params: dict, cfg, cache: dict, tokens: Array
                ) -> Tuple[Array, dict]:
    """tokens: (B,) int32 -> (logits (B, V_padded), new cache)."""
    B = tokens.shape[0]
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens][:, None, :].astype(dt)  # (B,1,D)
    idx = cache["index"]

    def body(h, blk_and_cache):
        blk, ck, cv = blk_and_cache
        attn_in = L.rmsnorm(blk["ln1"], h, cfg.norm_eps)
        a, ck, cv = L.attention_decode(blk["attn"], cfg, attn_in, ck, cv, idx,
                                       cfg.sliding_window)
        h = h + a
        hn = L.rmsnorm(blk["ln2"], h, cfg.norm_eps)
        if cfg.moe is not None:
            ff, _ = moe_lib.moe_ffn(blk["moe"], cfg, hn)
        else:
            ff = L.swiglu(blk["mlp"], hn)
        return h + ff, (ck, cv)

    def scan_body(h, xs):
        blk, ck, cv = xs
        h, (ck, cv) = body(h, (blk, ck, cv))
        return h, (ck, cv)

    x, (nk, nv) = jax.lax.scan(
        scan_body, x, (params["blocks"], cache["k"], cache["v"]),
        unroll=cfg.n_layers if L.layer_scan_unroll() else 1)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x[:, 0] @ params["embed"].T.astype(x.dtype)
    else:
        logits = x[:, 0] @ params["lm_head"].astype(x.dtype)
    new_cache = {"k": nk, "v": nv, "index": idx + 1}
    return logits, new_cache
