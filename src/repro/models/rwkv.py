"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free, data-dependent decay.

Per-head linear-attention-style recurrence with a matrix state
S_t in R^{n x n} (n = head_dim):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)          # u = "bonus"

with DATA-DEPENDENT per-channel decay w_t and ddlerp token-shift, followed
by per-head group-norm, SiLU gating, and output projection. Channel-mix is
the Finch squared-ReLU MLP with token-shift.

TPU adaptation (documented in DESIGN.md):
* Training/prefill uses a CHUNKWISE-PARALLEL scan: within a chunk of
  ``chunk_size`` tokens the contributions are computed with matmuls
  (MXU-friendly, O(T*C*n) work), and the (n x n) state is carried across
  chunks with ``jax.lax.scan``. Decode uses the exact per-step recurrence.
* The decay is parameterized ``log w_t = -decay_clamp * sigmoid(w0 + lora)``
  in (-decay_clamp, 0) instead of the paper's -exp(.): with chunk_size=16
  and decay_clamp=4 the within-chunk exponent |cum| <= 64 stays inside
  fp32 range, so the chunked form needs no per-pair renormalization. The
  expressible decay range (e^-4, 1) per step covers the useful regime.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (dense_init, rmsnorm, groupnorm_heads,
                                 layer_scan_unroll)

Array = jax.Array

DECAY_CLAMP = 4.0
_MIX_TARGETS = ("r", "k", "v", "w", "g")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_time_mix(key, cfg, dtype) -> dict:
    D = cfg.d_model
    r = cfg.rwkv
    n = r.head_dim
    H = D // n
    ks = jax.random.split(key, 12)
    p = {
        "mu_base": jnp.full((D,), 0.5, dtype),
        "mix_w1": dense_init(ks[0], D, r.lora_rank_mix * 5, dtype),
        "mix_w2": (jax.random.normal(ks[1], (5, r.lora_rank_mix, D))
                   / math.sqrt(r.lora_rank_mix)).astype(dtype),
        "mu": jnp.full((5, D), 0.5, dtype),  # per-target lerp coefficient
        "wr": dense_init(ks[2], D, D, dtype),
        "wk": dense_init(ks[3], D, D, dtype),
        "wv": dense_init(ks[4], D, D, dtype),
        "wg": dense_init(ks[5], D, D, dtype),
        "wo": dense_init(ks[6], D, D, dtype),
        "w0": jnp.zeros((D,), dtype),
        "decay_w1": dense_init(ks[7], D, r.lora_rank_decay, dtype),
        "decay_w2": dense_init(ks[8], r.lora_rank_decay, D, dtype),
        "bonus": jnp.zeros((H, n), dtype),
        "gn": jnp.ones((H, n), dtype),
    }
    return p


def init_channel_mix(key, cfg, dtype) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((D,), 0.5, dtype),
        "mu_r": jnp.full((D,), 0.5, dtype),
        "wk": dense_init(ks[0], D, F, dtype),
        "wv": dense_init(ks[1], F, D, dtype),
        "wr": dense_init(ks[2], D, D, dtype),
    }


def init_block(key, cfg, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "time": init_time_mix(k1, cfg, dtype),
        "chan": init_channel_mix(k2, cfg, dtype),
    }


def init_params(key, cfg) -> dict:
    from repro.models.layers import embed_init

    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, cfg.n_layers + 2)
    blocks = [init_block(ks[i], cfg, dtype) for i in range(cfg.n_layers)]
    return {
        "embed": embed_init(ks[-2], cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[-1], cfg.d_model, cfg.padded_vocab, dtype),
    }


# ---------------------------------------------------------------------------
# ddlerp token shift
# ---------------------------------------------------------------------------


def _ddlerp(p: dict, x: Array, x_prev: Array):
    """Data-dependent lerp producing the 5 mixed inputs (r,k,v,w,g).

    x, x_prev: (B, T, D). Returns tuple of 5 arrays (B, T, D).
    """
    dxx = x_prev - x
    base = x + dxx * p["mu_base"]
    lora = jnp.tanh(base @ p["mix_w1"])  # (B,T,5R)
    B, T, _ = lora.shape
    R = p["mix_w2"].shape[1]
    lora = lora.reshape(B, T, 5, R)
    off = jnp.einsum("btfr,frd->btfd", lora, p["mix_w2"])  # (B,T,5,D)
    mixed = x[:, :, None, :] + dxx[:, :, None, :] * (p["mu"] + off)
    return tuple(mixed[:, :, i, :] for i in range(5))


def _decay_log(p: dict, xw: Array) -> Array:
    """Per-channel log-decay in (-DECAY_CLAMP, 0). xw: (B,T,D)."""
    lora = jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    return -DECAY_CLAMP * jax.nn.sigmoid(
        (p["w0"] + lora).astype(jnp.float32)
    )


# ---------------------------------------------------------------------------
# wkv: chunkwise-parallel scan (train/prefill)
# ---------------------------------------------------------------------------


def wkv_chunked(r, k, v, log_w, bonus, state, chunk: int):
    """Chunkwise-parallel RWKV6 recurrence.

    r,k,v: (B, T, H, n); log_w: (B, T, H, n) (negative); bonus: (H, n);
    state: (B, H, n, n). T must be a multiple of ``chunk``.
    Returns (o (B,T,H,n), final state).
    """
    B, T, H, n = r.shape
    C = chunk
    NC = T // C
    rs = r.reshape(B, NC, C, H, n).astype(jnp.float32)
    ks_ = k.reshape(B, NC, C, H, n).astype(jnp.float32)
    vs = v.reshape(B, NC, C, H, n).astype(jnp.float32)
    lw = log_w.reshape(B, NC, C, H, n).astype(jnp.float32)
    u = bonus.astype(jnp.float32)

    # move chunk axis to front for scan: (NC, B, C, H, n)
    rs, ks_, vs, lw = (jnp.moveaxis(a, 1, 0) for a in (rs, ks_, vs, lw))

    def chunk_step(S, inp):
        rc, kc, vc, lwc = inp  # (B, C, H, n)
        cum = jnp.cumsum(lwc, axis=1)  # inclusive per-channel cumulative
        A_full = jnp.exp(cum[:, -1])  # (B,H,n) total chunk decay
        # q_t = r_t * exp(cum_{t-1});   kappa_s = k_s * exp(-cum_s)
        cum_prev = cum - lwc  # exclusive cumsum (cum_{t-1})
        q = rc * jnp.exp(cum_prev)
        kap = kc * jnp.exp(-cum)
        # inter-chunk: o_inter[t] = q_t @ S   (B,C,H,n) x (B,H,n,n)
        o_inter = jnp.einsum("bchi,bhij->bchj", q, S)
        # intra-chunk: strict-lower pairwise  (q_t . kappa_s) v_s
        att = jnp.einsum("bchi,bshi->bhcs", q, kap)  # (B,H,C,C)
        tri = jnp.tril(jnp.ones((C, C), jnp.float32), -1)
        att = att * tri
        o_intra = jnp.einsum("bhcs,bshj->bchj", att, vc)
        # bonus (diagonal) term: (r_t . (u * k_t)) v_t
        diag = jnp.sum(rc * (u * kc), axis=-1, keepdims=True)  # (B,C,H,1)
        o = o_inter + o_intra + diag * vc
        # state update: S' = diag(A_full) S + sum_s diag(exp(cum_C - cum_s)) k_s v_s^T
        scale = jnp.exp(cum[:, -1:, :, :] - cum)  # (B,C,H,n)
        S_new = A_full[:, :, :, None] * S + jnp.einsum(
            "bshi,bshj->bhij", kc * scale, vc
        )
        return S_new, o

    state = state.astype(jnp.float32)
    state, outs = jax.lax.scan(chunk_step, state, (rs, ks_, vs, lw))
    o = jnp.moveaxis(outs, 0, 1).reshape(B, T, H, n)
    return o.astype(r.dtype), state


def wkv_step(r, k, v, log_w, bonus, state):
    """Exact single-token recurrence (decode / oracle).

    r,k,v,log_w: (B, H, n); state: (B, H, n, n) fp32.
    """
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    u = bonus.astype(jnp.float32)
    kv = jnp.einsum("bhi,bhj->bhij", kf, vf)
    o = jnp.einsum("bhi,bhij->bhj", rf, state + u[None, :, :, None] * kv)
    w = jnp.exp(log_w.astype(jnp.float32))
    state = w[..., None] * state + kv
    return o.astype(r.dtype), state


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def time_mix(p: dict, cfg, x: Array, x_prev_last: Array, state: Array
             ) -> Tuple[Array, Array, Array]:
    """x: (B,T,D); x_prev_last: (B,D) last token of previous segment;
    state: (B,H,n,n). Returns (out, new last token, new state)."""
    B, T, D = x.shape
    n = cfg.rwkv.head_dim
    H = D // n
    x_prev = jnp.concatenate(
        [x_prev_last[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1
    )
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    r = (xr @ p["wr"]).reshape(B, T, H, n)
    k = (xk @ p["wk"]).reshape(B, T, H, n)
    v = (xv @ p["wv"]).reshape(B, T, H, n)
    g = xg @ p["wg"]
    log_w = _decay_log(p, xw).reshape(B, T, H, n)
    chunk = cfg.rwkv.chunk_size
    if T % chunk != 0 or T < chunk:
        # pure scan fallback for short / ragged sequences
        def step(S, inp):
            rt, kt, vt, lwt = inp
            o, S = wkv_step(rt, kt, vt, lwt, p["bonus"], S)
            return S, o

        seq = (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
               jnp.moveaxis(v, 1, 0), jnp.moveaxis(log_w, 1, 0))
        state, o = jax.lax.scan(step, state.astype(jnp.float32), seq)
        o = jnp.moveaxis(o, 0, 1)
    else:
        o, state = wkv_chunked(r, k, v, log_w, p["bonus"], state, chunk)
    o = groupnorm_heads(p["gn"], o).reshape(B, T, D)
    out = (o * jax.nn.silu(g)) @ p["wo"]
    return out, x[:, -1, :], state


def channel_mix(p: dict, cfg, x: Array, x_prev_last: Array
                ) -> Tuple[Array, Array]:
    x_prev = jnp.concatenate(
        [x_prev_last[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1
    )
    dxx = x_prev - x
    xk = x + dxx * p["mu_k"]
    xr = x + dxx * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    v = k @ p["wv"]
    return jax.nn.sigmoid(xr @ p["wr"]) * v, x[:, -1, :]


def block_apply(cfg, p, x, st):
    """st: dict(time_shift (B,D), chan_shift (B,D), wkv (B,H,n,n))."""
    t_out, t_shift, wkv = time_mix(p["time"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps),
                                   st["time_shift"], st["wkv"])
    h = x + t_out
    c_out, c_shift = channel_mix(p["chan"], cfg, rmsnorm(p["ln2"], h, cfg.norm_eps),
                                 st["chan_shift"])
    return h + c_out, {"time_shift": t_shift, "chan_shift": c_shift, "wkv": wkv}


# ---------------------------------------------------------------------------
# model-level forward / decode
# ---------------------------------------------------------------------------


def init_state(cfg, batch: int) -> dict:
    D = cfg.d_model
    n = cfg.rwkv.head_dim
    H = D // n
    L_ = cfg.n_layers
    return {
        "time_shift": jnp.zeros((L_, batch, D), jnp.float32),
        "chan_shift": jnp.zeros((L_, batch, D), jnp.float32),
        "wkv": jnp.zeros((L_, batch, H, n, n), jnp.float32),
        "index": jnp.zeros((), jnp.int32),
    }


def forward(params: dict, cfg, tokens: Array, prefix_embeds=None,
            window=None, last_only: bool = False) -> Tuple[Array, Array]:
    del prefix_embeds, window
    B, T = tokens.shape
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(dt)
    state0 = init_state(cfg, B)

    def body(h, blk_and_state):
        blk, ts, cs, wkv = blk_and_state
        h, st = block_apply(cfg, blk, h, {"time_shift": ts, "chan_shift": cs,
                                          "wkv": wkv})
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, _ = jax.lax.scan(
        body_fn, x,
        (params["blocks"], state0["time_shift"], state0["chan_shift"],
         state0["wkv"]),
        unroll=cfg.n_layers if layer_scan_unroll() else 1,
    )
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, jnp.zeros((), jnp.float32)


def decode_step(params: dict, cfg, cache: dict, tokens: Array
                ) -> Tuple[Array, dict]:
    """tokens: (B,). State-space decode: O(1) in sequence length."""
    B = tokens.shape[0]
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens][:, None, :].astype(dt)  # (B,1,D)

    def body(h, xs):
        blk, ts, cs, wkv = xs
        h, st = block_apply(cfg, blk, h, {"time_shift": ts, "chan_shift": cs,
                                          "wkv": wkv})
        return h, (st["time_shift"].astype(jnp.float32),
                   st["chan_shift"].astype(jnp.float32),
                   st["wkv"].astype(jnp.float32))

    x, (nts, ncs, nwkv) = jax.lax.scan(
        body, x,
        (params["blocks"], cache["time_shift"], cache["chan_shift"],
         cache["wkv"]),
        unroll=cfg.n_layers if layer_scan_unroll() else 1,
    )
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = x[:, 0] @ params["lm_head"].astype(x.dtype)
    return logits, {"time_shift": nts, "chan_shift": ncs, "wkv": nwkv,
                    "index": cache["index"] + 1}
