"""Global cross-pod byte-budget controller for the two-level sync.

``distributed.autotune_pod_ratios`` sizes each bucket's pod-stage k for
a MASS-CAPTURE target: the smallest k whose top-k holds a fixed fraction
of the mass the pod stage can see. That answers "how big must k be to
be this faithful?" but not the operator's actual question — "I can
afford N bytes per step across the slow link; where do they buy the
most mass?" ``BudgetController`` answers both from one measurement:

* it measures each sparse bucket's ABSOLUTE captured-mass curve on the
  realized pod-mean proxy (``buckets.simulate_pod_mean`` when per-shard
  buffers are available) — the same curves the autotuner reads, kept in
  one place;
* ``mass_target`` mode reproduces ``autotune_pod_ratios`` EXACTLY
  (``distributed.autotune_pod_ratios`` delegates here), so the two
  entry points can never drift apart;
* ``byte_budget`` mode WATER-FILLS a global ``SyncConfig.byte_budget``
  across buckets: dense buckets' fixed cross-pod cost and every sparse
  bucket's mandatory first slot are charged first, then slots are
  granted one at a time to whichever bucket currently offers the most
  marginal captured mass per marginal wire byte (marginal byte cost
  straight from ``encoding.message_nbytes``, so bit-packing slack —
  slots that fit in an already-paid-for word — is spent for free).
  Under concave capture curves (top-k curves are concave by
  construction: sorted decreasing contributions) the greedy allocation
  is the exact optimum — classic water-filling, cf. Wangni et al.'s
  variance-budgeted sparsification.

Either mode emits per-bucket pod ks clamped to the static padded
ceilings (``SyncConfig.pod_k_max_for_bucket`` / explicit ``k_caps``),
i.e. exactly the ``pod_ks`` the k-padded dynamic wire consumes — a
budget refresh is a pure data change with ZERO recompiles, and the
header-aware repack transport ships (and ``bucketed_message_bytes(...,
pod_ks=...)`` accounts) the allocated live k.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class BucketCurve:
    """One bucket's measured allocation inputs.

    ``abs_capture[k-1]`` is the ABSOLUTE squared mass (summed over rows)
    the k largest-|.| entries of the pod-mean proxy hold — the common
    currency the water-filling compares across buckets. ``rel_capture``
    is the same curve normalized within the visible ``support`` (the
    autotuner's historical units). Dense buckets carry empty curves and
    a fixed ``min_nbytes`` cross-pod cost."""

    bucket: int
    kind: str  # "sparse" | "dense"
    rows: int
    cols: int
    support: int  # pod-mean support bound (n_data * k_row, capped)
    k_cap: int  # static padded ceiling the allocation may not exceed
    abs_capture: np.ndarray  # (k_cap,) absolute captured mass at k
    rel_capture: np.ndarray  # (support,) support-relative capture at k
    min_nbytes: int  # cost of the mandatory allocation (k=1 | dense)


def _abs_capture(buf, max_k: int) -> np.ndarray:
    """Absolute captured squared mass (summed over rows) of a (rows,
    cols) buffer for k in 1..max_k — ``bucket_mass_capture``'s absolute
    sibling: comparable ACROSS buckets, which is what a global budget
    needs (a per-row fraction is not; a tiny bucket at 99% capture may
    hold less mass than a huge one at 50%)."""
    max_k = max(1, min(int(max_k), buf.shape[-1]))
    sq = jnp.square(jnp.abs(jnp.asarray(buf).astype(jnp.float32)))
    desc = -jnp.sort(-sq, axis=-1)[..., :max_k]
    return np.asarray(jnp.sum(jnp.cumsum(desc, axis=-1), axis=0))


class BudgetController:
    """Per-bucket pod-k allocator over measured mass/byte curves.

    ``cfg`` is a ``SyncConfig`` (duck-typed: ``k_for``, ``k_min``,
    ``wire``, ``value_dtype``, ``pod_mass_target``, ``byte_budget``);
    ``plan`` a ``buckets.BucketPlan``; ``n_data`` the intra-pod worker
    count (the support bound); ``k_caps`` the static padded ceilings
    (``step.pod_k_max`` on the dynamic path — None leaves only the
    support bound)."""

    def __init__(self, cfg, plan, n_data: int,
                 k_caps: Optional[Sequence[int]] = None):
        self.cfg = cfg
        self.plan = plan
        self.n_data = int(n_data)
        self.k_caps = None if k_caps is None else tuple(
            int(c) for c in k_caps)

    # -- measurement --------------------------------------------------------

    def measure(self, u_bufs) -> List[BucketCurve]:
        """Concrete per-bucket u = m + eta*g buffers (``(n_shards, rows,
        cols)`` per-shard stacks or ``(rows, cols)`` global — the same
        contract as ``autotune_pod_ratios``) -> one ``BucketCurve`` per
        bucket."""
        from repro.core import buckets as bk
        from repro.core import encoding as enc

        name = jnp.dtype(self.cfg.value_dtype).name
        curves = []
        for b, (spec, u) in enumerate(zip(self.plan.buckets, u_bufs)):
            if spec.kind == "dense":
                curves.append(BucketCurve(
                    bucket=b, kind="dense", rows=spec.rows, cols=spec.cols,
                    support=spec.cols, k_cap=spec.cols,
                    abs_capture=np.zeros(0), rel_capture=np.zeros(0),
                    min_nbytes=spec.rows * spec.cols * 4,
                ))
                continue
            k_row = self.cfg.k_for(spec.cols)
            support = max(1, min(spec.cols, self.n_data * k_row))
            if np.ndim(u) == 3:  # simulate the realized pod mean
                u = bk.simulate_pod_mean(u, k_row)
            k_cap = support
            if self.k_caps is not None:
                k_cap = max(1, min(k_cap, self.k_caps[b]))
            curves.append(BucketCurve(
                bucket=b, kind="sparse", rows=spec.rows, cols=spec.cols,
                support=support, k_cap=k_cap,
                abs_capture=_abs_capture(u, k_cap),
                rel_capture=bk.support_relative_capture(u, support),
                min_nbytes=enc.message_nbytes(
                    spec.rows, spec.cols, 1, name, self.cfg.wire),
            ))
        return curves

    # -- allocation ---------------------------------------------------------

    def allocate_mass_target(self, curves: Sequence[BucketCurve],
                             mass_target: Optional[float] = None
                             ) -> Tuple[int, ...]:
        """The autotuner's sizing, verbatim: per sparse bucket the
        smallest k whose support-relative capture reaches the target,
        clamped to [k_min, support] then to the static ceiling. Dense
        buckets get k=1 (never consulted)."""
        target = (self.cfg.pod_mass_target
                  if mass_target is None else mass_target)
        ks = []
        for c in curves:
            if c.kind == "dense":
                ks.append(1)
                continue
            k = int(np.searchsorted(c.rel_capture, target, side="left")) + 1
            k = max(self.cfg.k_min, min(k, c.support))
            if self.k_caps is not None:
                k = max(1, min(k, self.k_caps[c.bucket]))
            ks.append(k)
        return tuple(ks)

    def allocate_bytes(self, curves: Sequence[BucketCurve],
                       byte_budget: int) -> Tuple[int, ...]:
        """Water-fill ``byte_budget`` cross-pod bytes/step/worker across
        the buckets: charge the fixed costs (dense buckets, every sparse
        bucket's mandatory k=1 slot), then repeatedly grant the single
        slot with the highest marginal captured mass per marginal wire
        byte (zero-cost slots — bit-packing slack — are granted
        immediately). Returns per-bucket pod ks; an infeasible budget
        floors every sparse bucket at k=1 rather than failing (the
        minimum the codec can ship)."""
        import heapq

        from repro.core import encoding as enc

        name = jnp.dtype(self.cfg.value_dtype).name

        def nbytes_at(c, k):
            return enc.message_nbytes(c.rows, c.cols, k, name, self.cfg.wire)

        ks = {c.bucket: 1 for c in curves}
        spent = sum(c.min_nbytes for c in curves)
        remaining = int(byte_budget) - spent
        # heap of (-density, bucket): density = marginal mass / marginal
        # bytes for the bucket's NEXT slot; zero-cost steps use +inf
        heap = []

        def push(c):
            k = ks[c.bucket]
            if k >= c.k_cap:
                return
            gain = float(c.abs_capture[k] - c.abs_capture[k - 1])
            cost = nbytes_at(c, k + 1) - nbytes_at(c, k)
            dens = np.inf if cost == 0 else gain / cost
            heapq.heappush(heap, (-dens, c.bucket, cost, gain))

        sparse = {c.bucket: c for c in curves if c.kind == "sparse"}
        for c in sparse.values():
            push(c)
        while heap and remaining >= 0:
            neg_dens, b, cost, _ = heapq.heappop(heap)
            if cost > remaining or neg_dens == 0.0:
                # this bucket's next slot doesn't fit (or buys nothing);
                # retire the bucket — its later slots only cost more
                # and capture less (concave curve, monotone byte cost)
                continue
            ks[b] += 1
            remaining -= cost
            push(sparse[b])
        return tuple(ks[c.bucket] for c in curves)

    def allocate(self, u_bufs, byte_budget: Optional[int] = None,
                 mass_target: Optional[float] = None) -> Tuple[int, ...]:
        """Measure + allocate in one call: the byte budget (argument,
        else ``cfg.byte_budget``) wins when set; otherwise the mass
        target. Returns the per-bucket pod ks (the ``pod_ks`` schedule
        entry / ``ratios_of`` input)."""
        curves = self.measure(u_bufs)
        budget = (byte_budget if byte_budget is not None
                  else self.cfg.byte_budget)
        if budget is not None:
            return self.allocate_bytes(curves, budget)
        return self.allocate_mass_target(curves, mass_target)

    # -- emission -----------------------------------------------------------

    def ratios_of(self, ks: Sequence[int]) -> Tuple[float, ...]:
        """Per-bucket ks -> ``SyncConfig.pod_ratios`` (dense buckets
        1.0, sparse k/cols — ``int(round(r * cols))`` round-trips to k
        exactly)."""
        out = []
        for spec, k in zip(self.plan.buckets, ks):
            out.append(1.0 if spec.kind == "dense" else k / spec.cols)
        return tuple(out)

    def cross_bytes_of(self, ks: Sequence[int]) -> int:
        """Accounted cross-pod bytes/step/worker of an allocation — the
        bytes the header-aware repack transport realizes (dense buckets
        at their fixed cost, sparse at ``message_nbytes(k)``)."""
        from repro.core import encoding as enc

        name = jnp.dtype(self.cfg.value_dtype).name
        total = 0
        for spec, k in zip(self.plan.buckets, ks):
            if spec.kind == "dense":
                total += spec.rows * spec.cols * 4
            else:
                total += enc.message_nbytes(
                    spec.rows, spec.cols, max(1, min(int(k), spec.cols)),
                    name, self.cfg.wire)
        return total
