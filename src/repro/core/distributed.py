"""PARALLEL-MEM-SGD on a TPU mesh: per-worker memory + sparse all-gather.

This is the distributed heart of the framework. It runs INSIDE a
``jax.shard_map`` that is *manual* over the data-parallel mesh axes
(``('data',)`` or ``('pod', 'data')``) and *auto* (GSPMD) over the
``model`` axis. Each data-parallel worker:

  1. holds its own error-feedback memory m_w (paper Algorithm 2),
  2. forms u_w = m_w + eta * g_w for its local gradient g_w,
  3. selects ROW-BLOCK top-k (values, indices) per tensor (see below),
  4. exchanges ONLY those pairs via ``jax.lax.all_gather`` over the data
     axes (k values + k indices per tensor per worker, vs. d dense values
     for a vanilla all-reduce) — as raw arrays, or bit-packed into a
     single uint32 wire buffer per tensor when ``SyncConfig.wire ==
     "packed"`` (see ``repro.core.encoding`` and DESIGN.md),
  5. scatter-adds the W*k received pairs into a dense update and divides
     by W,
  6. keeps m_w' = u_w - own_selection.

Row-block top-k (TPU adaptation of the paper's top_k)
-----------------------------------------------------
A global top-k over a tensor-parallel parameter would require gathering
the full tensor across model shards first. Instead we select the top-k_row
within each ROW, where rows run over all axes EXCEPT a chosen ``col_axis``
that is NOT model-sharded (the launch layer picks it from the sharding
rules). Every row then lives entirely inside one model shard: selection is
shard-local, the (values, indices) arrays inherit the model sharding, and
the data-axis all-gather never touches the model axis. Row-block top-k is
a k-contraction (per-row top-k dominates per-row rand-k, which equals
rand_k in expectation; cf. ``repro.core.compression.blockwise_top_k``), so
Theorem 2.4 applies unchanged.

Sync strategies
---------------
* ``sparse_allgather`` — single-stage gather over all data axes (paper).
* ``hierarchical``     — beyond-paper: gather + densify + RE-COMPRESS
  within the pod, then gather the re-compressed summary across pods. The
  inter-pod bytes drop from W_pod*k to k_pod; the re-compression residual
  is folded back into the local memory, preserving the error-feedback
  guarantee (composition of contractions with feedback is again a
  contraction with feedback). On the bucketed path this is a true
  two-level scheme: each bucket re-selects the intra-pod mean at its OWN
  pod k (``SyncConfig.pod_ratios``, autotuned by ``autotune_pod_ratios``
  from the bucket's realized mass capture), and
  ``bucketed_message_bytes(by_level=True)`` accounts the intra- vs
  cross-pod bytes exactly per level.
* ``dense``            — vanilla data-parallel all-reduce baseline.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import pipeline
from repro.core.pipeline import COMM, COMPUTE, QUANT, REPACK
from repro.utils import compat

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PodConfig:
    """The two-level (pod-aware) half of a ``SyncConfig``."""

    # hierarchical only: re-compression ratio for the intra-pod mean
    ratio: Optional[float] = None
    # hierarchical + bucketed: per-bucket pod re-compression ratios
    # (index-aligned with BucketPlan.buckets), overriding the global
    # ``ratio`` bucket by bucket. Produced by ``autotune_pod_ratios``
    # from each bucket's realized mass capture so attention-sized and
    # bias-sized buckets don't share one k.
    ratios: Optional[Tuple[float, ...]] = None
    # mass-capture target the autotuner sizes each bucket's pod k for:
    # the smallest k whose top-k captures this fraction of the bucket's
    # per-row squared mass (clamped to the pod mean's support bound).
    mass_target: float = 0.9
    # Runtime pod k (bucketed hierarchical only): shape every buffer,
    # wire message and all-gather at the static per-bucket
    # ``pod_k_max_for_bucket`` while the LIVE k arrives as a traced
    # ``pod_ks`` argument to ``bucketed_sync_gradients`` — slots past
    # the live k are masked to (-0.0, 0) no-ops (-0.0 is the additive
    # identity; see ``kernels.topk_select.mask_live_k``) and the live
    # count rides in the packed header (``encoding.LIVE_N_WORD``). This is what
    # lets ``autotune_pod_ratios`` re-calibrate mid-run with ZERO
    # recompiles (see launch.train ``--pod-refresh-every``).
    dynamic: bool = False
    # optional cap (fraction of cols) on the static padded pod k —
    # bounds the gathered buffer below the full n_data*k_row support
    # bound at the cost of clamping how far a refresh can raise k.
    k_max_ratio: Optional[float] = None
    # the mesh axis pods are laid out over (set on multi-pod meshes)
    axis: Optional[str] = None

    def __post_init__(self):
        if self.ratios is not None and not isinstance(self.ratios, tuple):
            object.__setattr__(self, "ratios", tuple(self.ratios))


@dataclasses.dataclass(frozen=True)
class WireConfig:
    """What one sync message looks like on the wire."""

    # Wire format for the all-gather (repro.core.encoding):
    #  * "unpacked": separate (value_dtype values, int32 indices) arrays —
    #    k * (value_bits + 32) bits per row.
    #  * "packed": one uint32 buffer per leaf/bucket with bf16/f32 values
    #    and ceil(log2 cols)-bit row-local indices — k * (value_bits +
    #    ceil(log2 cols)) bits per row plus header/alignment slack. The
    #    decode + scatter-add runs shard-locally after the gather; results
    #    are bit-identical to the unpacked path. NB: on model-sharded
    #    leaves the encode's (rows, k) reshape can force GSPMD gathers —
    #    the bucketed path (already model-axis-free) is the primary user.
    wire: str = "unpacked"
    value_dtype: str = "float32"
    # QSGD-style stochastic quantization of the selected values to
    # ``quant`` levels (Qsparse-local-SGD's Q step): every sync stage
    # quantizes BEFORE its encode, the sender's own contribution uses
    # the DEQUANTIZED values so the error-feedback memory absorbs the
    # quantization error, and the packed wire ships
    # ``1 + ceil(log2(quant+1))``-bit codes plus one f32 row norm (see
    # ``encoding.WireSpec(quant=...)``). ``None`` = exact values.
    quant: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """How sync messages move and are budgeted across the slow links."""

    # Header-aware repack transport (bucketed hierarchical + pod dynamic):
    # grow each bucket's stage chain an explicit R stage between the pod
    # re-select/encode and the cross-pod gather — the point where a
    # header-aware transport compacts the k_max-padded summary down to
    # its live payload (``encoding.repack``) so cross-pod bytes track
    # the LIVE k, not the pad. In-jit the R stage is the identity
    # (static shapes cannot shrink inside a trace — results stay
    # BITWISE identical with repack on/off, and across overlap modes);
    # the byte reduction is realized by the host/pod-boundary transport
    # (``repack_transport``) and accounted by
    # ``bucketed_message_bytes(..., pod_ks=...)``.
    repack: bool = False
    # Global cross-pod byte budget per step per worker (bytes). Consumed
    # by ``core.budget.BudgetController``: instead of sizing each
    # bucket's pod k for a mass-capture target, the controller
    # water-fills this budget across buckets by marginal
    # mass-per-byte. ``None`` keeps the mass-target sizing.
    byte_budget: Optional[int] = None
    # Software-pipelined bucket schedule (repro.core.pipeline):
    #  * None  — legacy bucket-after-bucket emission (no barriers).
    #  * False — strict sequential schedule, pinned with barriers
    #            (depth 1: the honest overlap-off baseline).
    #  * True  — double buffer (depth 2): bucket b's all-gather +
    #            decode overlaps bucket b+1's top-k select + encode.
    # All three modes apply BITWISE-identical params and memory: the
    # pipeline only reorders stage emission and adds
    # ``optimization_barrier`` edges, never a value-changing op.
    overlap: Optional[bool] = None


# legacy flat SyncConfig keyword -> (grouped field, name inside the group)
_FLAT_TO_GROUP = {
    "pod_ratio": ("pod", "ratio"),
    "pod_ratios": ("pod", "ratios"),
    "pod_mass_target": ("pod", "mass_target"),
    "pod_dynamic": ("pod", "dynamic"),
    "pod_k_max_ratio": ("pod", "k_max_ratio"),
    "pod_axis": ("pod", "axis"),
    "wire": ("wire_cfg", "wire"),
    "value_dtype": ("wire_cfg", "value_dtype"),
    "quant": ("wire_cfg", "quant"),
    "repack": ("transport", "repack"),
    "byte_budget": ("transport", "byte_budget"),
    "overlap": ("transport", "overlap"),
}

# known-good flag bundles (see SyncConfig.preset)
_PRESETS = {
    # vanilla data-parallel all-reduce baseline
    "dense": dict(strategy="dense"),
    # the paper's Mem-SGD: bucketed top-k over the packed wire
    "topk": dict(strategy="sparse_allgather", bucketed=True,
                 wire_cfg=WireConfig(wire="packed")),
    # Qsparse-local-SGD: H local steps, top-k + s-level quantization,
    # one shared error memory (Basu et al.)
    "qsparse_local": dict(strategy="sparse_allgather", bucketed=True,
                          local_steps=4,
                          wire_cfg=WireConfig(wire="packed", quant=15)),
    # two-level pod sync with runtime pod k, header-aware repack
    # transport and the byte-budget water-filler ready to take a budget
    "pod_budgeted": dict(strategy="hierarchical", bucketed=True,
                         wire_cfg=WireConfig(wire="packed"),
                         pod=PodConfig(dynamic=True),
                         transport=TransportConfig(repack=True)),
}


@dataclasses.dataclass(frozen=True, init=False)
class SyncConfig:
    """How gradients are synchronized across the data-parallel axes.

    Grouped API: the pod-hierarchy knobs live in ``cfg.pod``
    (:class:`PodConfig`), the message format in ``cfg.wire_cfg``
    (:class:`WireConfig`) and the transport/scheduling knobs in
    ``cfg.transport`` (:class:`TransportConfig`)::

        SyncConfig(strategy="hierarchical", bucketed=True,
                   pod=PodConfig(dynamic=True, axis="pod"),
                   wire=WireConfig(wire="packed"),
                   transport=TransportConfig(repack=True))

    or start from a known-good bundle: ``SyncConfig.preset("topk")``.
    Flat reads (``cfg.pod_dynamic``, ``cfg.wire``, ``cfg.repack``, ...)
    keep working as properties. Flat CONSTRUCTION keywords
    (``SyncConfig(pod_dynamic=True, wire="packed")``) still parse via a
    deprecation shim — one release of warning before removal; use the
    grouped form or ``preset(...)``. Cross-flag constraints are enforced
    by ``validate()``, called at every sync entry point.
    """

    strategy: str = "sparse_allgather"  # | "hierarchical" | "dense"
    ratio: float = 0.001  # per-row k_row = max(k_min, ratio * row_len)
    k_min: int = 1
    k_max: Optional[int] = None
    # Qsparse-local-SGD local steps: workers take H uncommunicated steps
    # accumulating u = sum_h eta_h * g_h in bucket space, then sync ONCE
    # through the top-k (+ quantize) wire path — cross-worker bytes per
    # step drop by ~H while the shared memory absorbs every residual.
    # H=1 is the per-step paper path, bit-for-bit (the driver keeps it
    # on the literal unaccumulated code path).
    local_steps: int = 1
    data_axes: Tuple[str, ...] = ("data",)
    # leaves smaller than this sync densely (norm scales, biases): the
    # index overhead would exceed the dense message.
    dense_below: int = 16_384
    # Row layout for the selection:
    #  * "flatten": moveaxis + reshape to (R, C). Simple, but merging an
    #    unsharded leading dim with a model-sharded dim is a reshape GSPMD
    #    cannot repartition -> involuntary full-tensor all-gathers
    #    (measured in EXPERIMENTS.md §Perf iteration 1).
    #  * "batched": keep the native rank; top-k/scatter run batched over
    #    the leading dims, so every op preserves the tensor's sharding.
    layout: str = "batched"
    # pin sync intermediates to the parameter's sharding (A2 experiment;
    # measured no-op — GSPMD's sort/scatter partitioners replicate anyway)
    constrain_intermediates: bool = False
    # Selection/densify implementation:
    #  * "topk_scatter": jax.lax.top_k + batched scatter-add. XLA's SPMD
    #    partitioner REPLICATES both sort and scatter across the model
    #    axis (full-tensor all-gather/all-reduce per leaf — measured in
    #    §Perf iteration A3's microbenchmarks).
    #  * "argmax_onehot": k iterations of masked row-argmax + one-hot
    #    einsum densify — every op partitions cleanly; costs an extra
    #    O(k * size) elementwise flops (negligible for k <= 64).
    #  * "threshold_onehot": single-pass bisection threshold select
    #    (O(32*C), k-independent — repro.kernels.topk_select) + one-hot
    #    densify. Partitions cleanly like argmax_onehot but with no k
    #    limit; tiny k (<= the backend's measured cutover,
    #    repro.utils.platform.topk_loop_cutover) falls back to the
    #    argmax loop.
    selection: str = "argmax_onehot"
    argmax_k_limit: int = 64  # fall back to top_k beyond this
    # Bucketed flat-buffer engine (repro.core.buckets): pack the pytree
    # into a few dtype-homogeneous (R, bucket_cols) buffers so the sync
    # runs over <= ~4 big tensors instead of one dispatch per leaf.
    bucketed: bool = False
    bucket_cols: int = 1024
    pod: PodConfig = PodConfig()
    wire_cfg: WireConfig = WireConfig()
    transport: TransportConfig = TransportConfig()

    def __init__(
        self,
        strategy: str = "sparse_allgather",
        ratio: float = 0.001,
        k_min: int = 1,
        k_max: Optional[int] = None,
        local_steps: int = 1,
        data_axes: Tuple[str, ...] = ("data",),
        dense_below: int = 16_384,
        layout: str = "batched",
        constrain_intermediates: bool = False,
        selection: str = "argmax_onehot",
        argmax_k_limit: int = 64,
        bucketed: bool = False,
        bucket_cols: int = 1024,
        pod: Optional[PodConfig] = None,
        wire: Union[WireConfig, str, None] = None,
        transport: Optional[TransportConfig] = None,
        wire_cfg: Optional[WireConfig] = None,
        _warn: bool = True,
        **legacy,
    ):
        # ``wire=`` doubles as the grouped keyword (a WireConfig) and
        # the legacy flat format string ("packed"/"unpacked");
        # ``wire_cfg=`` is the unambiguous field name (what
        # dataclasses.replace round-trips).
        if isinstance(wire, WireConfig):
            if wire_cfg is not None:
                raise TypeError(
                    "pass either wire=WireConfig(...) or wire_cfg=, not both"
                )
            wire_cfg = wire
            wire = None
        if wire is not None:
            legacy["wire"] = wire
        pod = pod if pod is not None else PodConfig()
        wire_cfg = wire_cfg if wire_cfg is not None else WireConfig()
        transport = transport if transport is not None else TransportConfig()
        unknown = set(legacy) - set(_FLAT_TO_GROUP)
        if unknown:
            raise TypeError(
                f"SyncConfig got unexpected argument(s) {sorted(unknown)}"
            )
        if legacy and _warn:
            warnings.warn(
                "flat SyncConfig keyword(s) "
                f"{sorted(legacy)} are deprecated; use the grouped "
                "pod=PodConfig(...)/wire=WireConfig(...)/"
                "transport=TransportConfig(...) fields or "
                "SyncConfig.preset(...) — the flat shim is kept for one "
                "release",
                DeprecationWarning,
                stacklevel=2,
            )
        groups = {"pod": pod, "wire_cfg": wire_cfg, "transport": transport}
        over: dict = {"pod": {}, "wire_cfg": {}, "transport": {}}
        for k, v in legacy.items():
            grp, name = _FLAT_TO_GROUP[k]
            over[grp][name] = v
        for grp, kw in over.items():
            if kw:
                groups[grp] = dataclasses.replace(groups[grp], **kw)
        set_ = object.__setattr__
        set_(self, "strategy", strategy)
        set_(self, "ratio", ratio)
        set_(self, "k_min", k_min)
        set_(self, "k_max", k_max)
        set_(self, "local_steps", int(local_steps))
        set_(self, "data_axes", tuple(data_axes))
        set_(self, "dense_below", dense_below)
        set_(self, "layout", layout)
        set_(self, "constrain_intermediates", constrain_intermediates)
        set_(self, "selection", selection)
        set_(self, "argmax_k_limit", argmax_k_limit)
        set_(self, "bucketed", bucketed)
        set_(self, "bucket_cols", bucket_cols)
        set_(self, "pod", groups["pod"])
        set_(self, "wire_cfg", groups["wire_cfg"])
        set_(self, "transport", groups["transport"])

    # -- flat reads (back-compat with the pre-grouping field names) ---------

    @property
    def pod_ratio(self) -> Optional[float]:
        return self.pod.ratio

    @property
    def pod_ratios(self) -> Optional[Tuple[float, ...]]:
        return self.pod.ratios

    @property
    def pod_mass_target(self) -> float:
        return self.pod.mass_target

    @property
    def pod_dynamic(self) -> bool:
        return self.pod.dynamic

    @property
    def pod_k_max_ratio(self) -> Optional[float]:
        return self.pod.k_max_ratio

    @property
    def pod_axis(self) -> Optional[str]:
        return self.pod.axis

    @property
    def wire(self) -> str:
        return self.wire_cfg.wire

    @property
    def value_dtype(self) -> str:
        return self.wire_cfg.value_dtype

    @property
    def quant(self) -> Optional[int]:
        return self.wire_cfg.quant

    @property
    def repack(self) -> bool:
        return self.transport.repack

    @property
    def byte_budget(self) -> Optional[int]:
        return self.transport.byte_budget

    @property
    def overlap(self) -> Optional[bool]:
        return self.transport.overlap

    # -- warning-free grouped edits -----------------------------------------

    def with_pod(self, **kw) -> "SyncConfig":
        """Replace fields of ``self.pod`` (grouped, warning-free)."""
        return dataclasses.replace(
            self, pod=dataclasses.replace(self.pod, **kw))

    def with_wire(self, **kw) -> "SyncConfig":
        """Replace fields of ``self.wire_cfg`` (grouped, warning-free)."""
        return dataclasses.replace(
            self, wire_cfg=dataclasses.replace(self.wire_cfg, **kw))

    def with_transport(self, **kw) -> "SyncConfig":
        """Replace fields of ``self.transport`` (grouped, warning-free)."""
        return dataclasses.replace(
            self, transport=dataclasses.replace(self.transport, **kw))

    # -- presets ------------------------------------------------------------

    @classmethod
    def preset(cls, name: str, **overrides) -> "SyncConfig":
        """A known-good flag bundle, editable via ``overrides`` (grouped
        keywords replace a whole sub-config; flat keywords edit single
        fields on top of the bundle, warning-free — presets ARE the
        blessed construction path):

        * ``"dense"``         — vanilla data-parallel all-reduce.
        * ``"topk"``          — bucketed Mem-SGD over the packed wire.
        * ``"qsparse_local"`` — Qsparse-local-SGD: 4 local steps, top-k
          + 15-level stochastic quantization, packed wire.
        * ``"pod_budgeted"``  — two-level pod sync, runtime pod k,
          header-aware repack transport (give it ``byte_budget=...`` to
          engage the water-filler; pod_axis is filled in by the
          launcher from the mesh).
        """
        try:
            merged = dict(_PRESETS[name])
        except KeyError:
            raise ValueError(
                f"unknown SyncConfig preset {name!r}; available: "
                f"{sorted(_PRESETS)}"
            ) from None
        for k, v in overrides.items():
            if k == "wire" and isinstance(v, WireConfig):
                merged["wire_cfg"] = v
            else:
                merged[k] = v
        return cls(_warn=False, **merged)

    # -- cross-flag validation ----------------------------------------------

    def validate(self, plan=None) -> "SyncConfig":
        """Check cross-flag consistency; called at every sync entry
        point. Raises a named ``ValueError`` for each documented illegal
        combination instead of silently mis-syncing. Pass the
        ``BucketPlan`` when available to also check per-bucket
        alignment. Returns ``self`` so call sites can chain."""
        if self.strategy not in ("sparse_allgather", "hierarchical", "dense"):
            raise ValueError(f"unknown sync strategy {self.strategy!r}")
        if self.local_steps < 1:
            raise ValueError(
                f"SyncConfig.local_steps must be >= 1, got {self.local_steps}"
            )
        if self.local_steps > 1 and not self.bucketed:
            raise ValueError(
                "SyncConfig.local_steps > 1 requires the bucketed engine "
                "(bucketed=True): the local-step accumulator lives in "
                "bucket space"
            )
        if self.quant is not None:
            if self.quant < 1:
                raise ValueError(
                    f"WireConfig.quant must be >= 1 levels, got {self.quant}"
                )
            if self.strategy == "dense":
                raise ValueError(
                    "WireConfig.quant composes with the sparse selections; "
                    "the dense all-reduce strategy has no quantize stage"
                )
            if not self.bucketed:
                raise ValueError(
                    "WireConfig.quant requires the bucketed engine "
                    "(bucketed=True): quantization is defined on the "
                    "(rows, cols) bucket layout"
                )
            if self.value_dtype != "float32":
                raise ValueError(
                    "WireConfig.quant replaces the value dtype on the wire "
                    "(codes + f32 row norms); combining it with "
                    f"value_dtype={self.value_dtype!r} would quantize "
                    "already-rounded values"
                )
        if self.pod.dynamic and (
            self.strategy != "hierarchical" or self.pod.axis is None
            or not self.bucketed
        ):
            raise ValueError(
                "PodConfig.dynamic (runtime pod k) requires the bucketed "
                "hierarchical strategy with a pod axis — this config "
                "would silently ignore the live k schedule"
            )
        if self.transport.repack and not self.pod.dynamic:
            raise ValueError(
                "TransportConfig.repack requires PodConfig.dynamic: the "
                "repack boundary compacts the k_max-padded pod summary, "
                "which only exists on the runtime-k path"
            )
        if self.transport.byte_budget is not None and (
            self.strategy != "hierarchical" or not self.bucketed
        ):
            raise ValueError(
                "TransportConfig.byte_budget requires the bucketed "
                "hierarchical strategy: the budget water-fills per-bucket "
                "pod ks across a BucketPlan"
            )
        if plan is not None:
            validate_pod_ratios(self, plan)
        return self

    def overlap_depth(self) -> Optional[int]:
        """Pipeline depth the sync schedules at (None/1/2 — see
        ``overlap`` and ``repro.core.pipeline``)."""
        return pipeline.overlap_depth(self.overlap)

    def k_for(self, row_len: int) -> int:
        k = max(self.k_min, int(round(self.ratio * row_len)))
        if self.k_max is not None:
            k = min(k, self.k_max)
        return min(k, row_len)

    def pod_k_for(self, row_len: int) -> int:
        r = self.pod_ratio if self.pod_ratio is not None else self.ratio
        k = max(self.k_min, int(round(r * row_len)))
        if self.k_max is not None:
            k = min(k, self.k_max)
        return min(k, row_len)

    def pod_k_for_bucket(self, bucket: int, row_len: int) -> int:
        """Pod-stage k for one bucket: the autotuned per-bucket ratio
        when ``pod_ratios`` is set, the global ``pod_ratio`` otherwise.

        An out-of-range bucket index RAISES: ``pod_ratios`` must be
        index-aligned with the bucket plan (``validate_pod_ratios``) —
        the old silent fallback to the global ratio quietly desynced the
        byte accounting from the wire layout."""
        if self.pod_ratios is None:
            return self.pod_k_for(row_len)
        if bucket >= len(self.pod_ratios):
            raise ValueError(
                f"SyncConfig.pod_ratios has {len(self.pod_ratios)} entries "
                f"but bucket {bucket} was requested — pod_ratios must be "
                "index-aligned with the BucketPlan (one ratio per bucket; "
                "regenerate with autotune_pod_ratios)"
            )
        k = max(self.k_min, int(round(self.pod_ratios[bucket] * row_len)))
        if self.k_max is not None:
            k = min(k, self.k_max)
        return min(k, row_len)

    def pod_k_max_for_bucket(self, bucket: int, row_len: int,
                             n_data: int) -> int:
        """Static ceiling for one bucket's pod-stage k — the size the
        dynamic (k-padded) path shapes its buffers/wire at, and the
        support bound the delta spec must honour so a live ratio
        refresh can never overflow it. Covers the pod mean's support
        bound (``n_data * k_row`` — the most entries the pod stage can
        see), optionally tightened by ``pod_k_max_ratio``, and never
        below the statically configured pod k."""
        cap = min(row_len, max(1, n_data * self.k_for(row_len)))
        if self.pod_k_max_ratio is not None:
            cap = min(cap, max(self.k_min,
                               int(round(self.pod_k_max_ratio * row_len))))
        return min(row_len, max(cap, self.pod_k_for_bucket(bucket, row_len)))


def validate_pod_ratios(cfg: SyncConfig, plan) -> None:
    """Raise when ``cfg.pod_ratios`` is not index-aligned with ``plan``
    — a shorter tuple used to fall back silently to the global
    ``pod_ratio`` for the tail buckets, desyncing the byte accounting
    (and the delta-spec support bound) from what the wire ships."""
    if cfg.pod_ratios is None:
        return
    if len(cfg.pod_ratios) != len(plan.buckets):
        raise ValueError(
            f"SyncConfig.pod_ratios has {len(cfg.pod_ratios)} entries for "
            f"a {len(plan.buckets)}-bucket plan — regenerate them with "
            "autotune_pod_ratios (one ratio per bucket, dense buckets "
            "included)"
        )


def _axis_size(axis_names: Sequence[str]) -> int:
    n = 1
    for a in axis_names:
        n = n * compat.axis_size(a)
    return n


def _to_rows(x: Array, col_axis: int) -> Tuple[Array, tuple]:
    """Move col_axis last and flatten the rest: (R, C)."""
    moved = jnp.moveaxis(x, col_axis, -1)
    shape = moved.shape
    return moved.reshape(-1, shape[-1]), shape


def _from_rows(rows: Array, moved_shape: tuple, col_axis: int) -> Array:
    return jnp.moveaxis(rows.reshape(moved_shape), -1, col_axis)


def _row_topk(u: Array, k: int, constrain=lambda x: x) -> Tuple[Array, Array]:
    """u: (..., C) -> (vals (..., k), idx (..., k) int32) by |.| per row.
    Batched over all leading dims (sharding-preserving)."""
    _, idx = jax.lax.top_k(jnp.abs(u), k)
    idx = constrain(idx.astype(jnp.int32))
    vals = constrain(jnp.take_along_axis(u, idx, axis=-1))
    return vals, idx


def _row_topk_argmax(u: Array, k: int, constrain=lambda x: x
                     ) -> Tuple[Array, Array]:
    """Partition-safe per-row top-k: k masked-argmax iterations (no sort;
    GSPMD keeps batch-dim sharding). Ties resolve to the lowest index —
    identical semantics to the Pallas kernel and its oracle."""
    absu = jnp.abs(u.astype(jnp.float32))
    iota = jax.lax.broadcasted_iota(jnp.int32, u.shape, u.ndim - 1)
    vals = jnp.zeros(u.shape[:-1] + (k,), u.dtype)
    idxs = jnp.zeros(u.shape[:-1] + (k,), jnp.int32)
    for t in range(k):
        j = jnp.argmax(absu, axis=-1).astype(jnp.int32)
        v = jnp.take_along_axis(u, j[..., None], axis=-1)[..., 0]
        vals = vals.at[..., t].set(v)
        idxs = idxs.at[..., t].set(j)
        absu = jnp.where(iota == j[..., None], -jnp.inf, absu)
    return vals, idxs


def _row_topk_threshold(u: Array, k: int, constrain=lambda x: x
                        ) -> Tuple[Array, Array]:
    """Partition-safe single-pass per-row top-k: exact bit-bisection
    threshold (O(32*C) compare+count sweeps, k-independent — vs the
    argmax loop's O(k*C) dependent passes) + binary-search compaction
    (gathers along the unsharded row axis only; no sort, no scatter, so
    GSPMD keeps the batch sharding). Output contract identical to
    ``_row_topk_argmax`` / the Pallas kernels: decreasing |.|, ties to
    the lowest index."""
    from repro.kernels.topk_select import _threshold_select

    iota = jax.lax.broadcasted_iota(jnp.int32, u.shape, u.ndim - 1)
    vals, idx = _threshold_select(u, iota, None, k)
    return constrain(vals), constrain(idx.astype(jnp.int32))


def _row_densify_onehot(shape: tuple, vals: Array, idx: Array, dtype,
                        constrain=lambda x: x) -> Array:
    """Partition-safe densify: one-hot einsum instead of scatter (XLA's
    scatter partitioner replicates across the model axis)."""
    C = shape[-1]
    iota = jnp.arange(C, dtype=jnp.int32)
    onehot = (idx[..., None] == iota).astype(dtype)  # (..., k', C)
    return constrain(
        jnp.einsum("...kc,...k->...c", onehot, vals.astype(dtype))
    )


def _batch_iotas(shape: tuple) -> tuple:
    """Broadcastable index grids for every dim except the last."""
    nd = len(shape)
    out = []
    for i, s in enumerate(shape[:-1]):
        rshape = [1] * nd
        rshape[i] = s
        out.append(jnp.arange(s, dtype=jnp.int32).reshape(rshape))
    return tuple(out)


def _row_scatter(shape: tuple, vals: Array, idx: Array, dtype,
                 constrain=lambda x: x) -> Array:
    """Scatter-add (..., k') pairs into a dense (..., C) along the last
    axis, batched over leading dims (sharding-preserving)."""
    out = jnp.zeros(shape, dtype)
    return constrain(out.at[(*_batch_iotas(shape), idx)].add(vals))


def _pick_selection(cfg: "SyncConfig", k_row: int):
    """(topk, densify) implementations for one leaf/bucket (see the
    SyncConfig.selection comment for the trade-offs)."""
    from repro.utils.platform import topk_loop_cutover

    if cfg.selection not in (
        "topk_scatter", "argmax_onehot", "threshold_onehot"
    ):
        raise ValueError(f"unknown SyncConfig.selection {cfg.selection!r}")
    if cfg.selection == "threshold_onehot":
        if k_row <= topk_loop_cutover():
            return _row_topk_argmax, _row_densify_onehot
        return _row_topk_threshold, _row_densify_onehot
    if cfg.selection == "argmax_onehot" and k_row <= cfg.argmax_k_limit:
        return _row_topk_argmax, _row_densify_onehot
    return _row_topk, _row_scatter


def _gather_pairs(vals, idx, axes):
    """all_gather over every data axis; concatenated along the last axis:
    (..., W*k)."""
    for ax in axes:
        vals = jax.lax.all_gather(vals, ax, axis=vals.ndim - 1, tiled=True)
        idx = jax.lax.all_gather(idx, ax, axis=idx.ndim - 1, tiled=True)
    return vals, idx


def _encode_packed(vals, idx, wspec, live_n=None):
    """Encode (vals, idx) into one uint32 wire buffer
    (repro.core.encoding). ``live_n`` stamps a runtime live-slot count
    into the k-padded message's header (the pairs past it must already
    be masked). Pure compute — the pipeline's E stage."""
    from repro.core import encoding as enc

    k = wspec.k
    return enc.encode(
        wspec, vals.reshape(-1, k), idx.reshape(-1, k).astype(jnp.int32),
        live_n=live_n,
    )


def _gather_buf(buf, axes):
    """all-gather a wire buffer over every data axis (tiled along axis
    0). Pure communication — the pipeline's G stage."""
    for ax in axes:
        buf = jax.lax.all_gather(buf, ax, axis=0, tiled=True)
    return buf


def _decode_packed(buf, wspec, axes, lead_shape):
    """Decode a gathered wire buffer shard-locally back to (..., W*k)
    pairs, in exactly the tile order ``_gather_pairs`` produces, so the
    downstream densify/mean is bit-identical to the unpacked path. Pure
    compute — part of the pipeline's D stage."""
    from repro.core import encoding as enc

    W = _axis_size(axes)
    gv, gi = jax.vmap(lambda b: enc.decode(wspec, b))(
        buf.reshape(W, wspec.words)
    )
    gv = jnp.moveaxis(gv, 0, 1).reshape(tuple(lead_shape) + (W * wspec.k,))
    gi = jnp.moveaxis(gi, 0, 1).reshape(tuple(lead_shape) + (W * wspec.k,))
    return gv, gi


def _gather_packed(vals, idx, axes, wspec, live_n=None):
    """Packed-wire gather: encode -> all-gather -> decode (the three
    helpers above, run back to back for the non-pipelined callers)."""
    buf = _gather_buf(_encode_packed(vals, idx, wspec, live_n), axes)
    return _decode_packed(buf, wspec, axes, vals.shape[:-1])


def _run_stages(init, stages):
    st = init
    for f in stages:
        st = f(st)
    return st


def _fold_axes(key, axes):
    """Fold each named axis' index into a PRNG key: folding every data
    axis makes the key worker-unique (decorrelated level-1 quantization
    noise); folding only the pod axis keeps it identical WITHIN a pod —
    required where every worker in a pod must quantize the shared pod
    mean to the same codes."""
    for ax in axes:
        key = jax.random.fold_in(key, jax.lax.axis_index(ax))
    return key


def _quantize_selected(vals, idx, s, key):
    """QSGD-quantize a (..., k) selection: returns (norms, codes,
    dequantized f32 values). The dequantized values are what the sender
    densifies as its OWN contribution — bit-identical to what every
    receiver decodes (``encoding.dequantize_rows`` is the single shared
    formula), so the error-feedback memory absorbs exactly the
    quantization error that ships."""
    from repro.core import encoding as enc
    from repro.optim.qsgd import quantize_rows

    norms, codes = quantize_rows(vals.astype(jnp.float32), s, key)
    return norms, codes, enc.dequantize_rows(norms, codes, s)


def _encode_quant(wspec, codes, idx, norms, live_n=None):
    from repro.core import encoding as enc

    k = wspec.k
    return enc.encode(
        wspec, codes.reshape(-1, k), idx.reshape(-1, k).astype(jnp.int32),
        live_n=live_n, norms=norms.reshape(-1),
    )


def _sparse_stages(shape, dtype, k_row, axes, value_dtype,
                   constrain=lambda x: x, topk=_row_topk,
                   densify=None, wire: str = "unpacked",
                   quant: Optional[int] = None, qkey=None):
    """Stage chain for one flat sparse leaf/bucket, decomposed for the
    bucket pipeline (repro.core.pipeline):

      E (compute): top-k select + own densify + wire encode
      Q (quant):   OPTIONAL (``quant=s``) — stochastic s-level
                   quantization of the selected values (worker-unique
                   key: ``qkey`` folded over every gather axis). The own
                   densify moves here and uses the DEQUANTIZED values.
      G (comm):    all-gather over the data axes
      D (compute): wire decode + densify + mean

    Returns ``(stages, kinds, nbytes)``; stage 0 takes ``u`` (..., C)
    and the final stage returns ``(mean update, own selection)``. Run
    back to back, the no-quant stages compute EXACTLY the op sequence
    the old monolithic ``_leaf_sparse_sync`` emitted."""
    densify = densify or _row_scatter
    rows = 1
    for s in shape[:-1]:
        rows *= s
    W = _axis_size(axes)
    if wire == "packed":
        from repro.core import encoding as enc

        wspec = enc.WireSpec(rows=rows, cols=shape[-1], k=k_row,
                             value_dtype=jnp.dtype(value_dtype).name,
                             quant=quant)
        nbytes = wspec.nbytes
    else:
        wspec = None
        nbytes = rows * k_row * (jnp.dtype(value_dtype).itemsize + 4)

    def select_encode(u):
        vals, idx = topk(u, k_row, constrain)
        own = densify(shape, vals, idx, dtype, constrain)
        if wspec is not None:
            payload = _encode_packed(vals.astype(value_dtype), idx, wspec)
        else:
            payload = (vals.astype(value_dtype), idx)
        return own, payload

    def select(u):
        return topk(u, k_row, constrain)

    def quantize_encode(st):
        vals, idx = st
        key = _fold_axes(jax.random.fold_in(qkey, 1), axes)
        norms, codes, deq = _quantize_selected(vals, idx, quant, key)
        own = densify(shape, deq, idx, dtype, constrain)
        if wspec is not None:
            payload = _encode_quant(wspec, codes, idx, norms)
        else:
            payload = (deq.astype(value_dtype), idx)
        return own, payload

    def gather(st):
        own, payload = st
        if wspec is not None:
            return own, _gather_buf(payload, axes)
        return own, _gather_pairs(*payload, axes)

    def decode_apply(st):
        own, payload = st
        if wspec is not None:
            gv, gi = _decode_packed(payload, wspec, axes, shape[:-1])
        else:
            gv, gi = payload
        gv, gi = constrain(gv), constrain(gi)
        update = (densify(shape, gv, gi, value_dtype, constrain)
                  / W).astype(dtype)
        return update, own

    if quant is not None:
        if qkey is None:
            raise ValueError(
                "quantized sparse stages need a qkey (threaded PRNG key)")
        return ([select, quantize_encode, gather, decode_apply],
                (COMPUTE, QUANT, COMM, COMPUTE), nbytes)
    return ([select_encode, gather, decode_apply],
            (COMPUTE, COMM, COMPUTE), nbytes)


def _leaf_sparse_sync(u: Array, k_row: int, axes, value_dtype,
                      constrain=lambda x: x, topk=_row_topk,
                      densify=None, wire: str = "unpacked"):
    """u: (..., C). Returns (mean update, own selection, bytes/worker)."""
    stages, _, nbytes = _sparse_stages(
        u.shape, u.dtype, k_row, axes, value_dtype, constrain, topk,
        densify, wire,
    )
    update, own = _run_stages(u, stages)
    return update, own, nbytes


def _leaf_hierarchical_sync(u, k_row, k_pod, data_axes, pod_axis, value_dtype,
                            constrain=lambda x: x, topk=_row_topk,
                            densify=None, wire: str = "unpacked",
                            k_pod_live=None):
    """Two-level scheme: worker selections gather intra-pod at ``k_row``,
    the intra-pod mean is re-selected at ``k_pod`` and only that summary
    crosses the pod boundary; the pod-level residual is returned for the
    caller to fold into error-feedback memory (mass conservation:
    mean_w(u) == update + mean_w(new_memory) holds exactly up to
    float-sum association). Both gather stages go over the packed wire
    when ``wire="packed"``. Returns
    (update, own, residual, (intra_pod_bytes, cross_pod_bytes)).

    ``k_pod_live`` (traced scalar) switches the pod stage to RUNTIME k:
    ``k_pod`` then acts as the static padded ceiling — selection runs at
    ``k_pod`` and the tail slots past ``k_pod_live`` are masked to
    (-0.0, 0) no-ops (``kernels.topk_select.mask_live_k``), so the live k
    can move between steps without changing any shape, and mass
    conservation holds for every live k (pod_sel + residual == pod_mean
    exactly, whatever the selection kept). The reported cross-pod bytes
    are the PADDED gather size — the in-jit cost; a header-aware
    transport ships ``message_nbytes(..., live_k)`` instead."""
    stages, _, level_bytes = _hier_stages(
        u.shape, u.dtype, k_row, k_pod, data_axes, pod_axis, value_dtype,
        constrain, topk, densify, wire, k_pod_live,
    )
    update, own, residual = _run_stages(u, stages)
    return update, own, residual, level_bytes


def _hier_stages(shape, dtype, k_row, k_pod, data_axes, pod_axis,
                 value_dtype, constrain=lambda x: x, topk=_row_topk,
                 densify=None, wire: str = "unpacked", k_pod_live=None,
                 repack_boundary: bool = False,
                 quant: Optional[int] = None, qkey=None):
    """Stage chain for one two-level (hierarchical) leaf/bucket,
    decomposed for the bucket pipeline:

      E1 (compute): worker top-k + own densify + level-1 encode
      Q1 (quant):   OPTIONAL (``quant=s``) — stochastic quantization of
                    the worker selection (worker-unique key); the own
                    densify moves here and uses the DEQUANTIZED values
      G1 (comm):    intra-pod all-gather over the data axes
      M  (compute): level-1 decode + pod mean + pod re-select (live-k
                    mask) + residual + level-2 encode
      Q2 (quant):   OPTIONAL — quantization of the pod summary with a
                    key folded over the POD axis only, so every worker
                    in a pod draws identical codes for the shared pod
                    mean (the residual kept in memory must equal
                    pod_mean - dequantized summary on every worker);
                    the residual computation moves here
      R  (repack):  OPTIONAL (``repack_boundary``) — the header-aware
                    transport's compaction point, right before the slow
                    link. In-jit an identity (static shapes cannot
                    shrink inside a trace; bitwise-invariant by
                    construction); the host transport's R stage does
                    the real ``encoding.repack`` byte shrink.
      G2 (comm):    cross-pod all-gather
      D  (compute): level-2 decode + densify + pod mean

    Returns ``(stages, kinds, level_bytes)``; stage 0 takes ``u`` and
    the final stage returns ``(update, own, residual)``. Without quant
    the op sequence is exactly the old monolithic
    ``_leaf_hierarchical_sync`` body."""
    from repro.core import encoding as enc

    densify = densify or _row_scatter
    rows = 1
    for s in shape[:-1]:
        rows *= s
    cols = shape[-1]
    n_data = _axis_size(data_axes)
    n_pods = compat.axis_size(pod_axis)
    name = jnp.dtype(value_dtype).name
    if wire == "packed":
        w1 = enc.WireSpec(rows=rows, cols=cols, k=k_row, value_dtype=name,
                          quant=quant)
        w2 = enc.WireSpec(rows=rows, cols=cols, k=k_pod, value_dtype=name,
                          quant=quant)
    else:
        w1 = w2 = None
    level_bytes = (
        enc.message_nbytes(rows, cols, k_row, name, wire, quant=quant),
        enc.message_nbytes(rows, cols, k_pod, name, wire, quant=quant),
    )

    def l1_select_encode(u):
        vals, idx = topk(u, k_row, constrain)
        own = densify(shape, vals, idx, dtype, constrain)
        if w1 is not None:
            payload = _encode_packed(vals.astype(value_dtype), idx, w1)
        else:
            payload = (vals.astype(value_dtype), idx)
        return own, payload

    def l1_select(u):
        return topk(u, k_row, constrain)

    def l1_quantize_encode(st):
        vals, idx = st
        key = _fold_axes(jax.random.fold_in(qkey, 1),
                         tuple(data_axes) + (pod_axis,))
        norms, codes, deq = _quantize_selected(vals, idx, quant, key)
        own = densify(shape, deq, idx, dtype, constrain)
        if w1 is not None:
            payload = _encode_quant(w1, codes, idx, norms)
        else:
            payload = (deq.astype(value_dtype), idx)
        return own, payload

    def l1_gather(st):
        own, payload = st
        if w1 is not None:
            return own, _gather_buf(payload, data_axes)
        return own, _gather_pairs(*payload, data_axes)

    def _pod_mean_select(payload):
        if w1 is not None:
            gv, gi = _decode_packed(payload, w1, data_axes, shape[:-1])
        else:
            gv, gi = payload
        pod_mean = densify(shape, gv, gi, value_dtype, constrain) / n_data
        pvals, pidx = topk(pod_mean, k_pod, constrain)
        if k_pod_live is not None:
            from repro.kernels.topk_select import mask_live_k

            pvals, pidx = mask_live_k(pvals, pidx, k_pod_live)
            pvals, pidx = constrain(pvals), constrain(pidx)
        return pod_mean, pvals, pidx

    def pod_reselect_encode(st):
        own, payload = st
        pod_mean, pvals, pidx = _pod_mean_select(payload)
        pod_sel = densify(shape, pvals, pidx, value_dtype, constrain)
        # kept in memory (identical pod-wide)
        residual = pod_mean - pod_sel
        if w2 is not None:
            payload2 = _encode_packed(pvals, pidx, w2, live_n=k_pod_live)
        else:
            payload2 = (pvals, pidx)
        return own, residual, payload2

    def pod_reselect(st):
        own, payload = st
        pod_mean, pvals, pidx = _pod_mean_select(payload)
        return own, pod_mean, pvals, pidx

    def pod_quantize_encode(st):
        own, pod_mean, pvals, pidx = st
        # pod-axis-only fold: identical codes on every worker of a pod
        key = _fold_axes(jax.random.fold_in(qkey, 2), (pod_axis,))
        norms, codes, deq = _quantize_selected(pvals, pidx, quant, key)
        pod_sel = densify(shape, deq, pidx, value_dtype, constrain)
        # memory absorbs selection AND quantization error of the summary
        residual = pod_mean - pod_sel
        if w2 is not None:
            payload2 = _encode_quant(w2, codes, pidx, norms,
                                     live_n=k_pod_live)
        else:
            payload2 = (deq.astype(value_dtype), pidx)
        return own, residual, payload2

    def repack_boundary_stage(st):
        # in-jit identity: the traced buffer keeps its static padded
        # layout (invariant 10's bitwise guarantee is untouched); the
        # host executor substitutes the real ``encoding.repack`` here
        return st

    def l2_gather(st):
        own, residual, payload2 = st
        if w2 is not None:
            return own, residual, _gather_buf(payload2, (pod_axis,))
        return own, residual, _gather_pairs(*payload2, (pod_axis,))

    def l2_decode_apply(st):
        own, residual, payload2 = st
        if w2 is not None:
            av, ai = _decode_packed(payload2, w2, (pod_axis,), shape[:-1])
        else:
            av, ai = payload2
        update = (densify(shape, av, ai, value_dtype, constrain)
                  / n_pods).astype(dtype)
        return update, own, residual.astype(dtype)

    if quant is not None:
        if qkey is None:
            raise ValueError(
                "quantized hierarchical stages need a qkey (threaded "
                "PRNG key)")
        stages = [l1_select, l1_quantize_encode, l1_gather, pod_reselect,
                  pod_quantize_encode]
        kinds = [COMPUTE, QUANT, COMM, COMPUTE, QUANT]
    else:
        stages = [l1_select_encode, l1_gather, pod_reselect_encode]
        kinds = [COMPUTE, COMM, COMPUTE]
    if repack_boundary:
        stages.append(repack_boundary_stage)
        kinds.append(REPACK)
    stages += [l2_gather, l2_decode_apply]
    kinds += [COMM, COMPUTE]
    return stages, tuple(kinds), level_bytes


def _dense_stages(shape, dtype, axes):
    """Single-stage chain for a dense leaf/bucket: one all-reduce (the
    pipeline treats it as pure comm, free to overlap with sparse
    buckets' compute). Final state is ``(update, own)``; bytes are the
    buffer size."""
    nbytes = 1
    for s in shape:
        nbytes *= s
    nbytes *= jnp.dtype(dtype).itemsize

    def allreduce(u):
        update = jax.lax.pmean(u, axes if len(axes) > 1 else axes[0])
        return update, u

    return [allreduce], (COMM,), nbytes


def _leaf_dense_sync(u: Array, axes):
    update = jax.lax.pmean(u, axes if len(axes) > 1 else axes[0])
    return update, u, u.size * u.dtype.itemsize


def sparse_sync_gradients(
    cfg: SyncConfig,
    memory_tree,
    grad_tree,
    eta: Array,
    col_axes=None,
    specs=None,
    mesh=None,
):
    """Full PARALLEL-MEM-SGD gradient exchange on a pytree.

    Must be called inside a shard_map manual over cfg.data_axes (+ pod
    axis). ``memory_tree`` leaves match ``grad_tree`` shapes (this worker's
    own memory). ``col_axes``: pytree of ints (or None -> last axis),
    choosing the NON-model-sharded axis used as the row-block column; from
    ``repro.launch.sharding.sync_col_axes``.

    Returns (update_tree [SUBTRACT from params], new_memory_tree,
    bytes_per_worker_per_step [python int]).
    """
    cfg.validate()
    value_dtype = jnp.dtype(cfg.value_dtype)
    all_axes = tuple(cfg.data_axes) + (
        (cfg.pod_axis,) if cfg.pod_axis else ()
    )

    def build_leaf(m, g, col_axis, spec):
        """One leaf's pipeline entry: (init, stages, kinds, finish,
        nbytes). ``init`` is the row-layout u; ``finish`` undoes the
        layout and folds the error-feedback memory."""
        u_full = m + eta * g.astype(m.dtype)
        d = u_full.size
        if cfg.strategy == "dense" or d < cfg.dense_below:
            stages, kinds, nbytes = _dense_stages(
                u_full.shape, u_full.dtype, all_axes)

            def finish(st, u_full=u_full):
                upd, own = st
                return upd, u_full - own

            return u_full, stages, kinds, finish, nbytes
        ca = (col_axis if col_axis is not None else u_full.ndim - 1) % u_full.ndim
        if cfg.layout == "flatten":
            u, moved_shape = _to_rows(u_full, ca)
            unrow = lambda x: _from_rows(x, moved_shape, ca)
            constrain = lambda x: x
        else:  # "batched": moveaxis only — every op preserves sharding
            u = jnp.moveaxis(u_full, ca, -1)
            unrow = lambda x: jnp.moveaxis(x, -1, ca)
            if spec is not None and mesh is not None and cfg.constrain_intermediates:
                # pin every (..., C)- and (..., k)-shaped intermediate to
                # the parameter's own (permuted) sharding so GSPMD never
                # falls back to replicating full tensors around the top-k
                # and scatter ops (§Perf iteration A2).
                dims = list(spec)
                dims.append(dims.pop(ca))  # moveaxis(ca, -1)
                from jax.sharding import NamedSharding, PartitionSpec

                full_s = NamedSharding(mesh, PartitionSpec(*dims))
                rows_s = NamedSharding(
                    mesh, PartitionSpec(*dims[:-1], None))

                def constrain(x):
                    s = full_s if x.shape == u.shape else rows_s
                    return jax.lax.with_sharding_constraint(x, s)

                u = constrain(u)
            else:
                constrain = lambda x: x
        C = u.shape[-1]
        topk, densify = _pick_selection(cfg, cfg.k_for(C))
        if cfg.strategy == "hierarchical" and cfg.pod_axis is not None:
            stages, kinds, level_bytes = _hier_stages(
                u.shape, u.dtype, cfg.k_for(C), cfg.pod_k_for(C),
                tuple(cfg.data_axes), cfg.pod_axis, value_dtype,
                constrain, topk, densify, wire=cfg.wire,
                repack_boundary=cfg.repack,
            )
            nbytes = sum(level_bytes)

            def finish(st, u=u, unrow=unrow):
                upd, own, residual = st
                return unrow(upd), unrow((u - own) + residual)
        elif cfg.strategy in ("sparse_allgather", "hierarchical"):
            stages, kinds, nbytes = _sparse_stages(
                u.shape, u.dtype, cfg.k_for(C), all_axes, value_dtype,
                constrain, topk, densify, wire=cfg.wire,
            )

            def finish(st, u=u, unrow=unrow):
                upd, own = st
                return unrow(upd), unrow(u - own)
        else:
            raise ValueError(f"unknown sync strategy {cfg.strategy!r}")
        return u, stages, kinds, finish, nbytes

    leaves_g, treedef = jax.tree.flatten(grad_tree)
    leaves_m = treedef.flatten_up_to(memory_tree)
    if col_axes is None:
        leaves_c = [None] * len(leaves_g)
    else:
        leaves_c = treedef.flatten_up_to(col_axes)
    if specs is None:
        leaves_s = [None] * len(leaves_g)
    else:
        leaves_s = treedef.flatten_up_to(specs)
    inits, stage_lists, kind_lists, finishes = [], [], [], []
    total_bytes = 0
    for m, g, c, sp in zip(leaves_m, leaves_g, leaves_c, leaves_s):
        init, stages, kinds, fin, nbytes = build_leaf(m, g, c, sp)
        inits.append(init)
        stage_lists.append(stages)
        kind_lists.append(kinds)
        finishes.append(fin)
        total_bytes += int(nbytes)
    outs = pipeline.run_schedule(
        inits, stage_lists, kind_lists, cfg.overlap_depth()
    )
    ups, mems = [], []
    for st, fin in zip(outs, finishes):
        upd, new_m = fin(st)
        ups.append(upd)
        mems.append(new_m)
    return treedef.unflatten(ups), treedef.unflatten(mems), total_bytes


def bucketed_sync_gradients(
    cfg: SyncConfig,
    plan,
    memory_bufs,
    grad_tree,
    eta: Array,
    return_bufs: bool = False,
    pod_ks=None,
    grad_bufs=None,
    quant_key=None,
):
    """PARALLEL-MEM-SGD gradient exchange over flat buckets.

    Same contract as ``sparse_sync_gradients`` but the pytree is packed
    into the plan's few big (rows, cols) buffers first (see
    ``repro.core.buckets``): per-worker memory lives in bucket space
    (``memory_bufs``: one f32 buffer per bucket) and the all-gather runs
    once per bucket instead of once per leaf — over the packed uint32
    wire buffers when ``cfg.wire == "packed"`` (bit-identical results,
    ~2x fewer bytes; this path has no model-axis sharding to disturb). Rows never cross leaves'
    dtype groups; note that packing reshapes away any model-axis sharding,
    so this path targets data-parallel (or small-model-axis) meshes — the
    per-leaf path remains the choice for heavily tensor-parallel params.

    Returns (update_tree [f32 leaves, SUBTRACT from params],
    new_memory_bufs, bytes_per_worker_per_step) — plus the update's
    bucket-space (rows, cols) buffers when ``return_bufs`` (consumed by
    the delta stream, which re-encodes them without re-packing the tree).

    With ``cfg.pod_dynamic`` the hierarchical pod stage runs at RUNTIME
    k: ``pod_ks`` (one int32 scalar per bucket, e.g. a traced (n_buckets,)
    array indexed here) carries each bucket's live pod k, clipped to
    [1, ``pod_k_max_for_bucket``]; every buffer/wire/all-gather keeps
    the static k_max shape, so the same jitted step serves any k
    schedule with zero recompiles.

    ``grad_bufs`` (one f32 (rows, cols) buffer per bucket) substitutes
    for ``grad_tree``'s packing — the Qsparse-local-SGD driver passes
    its H-step bucket-space accumulator here (with ``eta=1.0``: the
    per-step stepsizes were already folded in by
    ``buckets.accumulate_local``). ``quant_key`` (a traced PRNG key,
    step already folded in) is required when ``cfg.wire_cfg.quant`` is
    set; each bucket folds its index, each quantize stage its level tag
    and axis indices.
    """
    from repro.core import buckets as bk

    cfg.validate(plan)
    if cfg.pod_dynamic and pod_ks is None:
        raise ValueError(
            "PodConfig.dynamic needs pod_ks (one live pod k "
            "per bucket) — pass the traced schedule the train step "
            "threads through, or unset pod.dynamic for static pod "
            "ratios"
        )
    if cfg.quant is not None and quant_key is None:
        raise ValueError(
            "WireConfig.quant needs quant_key (a threaded PRNG key; fold "
            "the step count in before calling) — stochastic rounding "
            "must draw fresh noise every sync"
        )
    value_dtype = jnp.dtype(cfg.value_dtype)
    all_axes = tuple(cfg.data_axes) + (
        (cfg.pod_axis,) if cfg.pod_axis else ()
    )
    if grad_bufs is not None:
        g_bufs = [b.astype(jnp.float32) for b in grad_bufs]
    else:
        g_bufs = bk.pack(plan, grad_tree, dtype=jnp.float32)
    # Build every bucket's stage chain up front, then emit in the
    # planned (possibly double-buffered) order. The finish closures run
    # after the schedule: they only combine already-computed values
    # (u, own, residual), so they impose no ordering of their own.
    inits, stage_lists, kind_lists, finishes = [], [], [], []
    total_bytes = 0
    for b, (spec, m, g) in enumerate(zip(plan.buckets, memory_bufs, g_bufs)):
        u = m + eta * g
        bkey = (jax.random.fold_in(quant_key, b)
                if quant_key is not None else None)
        if cfg.strategy == "dense" or spec.kind == "dense":
            stages, kinds, nbytes = _dense_stages(u.shape, u.dtype, all_axes)

            def finish(st, u=u):
                upd, own = st
                return upd, u - own
        elif cfg.strategy == "hierarchical" and cfg.pod_axis is not None:
            # true two-level: worker->pod at k_row, pod mean re-selected
            # at this bucket's own pod k (autotuned via cfg.pod_ratios),
            # pod residual folded into the bucket-space memory
            k_row = cfg.k_for(spec.cols)
            topk, densify = _pick_selection(cfg, k_row)
            if cfg.pod_dynamic:
                # runtime k: shapes at the static k_max, live k masks
                # the tail (clipped so a bad schedule can never overflow
                # the padded wire layout)
                n_data = _axis_size(tuple(cfg.data_axes))
                k_pod = cfg.pod_k_max_for_bucket(b, spec.cols, n_data)
                k_live = jnp.clip(
                    jnp.asarray(pod_ks[b], jnp.int32), 1, k_pod
                )
            else:
                k_pod = cfg.pod_k_for_bucket(b, spec.cols)
                k_live = None
            stages, kinds, level_bytes = _hier_stages(
                u.shape, u.dtype, k_row, k_pod,
                tuple(cfg.data_axes), cfg.pod_axis, value_dtype,
                topk=topk, densify=densify, wire=cfg.wire,
                k_pod_live=k_live, repack_boundary=cfg.repack,
                quant=cfg.quant, qkey=bkey,
            )
            nbytes = sum(level_bytes)

            def finish(st, u=u):
                upd, own, residual = st
                return upd, (u - own) + residual
        elif cfg.strategy in ("sparse_allgather", "hierarchical"):
            k_row = cfg.k_for(spec.cols)
            topk, densify = _pick_selection(cfg, k_row)
            stages, kinds, nbytes = _sparse_stages(
                u.shape, u.dtype, k_row, all_axes, value_dtype,
                topk=topk, densify=densify, wire=cfg.wire,
                quant=cfg.quant, qkey=bkey,
            )

            def finish(st, u=u):
                upd, own = st
                return upd, u - own
        else:
            raise ValueError(f"unknown sync strategy {cfg.strategy!r}")
        inits.append(u)
        stage_lists.append(stages)
        kind_lists.append(kinds)
        finishes.append(finish)
        total_bytes += int(nbytes)
    outs = pipeline.run_schedule(
        inits, stage_lists, kind_lists, cfg.overlap_depth()
    )
    ups, mems = [], []
    for st, fin in zip(outs, finishes):
        upd, new_m = fin(st)
        ups.append(upd)
        mems.append(new_m)
    if return_bufs:
        return bk.unpack(plan, ups), tuple(mems), total_bytes, ups
    return bk.unpack(plan, ups), tuple(mems), total_bytes


def repack_transport(wspec, buf, link=None):
    """The host/pod-boundary half of the header-aware repack transport:
    compact a k-padded wire buffer to its live payload
    (``encoding.repack``, sized by the buffer's own header word), ship
    exactly THAT many bytes across the slow link, and re-expand to the
    padded layout the in-jit consumer expects (``encoding.repad`` —
    bitwise equal to the buffer that went in, so the transport is
    invisible to everything downstream).

    Returns ``(padded_buf_or_future, wire_nbytes)``. With ``link=None``
    the round trip runs inline (the accounting/selfcheck path); with a
    ``pipeline.EmulatedLink``-style object the small buffer rides
    ``link.transfer(small_buf, wire_nbytes)`` and the returned future
    repads on ``.result()`` — drop it into a ``run_host_pipeline`` comm
    stage and the planner overlaps the (live-k-sized) transfer exactly
    like any gather."""
    from repro.core import encoding as enc

    small_spec, small_buf = enc.repack(wspec, buf)
    nbytes = small_spec.nbytes
    if link is None:
        return enc.repad(wspec, small_spec, small_buf), nbytes
    fut = link.transfer(small_buf, nbytes)

    class _Repad:
        def result(self):
            return enc.repad(wspec, small_spec, fut.result())

    return _Repad(), nbytes


def _sparse_leaf_bytes(cfg: SyncConfig, rows: int, cols: int,
                       pod_k: Optional[int] = None) -> int:
    """Exact per-worker bytes one sparse leaf/bucket puts on the wire:
    the packed ``WireSpec`` buffer size (header + bit-packed sections) or
    the raw (value_dtype, int32) pair arrays, per gather stage."""
    from repro.core import encoding as enc

    ks = [cfg.k_for(cols)]
    if cfg.strategy == "hierarchical" and cfg.pod_axis is not None:
        ks.append(pod_k if pod_k is not None else cfg.pod_k_for(cols))
    name = jnp.dtype(cfg.value_dtype).name
    return sum(
        enc.message_nbytes(rows, cols, k, name, cfg.wire, quant=cfg.quant)
        for k in ks
    )


def autotune_pod_ratios(cfg: SyncConfig, plan, u_bufs, n_data: int,
                        mass_target: Optional[float] = None,
                        k_caps: Optional[Sequence[int]] = None) -> tuple:
    """Per-bucket pod re-compression ratios from realized mass capture.

    The pod-stage selection sees the intra-pod mean, whose per-row
    support is bounded by ``n_data * k_row`` — shipping more slots than
    that is pure waste, and shipping the same k for every bucket wastes
    slots on buckets whose mass concentrates early. For each sparse
    bucket this picks the smallest k whose top-k captures
    ``cfg.pod_mass_target`` of the mass the pod stage can see at all,
    clamped to [k_min, support bound], and returns ratio = k / cols.
    Normalizing within the visible support (not the full row) is what
    makes the target meaningful per bucket: a heavy-tailed bucket
    reaches it in a handful of slots, a flat one keeps most of the
    bound.

    ``u_bufs`` leaves are concrete bucket buffers of u = m + eta*g:

    * ``(n_shards, rows, cols)`` — per-data-shard buffers. The pod
      stage is SIMULATED exactly: per-shard top-``k_row`` selection,
      densify, mean — the mass-capture curve is measured on the
      realized pod-mean proxy, so overlapping worker selections (highly
      correlated shard gradients) concentrate mass and shrink k.
    * ``(rows, cols)`` — a single global buffer; its top-``support``
      tail curve is the (more conservative) proxy.

    Host-side calibration: call once on concrete buffers and bake the
    result into ``SyncConfig.pod_ratios`` before building the jitted
    step (static wire layouts) — or, with ``cfg.pod_dynamic``, call it
    again MID-RUN on the live memory+gradient buffers and feed the new
    ks straight into the running step (the k-padded wire needs no
    re-jit). ``k_caps`` clamps each bucket's k to the static padded
    ceiling (``pod_k_max_for_bucket``) so a refresh can never outgrow
    the compiled buffers. Dense buckets get ratio 1.0 (never
    consulted).

    This is the mass-target mode of ``core.budget.BudgetController``
    (one measurement + allocator serves both this target sizing and the
    global ``SyncConfig.byte_budget`` water-filling); it delegates
    there so the two entry points can never drift apart."""
    from repro.core.budget import BudgetController

    ctl = BudgetController(cfg, plan, n_data, k_caps=k_caps)
    ks = ctl.allocate_mass_target(ctl.measure(u_bufs), mass_target)
    return ctl.ratios_of(ks)


def bucketed_message_bytes(cfg: SyncConfig, plan, *, by_level: bool = False,
                           n_data: Optional[int] = None,
                           pod_ks: Optional[Sequence[int]] = None):
    """Per-worker per-step transmitted bytes for a BucketPlan — the exact
    size of the buffers the sync all-gathers (index cost is the bucket's
    row-local ceil(log2 cols) bits when ``cfg.wire == "packed"``).

    With ``by_level=True`` returns ``{"intra", "cross", "total"}`` —
    the per-worker bytes that stay inside a pod vs cross the pod
    boundary on a ``(pod, data)`` mesh:

    * hierarchical: level 1 (k_row pairs, data-axis gather) is intra;
      only the re-compressed level-2 summary (this bucket's pod k)
      crosses pods.
    * flat strategies: the data-axis gather is intra, but the pod-axis
      gather then ships the CONCATENATED data-axis buffer — every
      worker lane re-transmits ``n_data`` messages across the boundary
      (pass ``n_data``; this is the fan-in the two-level scheme wins
      back).
    * dense buckets/strategy: the all-reduce moves ~buffer-size bytes
      at each level.

    ``total`` keeps the historical meaning (sum of the per-stage
    messages this worker emits) and equals the no-argument return.

    Runtime-k accounting: with ``cfg.pod_dynamic`` the level-2 message
    is k-PADDED — pass ``n_data`` and the default counts the padded
    gather buffer (``pod_k_max_for_bucket``), which is what the jitted
    step realizes. Pass ``pod_ks`` (the live per-bucket ks) to count
    the EFFECTIVE bytes instead: what a header-aware transport that
    re-packs to the live count (``encoding.LIVE_N_WORD``) would ship.
    """
    from repro.core import encoding as enc

    cfg.validate(plan)
    if by_level and cfg.pod_axis is not None and n_data is None and (
        cfg.strategy not in ("hierarchical", "dense")
    ):
        raise ValueError(
            "by_level accounting for a flat strategy on a pod mesh needs "
            "n_data (the concatenated data-axis buffer is what crosses "
            "the pod boundary)"
        )
    if (cfg.pod_dynamic and cfg.strategy == "hierarchical"
            and cfg.pod_axis is not None
            and pod_ks is None and n_data is None):
        raise ValueError(
            "pod-dynamic accounting needs n_data (the padded gather is "
            "shaped at the n_data-dependent k_max) or pod_ks (the live "
            "per-bucket ks, for effective-transport bytes)"
        )
    name = jnp.dtype(cfg.value_dtype).name
    intra = cross = total = 0
    pod = cfg.pod_axis is not None
    for b, spec in enumerate(plan.buckets):
        if cfg.strategy == "dense" or spec.kind == "dense":
            nb = spec.rows * spec.cols * 4
            total += nb
            intra += nb
            cross += nb if pod else 0
        elif cfg.strategy == "hierarchical" and pod:
            if pod_ks is not None:
                k2 = max(1, min(int(pod_ks[b]), spec.cols))
            elif cfg.pod_dynamic:
                k2 = cfg.pod_k_max_for_bucket(b, spec.cols, n_data)
            else:
                k2 = cfg.pod_k_for_bucket(b, spec.cols)
            lvl1 = enc.message_nbytes(
                spec.rows, spec.cols, cfg.k_for(spec.cols), name, cfg.wire,
                quant=cfg.quant)
            lvl2 = enc.message_nbytes(
                spec.rows, spec.cols, k2, name, cfg.wire, quant=cfg.quant)
            total += lvl1 + lvl2
            intra += lvl1
            cross += lvl2
        else:
            msg = _sparse_leaf_bytes(cfg, spec.rows, spec.cols)
            total += msg
            intra += msg
            if pod and n_data is not None:
                cross += n_data * msg
    if by_level:
        return {"intra": intra, "cross": cross, "total": total}
    return total


def amortized_bytes_per_step(cfg: SyncConfig, plan, *, by_level: bool = False,
                             n_data: Optional[int] = None,
                             pod_ks: Optional[Sequence[int]] = None):
    """Cross-worker bytes per OPTIMIZER step under Qsparse-local-SGD:
    with ``cfg.local_steps = H`` the workers communicate once every H
    steps, so the per-step cost is ``bucketed_message_bytes / H`` — the
    ~1/H scaling the local bench asserts. Same ``by_level`` contract,
    values as floats."""
    b = bucketed_message_bytes(cfg, plan, by_level=by_level, n_data=n_data,
                               pod_ks=pod_ks)
    H = max(1, cfg.local_steps)
    if isinstance(b, dict):
        return {k: v / H for k, v in b.items()}
    return b / H


def message_bytes(cfg: SyncConfig, params, col_axes=None) -> int:
    """Per-worker per-step transmitted bytes for a parameter pytree — the
    exact size of the gathered arrays (or packed wire buffers)."""
    cfg.validate()
    total = 0
    leaves, treedef = jax.tree.flatten(params)
    if col_axes is None:
        caxes = [None] * len(leaves)
    else:
        caxes = treedef.flatten_up_to(col_axes)
    for p, c in zip(leaves, caxes):
        d = p.size
        if cfg.strategy == "dense" or d < cfg.dense_below:
            total += d * 4
            continue
        ca = (c if c is not None else p.ndim - 1) % max(p.ndim, 1)
        C = p.shape[ca] if p.ndim else 1
        total += _sparse_leaf_bytes(cfg, d // max(C, 1), C)
    return total
