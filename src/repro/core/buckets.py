"""Bucketed flat-buffer engine: pytree -> a handful of big 2D buffers.

Models with hundreds of small leaves (Griffin/RWKV/MoE configs) pay one
compressor dispatch per leaf in the naive ``tree_memory_step`` path and
lose all tiling efficiency (a 768-element norm scale occupies a whole
Pallas grid launch). This module packs the gradient pytree into a few
large, dtype-homogeneous buffers:

* one SPARSE bucket per gradient dtype: every leaf with
  ``size >= dense_below`` plus all the small-but-compressible leaves,
  concatenated flat and viewed as (rows, cols). Per-row top-k over the
  bucket is exactly ``blockwise_top_k(k, cols)`` over the concatenated
  parameter vector — a k-contraction with k/d = k/cols (see
  ``repro.core.compression``), so Theorem 2.4 applies unchanged.
* one DENSE bucket per dtype holding the ``dense_below`` leaves (norm
  scales, biases): synced uncompressed, shaped (1, total).

The error-feedback memory then lives in BUCKET space (one f32 buffer per
bucket, not one per leaf): ``memsgd``'s per-step compression becomes <= ~4
fused kernel dispatches regardless of leaf count, and the distributed
all-gather exchanges <= ~4 (values, indices) pair sets.

Padding tail entries are identically zero in every gradient, start at zero
memory, and so stay zero in u = m + eta*g forever: they are never selected
ahead of a real entry (ties break to the LOWEST index and padding sits at
the highest indices of the last row), contribute nothing when they are
selected into an all-zero tail row, and are sliced off by ``unpack``.

A ``BucketPlan`` is pure static metadata (shapes/dtypes/offsets): building
one from tracers inside jit is free and deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

DEFAULT_BUCKET_COLS = 1024
DEFAULT_DENSE_BELOW = 16_384


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Static description of one packed buffer."""

    dtype: str  # canonical jnp dtype name, e.g. "float32"
    kind: str  # "sparse" (row-block compressed) | "dense" (uncompressed)
    rows: int
    cols: int
    size: int  # sum of member leaf sizes (<= rows * cols)

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.rows, self.cols)


@dataclasses.dataclass(frozen=True)
class LeafPlacement:
    bucket: int  # index into BucketPlan.buckets
    offset: int  # flat offset within the bucket's (rows*cols,) space
    shape: Tuple[int, ...]
    dtype: str
    size: int


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    treedef: object
    placements: Tuple[LeafPlacement, ...]
    buckets: Tuple[BucketSpec, ...]

    @property
    def n_dispatch(self) -> int:
        """Compressor/sync dispatches per step (one per bucket)."""
        return len(self.buckets)


def make_plan(
    tree,
    *,
    cols: int = DEFAULT_BUCKET_COLS,
    dense_below: int = DEFAULT_DENSE_BELOW,
) -> BucketPlan:
    """Assign every leaf of ``tree`` (arrays or ShapeDtypeStructs) to a
    bucket. Grouping key: (dtype, dense|sparse); leaves keep their
    flatten order within a bucket."""
    leaves, treedef = jax.tree.flatten(tree)
    groups: dict = {}  # key -> [leaf indices]
    keys_in_order: list = []
    infos = []
    for i, leaf in enumerate(leaves):
        size = 1
        for s in leaf.shape:
            size *= s
        dtype = jnp.dtype(leaf.dtype).name
        kind = "dense" if size < dense_below else "sparse"
        infos.append((tuple(leaf.shape), dtype, size, kind))
        key = (dtype, kind)
        if key not in groups:
            groups[key] = []
            keys_in_order.append(key)
        groups[key].append(i)

    buckets: List[BucketSpec] = []
    placements: List[Optional[LeafPlacement]] = [None] * len(leaves)
    for b, key in enumerate(keys_in_order):
        dtype, kind = key
        offset = 0
        for i in groups[key]:
            shape, dt, size, _ = infos[i]
            placements[i] = LeafPlacement(
                bucket=b, offset=offset, shape=shape, dtype=dt, size=size
            )
            offset += size
        if kind == "sparse":
            rows = -(-offset // cols)
            buckets.append(BucketSpec(dtype, kind, rows, cols, offset))
        else:
            buckets.append(BucketSpec(dtype, kind, 1, offset, offset))
    return BucketPlan(
        treedef=treedef,
        placements=tuple(placements),
        buckets=tuple(buckets),
    )


def pack(plan: BucketPlan, tree, dtype=None) -> List[Array]:
    """Pytree -> one (rows, cols) buffer per bucket (zero-padded tail).

    ``dtype`` overrides the per-bucket dtype (e.g. f32 for memory math).
    """
    leaves = plan.treedef.flatten_up_to(tree)
    parts: List[List[Array]] = [[] for _ in plan.buckets]
    for leaf, pl_ in zip(leaves, plan.placements):
        parts[pl_.bucket].append(jnp.ravel(leaf))
    out = []
    for spec, chunks in zip(plan.buckets, parts):
        dt = jnp.dtype(dtype) if dtype is not None else jnp.dtype(spec.dtype)
        flat = jnp.concatenate([c.astype(dt) for c in chunks])
        pad = spec.rows * spec.cols - spec.size
        if pad:
            flat = jnp.pad(flat, (0, pad))
        out.append(flat.reshape(spec.rows, spec.cols))
    return out


def unpack(plan: BucketPlan, buffers: Sequence[Array], cast: bool = False):
    """Buffers -> pytree of leaf-shaped arrays (buffer dtype, or the
    original leaf dtype when ``cast``)."""
    flats = [jnp.ravel(b) for b in buffers]
    leaves = []
    for pl_ in plan.placements:
        piece = jax.lax.dynamic_slice_in_dim(
            flats[pl_.bucket], pl_.offset, pl_.size
        ).reshape(pl_.shape)
        if cast:
            piece = piece.astype(jnp.dtype(pl_.dtype))
        leaves.append(piece)
    return plan.treedef.unflatten(leaves)


def bucket_mass_capture(buf: Array, max_k: int) -> Array:
    """Mean per-row captured squared-mass fraction of a (rows, cols)
    buffer for every k in 1..max_k: ``out[k-1]`` is the fraction of each
    row's squared mass the k largest-|.| entries hold, averaged over
    rows (all-zero rows count as fully captured). Monotone
    non-decreasing in k and exactly 1.0 at k = cols.

    This is the "realized mass capture" the two-level sync autotunes its
    per-bucket pod re-compression ratio from: attention-sized buckets
    with heavy tails need a larger pod-level k than bias-sized buckets
    whose mass concentrates in a few coordinates (see
    ``repro.core.distributed.autotune_pod_ratios``)."""
    max_k = max(1, min(int(max_k), buf.shape[-1]))
    sq = jnp.square(jnp.abs(buf.astype(jnp.float32)))
    desc = -jnp.sort(-sq, axis=-1)[..., :max_k]
    captured = jnp.cumsum(desc, axis=-1)
    total = jnp.sum(sq, axis=-1, keepdims=True)
    frac = jnp.where(total > 0, captured / jnp.maximum(total, 1e-30), 1.0)
    return jnp.mean(frac, axis=0)


def simulate_pod_mean(u: Array, k_row: int) -> Array:
    """(n_shards, rows, cols) per-shard bucket buffers -> the realized
    intra-pod mean the pod-stage selection sees: per-shard top-``k_row``
    by |.|, densify, mean over shards. Overlapping shard selections
    (correlated gradients) concentrate mass here, which is exactly why
    the autotuner and the refresh bench measure capture on this proxy
    instead of the raw buffers."""
    n, rows, _ = u.shape
    _, idx = jax.lax.top_k(jnp.abs(u.astype(jnp.float32)), k_row)
    idx = idx.astype(jnp.int32)
    vals = jnp.take_along_axis(u.astype(jnp.float32), idx, axis=-1)
    sel = jnp.zeros(u.shape, jnp.float32)
    ni = jnp.arange(n, dtype=jnp.int32)[:, None, None]
    ri = jnp.arange(rows, dtype=jnp.int32)[None, :, None]
    sel = sel.at[ni, ri, idx].add(vals)
    return jnp.mean(sel, axis=0)


def support_relative_capture(buf: Array, support: int):
    """Mean per-row capture curve of a (rows, cols) buffer NORMALIZED
    within the visible ``support`` (numpy array, length ``support``):
    ``out[k-1]`` is the fraction of the mass the pod stage can see at
    all that the k largest-|.| entries hold. Normalizing within the
    support (not the full row) is what makes a mass target meaningful
    per bucket — see ``distributed.autotune_pod_ratios``."""
    import numpy as np

    frac = np.asarray(bucket_mass_capture(buf, support))
    return frac / max(float(frac[-1]), 1e-30)


def init_bucket_memory(plan: BucketPlan, dtype=jnp.float32) -> Tuple[Array, ...]:
    """Zero error-feedback memory, one buffer per bucket (m_0 = 0)."""
    return tuple(
        jnp.zeros(spec.shape, dtype=dtype) for spec in plan.buckets
    )


def init_local_accum(plan: BucketPlan, dtype=jnp.float32) -> Tuple[Array, ...]:
    """Zero local-step accumulator, one buffer per bucket.

    Qsparse-local-SGD (``SyncConfig(local_steps=H)``): between syncs each
    worker folds its per-step scaled gradients into this bucket-space
    accumulator, acc = sum_h eta_h * g_h; the sync round then compresses
    u = m + acc and resets acc to zero. Same shapes/dtype as the
    error-feedback memory, so it shares the memory's sharding."""
    return tuple(
        jnp.zeros(spec.shape, dtype=dtype) for spec in plan.buckets
    )


def accumulate_local(
    plan: BucketPlan, acc_bufs: Sequence[Array], grad_tree, eta
) -> Tuple[Array, ...]:
    """One uncommunicated local step: acc += eta * pack(g) per bucket."""
    g_bufs = pack(plan, grad_tree, dtype=jnp.float32)
    e = jnp.asarray(eta, jnp.float32)
    return tuple(a + e * g for a, g in zip(acc_bufs, g_bufs))


def bucket_memory_step(
    plan: BucketPlan,
    memory_bufs: Sequence[Array],
    grad_tree,
    eta,
    k_for: Callable[[int], int],
    *,
    method: str = "auto",
    interpret: Optional[bool] = None,
):
    """One Mem-SGD error-feedback step over the buckets.

    For each sparse bucket runs the FUSED Pallas update
    (u = m + eta*g -> per-row top-k -> residual memory) in a single
    dispatch; dense buckets pass through uncompressed with zero residual.

    Returns (applied_tree [dense comp_k(u), f32 leaves],
    new_memory_bufs, n_dispatch).
    """
    from repro.kernels import densify_rows_ref, fused_memsgd_update

    g_bufs = pack(plan, grad_tree, dtype=jnp.float32)
    applied_bufs, new_mem = [], []
    for spec, m, g in zip(plan.buckets, memory_bufs, g_bufs):
        if spec.kind == "dense":
            u = m + jnp.asarray(eta, m.dtype) * g
            applied_bufs.append(u)
            new_mem.append(jnp.zeros_like(u))
            continue
        k = k_for(spec.cols)
        nm, vals, idx = fused_memsgd_update(
            m, g, eta, k, method=method, interpret=interpret
        )
        applied_bufs.append(densify_rows_ref(m, vals, idx))
        new_mem.append(nm)
    return (
        unpack(plan, applied_bufs),
        tuple(new_mem),
        plan.n_dispatch,
    )
