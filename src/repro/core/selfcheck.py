"""Shared diagnostic harness for the two-level pod sync invariants.

One synthetic probe used by BOTH the `hierarchy` bench subprocess and
the slow property test (`tests/test_hierarchical_bucketed.py`) — the
invariant definitions live here once instead of in two embedded script
string literals. Runs the two-level bucketed sync on a tiny 2-bucket
tree over a real ``(pod, data)`` mesh, once per wire format, and
reports everything the scheme guarantees:

* **conservation_max_err** — exact two-level mass conservation:
  ``mean_w(u) == update + mean_w(new_memory)`` (both residual levels
  fold back into bucket memory; float-sum association is the only
  slack).
* **bit_identical** — packed and unpacked wires produce bitwise equal
  updates AND memories.
* **accounting_exact** — the bytes the sync realizes equal the static
  ``bucketed_message_bytes`` prediction, per wire.

Must run under enough host devices for the mesh (see the subprocess
pattern in tests/test_distributed.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import buckets as bk
from repro.core.distributed import (
    SyncConfig,
    bucketed_message_bytes,
    bucketed_sync_gradients,
)
from repro.utils.compat import shard_map


def two_level_selfcheck(mesh, ratio: float = 0.05, pod_ratio: float = 0.1,
                        eta: float = 0.3) -> dict:
    """Probe the two-level sync invariants on ``mesh`` (must have axes
    ``("pod", "data")``). Returns a dict of the three invariant
    measurements plus the per-wire byte accounting."""
    W = int(np.prod([mesh.shape[a] for a in ("pod", "data")]))
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 384)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (40,))}
    plan = bk.make_plan(tree, cols=128, dense_below=64)
    gs = jax.tree.map(lambda x: jnp.stack(
        [x * (1 + 0.1 * i) + 0.01 * i for i in range(W)]), tree)
    mem = tuple(
        jax.random.normal(jax.random.PRNGKey(9 + b), (W,) + s.shape)
        * (0.1 if s.kind == "sparse" else 0.0)
        for b, s in enumerate(plan.buckets))

    realized = {}

    def run(wire):
        cfg = SyncConfig(ratio=ratio, strategy="hierarchical",
                         data_axes=("data",), pod_axis="pod",
                         bucketed=True, bucket_cols=128, wire=wire,
                         pod_ratios=(1.0, pod_ratio))

        def sync(mem, g):
            upd, new_mem, nbytes = bucketed_sync_gradients(
                cfg, plan, jax.tree.map(lambda m: m[0], mem),
                jax.tree.map(lambda x: x[0], g), jnp.float32(eta))
            realized[wire] = nbytes  # static python int, trace-time
            return upd, jax.tree.map(lambda m: m[None], new_mem)

        wspec = jax.tree.map(lambda _: P(("pod", "data")), mem)
        gspec = jax.tree.map(lambda _: P(("pod", "data")), gs)
        upd, new_mem = shard_map(
            sync, mesh=mesh, in_specs=(wspec, gspec),
            out_specs=(jax.tree.map(lambda _: P(), tree), wspec))(mem, gs)
        return upd, new_mem, cfg

    upd_p, mem_p, cfg_p = run("packed")
    upd_u, mem_u, cfg_u = run("unpacked")
    bit = all(np.array_equal(np.asarray(a), np.asarray(b))
              for a, b in zip(jax.tree.leaves((upd_p, mem_p)),
                              jax.tree.leaves((upd_u, mem_u))))

    err = 0.0
    upd_bufs = bk.pack(plan, upd_p, dtype=jnp.float32)
    for b in range(len(plan.buckets)):
        u_w = jnp.stack([
            mem[b][w] + eta * bk.pack(
                plan, jax.tree.map(lambda x, w=w: x[w], gs),
                dtype=jnp.float32)[b]
            for w in range(W)])
        lhs = jnp.mean(u_w, axis=0)
        rhs = upd_bufs[b] + jnp.mean(mem_p[b], axis=0)
        err = max(err, float(jnp.max(jnp.abs(lhs - rhs))))

    acc = {w: bucketed_message_bytes(c, plan)
           for w, c in (("packed", cfg_p), ("unpacked", cfg_u))}
    return {
        "bit_identical": bool(bit),
        "conservation_max_err": err,
        "accounting_exact": realized == acc,
        "realized_bytes": realized,
        "accounted_bytes": acc,
    }
