"""Shared diagnostic harness for the two-level pod sync invariants.

Synthetic probes used by BOTH the bench subprocesses (`hierarchy`,
`refresh`) and the slow property tests — the invariant definitions live
here once instead of in embedded script string literals.
``two_level_selfcheck`` runs the two-level bucketed sync on a tiny
2-bucket tree over a real ``(pod, data)`` mesh, once per wire format,
and reports everything the scheme guarantees; ``dynamic_k_selfcheck``
does the same for the RUNTIME pod-k (k-padded wire) path:

* **conservation_max_err** — exact two-level mass conservation:
  ``mean_w(u) == update + mean_w(new_memory)`` (both residual levels
  fold back into bucket memory; float-sum association is the only
  slack).
* **bit_identical** — packed and unpacked wires produce bitwise equal
  updates AND memories.
* **accounting_exact** — the bytes the sync realizes equal the static
  ``bucketed_message_bytes`` prediction, per wire.

Must run under enough host devices for the mesh (see the subprocess
pattern in tests/test_distributed.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import buckets as bk
from repro.core.distributed import (
    SyncConfig,
    bucketed_message_bytes,
    bucketed_sync_gradients,
)
from repro.utils.compat import shard_map


def bitwise_equal(a, b) -> bool:
    """True iff the two pytrees have the same number of leaves and every
    leaf pair is BYTE-identical (uint8 view — float ``==`` would miss
    -0.0 vs +0.0 and NaN payloads). The one comparator every probe,
    bench script and test should share — a truncating ``zip`` over
    mismatched leaf lists silently passes."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(
        np.array_equal(np.asarray(x).view(np.uint8),
                       np.asarray(y).view(np.uint8))
        for x, y in zip(la, lb)
    )


def two_level_selfcheck(mesh, ratio: float = 0.05, pod_ratio: float = 0.1,
                        eta: float = 0.3) -> dict:
    """Probe the two-level sync invariants on ``mesh`` (must have axes
    ``("pod", "data")``). Returns a dict of the three invariant
    measurements plus the per-wire byte accounting."""
    W = int(np.prod([mesh.shape[a] for a in ("pod", "data")]))
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 384)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (40,))}
    plan = bk.make_plan(tree, cols=128, dense_below=64)
    gs = jax.tree.map(lambda x: jnp.stack(
        [x * (1 + 0.1 * i) + 0.01 * i for i in range(W)]), tree)
    mem = tuple(
        jax.random.normal(jax.random.PRNGKey(9 + b), (W,) + s.shape)
        * (0.1 if s.kind == "sparse" else 0.0)
        for b, s in enumerate(plan.buckets))

    realized = {}

    def run(wire):
        cfg = SyncConfig(ratio=ratio, strategy="hierarchical",
                         data_axes=("data",), pod_axis="pod",
                         bucketed=True, bucket_cols=128, wire=wire,
                         pod_ratios=(1.0, pod_ratio))

        def sync(mem, g):
            upd, new_mem, nbytes = bucketed_sync_gradients(
                cfg, plan, jax.tree.map(lambda m: m[0], mem),
                jax.tree.map(lambda x: x[0], g), jnp.float32(eta))
            realized[wire] = nbytes  # static python int, trace-time
            return upd, jax.tree.map(lambda m: m[None], new_mem)

        wspec = jax.tree.map(lambda _: P(("pod", "data")), mem)
        gspec = jax.tree.map(lambda _: P(("pod", "data")), gs)
        upd, new_mem = shard_map(
            sync, mesh=mesh, in_specs=(wspec, gspec),
            out_specs=(jax.tree.map(lambda _: P(), tree), wspec))(mem, gs)
        return upd, new_mem, cfg

    upd_p, mem_p, cfg_p = run("packed")
    upd_u, mem_u, cfg_u = run("unpacked")
    bit = all(np.array_equal(np.asarray(a), np.asarray(b))
              for a, b in zip(jax.tree.leaves((upd_p, mem_p)),
                              jax.tree.leaves((upd_u, mem_u))))

    err = 0.0
    upd_bufs = bk.pack(plan, upd_p, dtype=jnp.float32)
    for b in range(len(plan.buckets)):
        u_w = jnp.stack([
            mem[b][w] + eta * bk.pack(
                plan, jax.tree.map(lambda x, w=w: x[w], gs),
                dtype=jnp.float32)[b]
            for w in range(W)])
        lhs = jnp.mean(u_w, axis=0)
        rhs = upd_bufs[b] + jnp.mean(mem_p[b], axis=0)
        err = max(err, float(jnp.max(jnp.abs(lhs - rhs))))

    acc = {w: bucketed_message_bytes(c, plan)
           for w, c in (("packed", cfg_p), ("unpacked", cfg_u))}
    return {
        "bit_identical": bool(bit),
        "conservation_max_err": err,
        "accounting_exact": realized == acc,
        "realized_bytes": realized,
        "accounted_bytes": acc,
    }


def dynamic_k_selfcheck(mesh, ratio: float = 0.05, eta: float = 0.3,
                        ks=(9, 4)) -> dict:
    """Probe the RUNTIME pod-k (k-padded wire) invariants on ``mesh``
    (axes ``("pod", "data")``). Same tiny 2-bucket tree as
    ``two_level_selfcheck``. Reports:

    * **dynamic_matches_static** — for each wire format and each live k
      in ``ks``, the dynamic path fed that k as a runtime value is
      BITWISE identical to the static path compiled at that k, compared
      on the APPLIED update (params - update) and the new memory:
      padding the selection to k_max and masking the tail reproduces the
      static computation. (The raw update may differ in the SIGN of
      all-zero columns at k_live=1 — XLA's no-reduce special case — a
      transient ±0.0 that cancels at application; see
      ``kernels.topk_select.mask_live_k``.)
    * **conservation_max_err** — two-level mass conservation under a
      SWITCHED live k (the refresh-boundary invariant): for every live
      k, ``mean_w(u) == update + mean_w(new_memory)``.
    * **accounting_exact** — the realized gather bytes of the dynamic
      path equal the k_max-padded ``bucketed_message_bytes`` prediction
      (the padded buffer IS what the jitted step ships).
    """
    import dataclasses

    W = int(np.prod([mesh.shape[a] for a in ("pod", "data")]))
    n_data = int(mesh.shape["data"])
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 384)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (40,))}
    plan = bk.make_plan(tree, cols=128, dense_below=64)
    gs = jax.tree.map(lambda x: jnp.stack(
        [x * (1 + 0.1 * i) + 0.01 * i for i in range(W)]), tree)
    mem = tuple(
        jax.random.normal(jax.random.PRNGKey(9 + b), (W,) + s.shape)
        * (0.1 if s.kind == "sparse" else 0.0)
        for b, s in enumerate(plan.buckets))

    realized = {}

    def run(cfg, pod_ks=None, tag=None):
        def sync(mem_, g_):
            kw = {"pod_ks": pod_ks} if pod_ks is not None else {}
            upd, new_mem, nbytes = bucketed_sync_gradients(
                cfg, plan, jax.tree.map(lambda m: m[0], mem_),
                jax.tree.map(lambda x: x[0], g_), jnp.float32(eta), **kw)
            if tag is not None:
                realized[tag] = nbytes
            return upd, jax.tree.map(lambda m: m[None], new_mem)

        wspec = jax.tree.map(lambda _: P(("pod", "data")), mem)
        gspec = jax.tree.map(lambda _: P(("pod", "data")), gs)
        return shard_map(
            sync, mesh=mesh, in_specs=(wspec, gspec),
            out_specs=(jax.tree.map(lambda _: P(), tree), wspec))(mem, gs)

    matches = True
    cons_err = 0.0
    acc_ok = True
    for wire in ("packed", "unpacked"):
        dyn = SyncConfig(ratio=ratio, strategy="hierarchical",
                         data_axes=("data",), pod_axis="pod",
                         bucketed=True, bucket_cols=128, wire=wire,
                         pod_ratios=(1.0, ks[0] / 128), pod_dynamic=True)
        for k_live in ks:
            static = dataclasses.replace(
                dyn, pod_dynamic=False, pod_ratios=(1.0, k_live / 128))
            out_s = run(static)
            tag = f"{wire}@{k_live}"
            out_d = run(dyn, pod_ks=jnp.asarray([1, k_live], jnp.int32),
                        tag=tag)
            applied_s = jax.tree.map(lambda t, u: t - u, tree, out_s[0])
            applied_d = jax.tree.map(lambda t, u: t - u, tree, out_d[0])
            matches = matches and bitwise_equal((applied_s, out_s[1]),
                                                (applied_d, out_d[1]))
            acc_ok = acc_ok and realized[tag] == bucketed_message_bytes(
                dyn, plan, n_data=n_data)
            # conservation at this live k (the refresh-boundary invariant)
            upd_bufs = bk.pack(plan, out_d[0], dtype=jnp.float32)
            for b in range(len(plan.buckets)):
                u_w = jnp.stack([
                    mem[b][w] + eta * bk.pack(
                        plan, jax.tree.map(lambda x, w=w: x[w], gs),
                        dtype=jnp.float32)[b]
                    for w in range(W)])
                lhs = jnp.mean(u_w, axis=0)
                rhs = upd_bufs[b] + jnp.mean(out_d[1][b], axis=0)
                cons_err = max(cons_err,
                               float(jnp.max(jnp.abs(lhs - rhs))))
    return {
        "dynamic_matches_static": bool(matches),
        "conservation_max_err": cons_err,
        "accounting_exact": bool(acc_ok),
        "live_ks": list(ks),
    }


def overlap_selfcheck(mesh, ratio: float = 0.05, eta: float = 0.3,
                      wire: str = "packed") -> dict:
    """Probe the pipelined-schedule bit-identity guarantee on ``mesh``
    (axes ``("pod", "data")``): for each sync path — flat
    ``sparse_allgather``, static two-level ``hierarchical``, and the
    runtime-k ``pod_dynamic`` path INCLUDING a mid-run live-k switch —
    ``SyncConfig.overlap`` in {None, False, True} must produce BITWISE
    equal applied params and memory (the pipeline only reorders
    emission and adds ``optimization_barrier`` edges, never a
    value-changing op; see repro.core.pipeline). Same tiny 2-bucket
    tree as ``two_level_selfcheck``."""
    import dataclasses

    W = int(np.prod([mesh.shape[a] for a in ("pod", "data")]))
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 384)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (40,))}
    plan = bk.make_plan(tree, cols=128, dense_below=64)
    gs = jax.tree.map(lambda x: jnp.stack(
        [x * (1 + 0.1 * i) + 0.01 * i for i in range(W)]), tree)
    mem0 = tuple(
        jax.random.normal(jax.random.PRNGKey(9 + b), (W,) + s.shape)
        * (0.1 if s.kind == "sparse" else 0.0)
        for b, s in enumerate(plan.buckets))

    def run(cfg, mem_, pod_ks=None):
        def sync(m_, g_):
            kw = {"pod_ks": pod_ks} if pod_ks is not None else {}
            upd, new_mem, _ = bucketed_sync_gradients(
                cfg, plan, jax.tree.map(lambda m: m[0], m_),
                jax.tree.map(lambda x: x[0], g_), jnp.float32(eta), **kw)
            return upd, jax.tree.map(lambda m: m[None], new_mem)

        wspec = jax.tree.map(lambda _: P(("pod", "data")), mem_)
        gspec = jax.tree.map(lambda _: P(("pod", "data")), gs)
        return shard_map(
            sync, mesh=mesh, in_specs=(wspec, gspec),
            out_specs=(jax.tree.map(lambda _: P(), tree), wspec))(mem_, gs)

    base = dict(ratio=ratio, data_axes=("data",), pod_axis="pod",
                bucketed=True, bucket_cols=128, wire=wire)
    paths = {
        "flat": SyncConfig(strategy="sparse_allgather", **base),
        "hierarchical": SyncConfig(strategy="hierarchical",
                                   pod_ratios=(1.0, 0.1), **base),
        "pod_dynamic": SyncConfig(strategy="hierarchical",
                                  pod_ratios=(1.0, 9 / 128),
                                  pod_dynamic=True, **base),
    }
    out = {}
    for name, cfg in paths.items():
        per_overlap = {}
        for ov in (None, False, True):
            c = dataclasses.replace(cfg, overlap=ov)
            mem_ = mem0
            applied = []
            # pod_dynamic: two chained steps across a live-k REFRESH
            # (9 -> 4) — the schedule must stay bit-identical through
            # the switch, not just at a fixed k
            schedule = ([[1, 9], [1, 4]] if name == "pod_dynamic"
                        else [None])
            for ks in schedule:
                pk = (None if ks is None
                      else jnp.asarray(ks, jnp.int32))
                upd, mem_ = run(c, mem_, pod_ks=pk)
                applied.append(
                    jax.tree.map(lambda t, u: t - u, tree, upd))
            per_overlap[ov] = (applied, mem_)
        out[f"{name}_bitwise"] = bool(
            bitwise_equal(per_overlap[None], per_overlap[False])
            and bitwise_equal(per_overlap[None], per_overlap[True]))
    out["bitwise_all"] = all(out.values())
    return out


def local_quant_selfcheck(mesh, ratio: float = 0.05, eta: float = 0.3,
                          quant: int = 15, hs=(2, 4, 8)) -> dict:
    """Probe the Qsparse-local-SGD invariants on ``mesh`` (axes
    ``("pod", "data")``). Same tiny 2-bucket tree as
    ``two_level_selfcheck``. Reports:

    * **h1_accum_bitwise** — the local-step ACCUMULATOR path (pack each
      step's scaled gradient into bucket space via
      ``buckets.accumulate_local``, then sync the accumulator with
      ``grad_bufs=``/``eta=1.0``) is BITWISE identical to the direct
      per-step sync at H=1, on flat, hierarchical AND runtime-k
      (pod_dynamic) strategies: packing is elementwise-linear, so
      ``1.0 * (eta*pack(g))`` reproduces ``eta*pack(g)`` exactly. This
      is the acceptance invariant that lets the train driver keep H=1
      on the literal per-step path.
    * **quant_conservation_max_err** — with the QSGD wire tier
      (``WireConfig.quant``) mass conservation stays EXACT (float-sum
      association is the only slack): the memory absorbs the
      quantization error because the sender's own contribution uses the
      dequantized values, on both flat and two-level strategies.
    * **quant_bit_identical** — packed and unpacked wires produce
      bitwise equal updates and memories under quantization: both ship
      ``encoding.dequantize_rows`` of the same codes.
    * **quant_accounting_exact** — realized sync bytes equal the
      ``bucketed_message_bytes(..., quant)`` prediction (code words +
      row-norm words, exact).
    * **amortized_ratio_exact** — ``amortized_bytes_per_step`` scales
      exactly 1/H for every H in ``hs``.
    """
    from repro.core.distributed import amortized_bytes_per_step

    W = int(np.prod([mesh.shape[a] for a in ("pod", "data")]))
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 384)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (40,))}
    plan = bk.make_plan(tree, cols=128, dense_below=64)
    gs = jax.tree.map(lambda x: jnp.stack(
        [x * (1 + 0.1 * i) + 0.01 * i for i in range(W)]), tree)
    mem0 = tuple(
        jax.random.normal(jax.random.PRNGKey(9 + b), (W,) + s.shape)
        * (0.1 if s.kind == "sparse" else 0.0)
        for b, s in enumerate(plan.buckets))
    realized = {}

    def run(cfg, accumulate=False, pod_ks=None, tag=None):
        qk = (jax.random.PRNGKey(5) if cfg.quant is not None else None)

        def sync(m_, g_):
            kw = {}
            if pod_ks is not None:
                kw["pod_ks"] = pod_ks
            if qk is not None:
                kw["quant_key"] = qk
            g0 = jax.tree.map(lambda x: x[0], g_)
            if accumulate:
                acc = bk.init_local_accum(plan)
                acc = bk.accumulate_local(plan, acc, g0, jnp.float32(eta))
                kw["grad_bufs"] = acc
                upd, new_mem, nbytes = bucketed_sync_gradients(
                    cfg, plan, jax.tree.map(lambda m: m[0], m_), None,
                    jnp.float32(1.0), **kw)
            else:
                upd, new_mem, nbytes = bucketed_sync_gradients(
                    cfg, plan, jax.tree.map(lambda m: m[0], m_), g0,
                    jnp.float32(eta), **kw)
            if tag is not None:
                realized[tag] = nbytes
            return upd, jax.tree.map(lambda m: m[None], new_mem)

        wspec = jax.tree.map(lambda _: P(("pod", "data")), mem0)
        gspec = jax.tree.map(lambda _: P(("pod", "data")), gs)
        return shard_map(
            sync, mesh=mesh, in_specs=(wspec, gspec),
            out_specs=(jax.tree.map(lambda _: P(), tree), wspec))(mem0, gs)

    base = dict(ratio=ratio, data_axes=("data",), bucketed=True,
                bucket_cols=128)
    from repro.core.distributed import PodConfig, WireConfig

    # 1) H=1 accumulator routing is bitwise-invisible on every strategy
    paths = {
        "flat": SyncConfig(strategy="sparse_allgather",
                           pod=PodConfig(axis="pod"),
                           wire=WireConfig(wire="packed"), **base),
        "hierarchical": SyncConfig(strategy="hierarchical",
                                   pod=PodConfig(axis="pod",
                                                 ratios=(1.0, 0.1)),
                                   wire=WireConfig(wire="packed"), **base),
        "pod_dynamic": SyncConfig(strategy="hierarchical",
                                  pod=PodConfig(axis="pod", dynamic=True,
                                                ratios=(1.0, 9 / 128)),
                                  wire=WireConfig(wire="packed"), **base),
    }
    h1_ok = True
    for name, cfg in paths.items():
        pk = (jnp.asarray([1, 9], jnp.int32) if name == "pod_dynamic"
              else None)
        direct = run(cfg, accumulate=False, pod_ks=pk)
        accum = run(cfg, accumulate=True, pod_ks=pk)
        h1_ok = h1_ok and bitwise_equal(direct, accum)

    # 2) quantized tier: conservation + packed/unpacked identity + bytes
    cons_err = 0.0
    bit_ok = True
    acc_ok = True
    for name, mk in (
        ("flat", lambda w: SyncConfig(
            strategy="sparse_allgather", pod=PodConfig(axis="pod"),
            wire=WireConfig(wire=w, quant=quant), **base)),
        ("hier", lambda w: SyncConfig(
            strategy="hierarchical",
            pod=PodConfig(axis="pod", ratios=(1.0, 0.1)),
            wire=WireConfig(wire=w, quant=quant), **base)),
    ):
        out_p = run(mk("packed"), tag=f"{name}-packed")
        out_u = run(mk("unpacked"))
        bit_ok = bit_ok and bitwise_equal(out_p, out_u)
        acc_ok = acc_ok and realized[f"{name}-packed"] == (
            bucketed_message_bytes(mk("packed"), plan))
        upd_bufs = bk.pack(plan, out_p[0], dtype=jnp.float32)
        for b in range(len(plan.buckets)):
            u_w = jnp.stack([
                mem0[b][w] + eta * bk.pack(
                    plan, jax.tree.map(lambda x, w=w: x[w], gs),
                    dtype=jnp.float32)[b]
                for w in range(W)])
            lhs = jnp.mean(u_w, axis=0)
            rhs = upd_bufs[b] + jnp.mean(out_p[1][b], axis=0)
            cons_err = max(cons_err, float(jnp.max(jnp.abs(lhs - rhs))))

    # 3) amortized byte accounting scales exactly 1/H
    q = SyncConfig(strategy="sparse_allgather", pod=PodConfig(axis="pod"),
                   wire=WireConfig(wire="packed", quant=quant), **base)
    full = bucketed_message_bytes(q, plan)
    ratio_ok = all(
        amortized_bytes_per_step(
            SyncConfig(strategy="sparse_allgather",
                       pod=PodConfig(axis="pod"),
                       wire=WireConfig(wire="packed", quant=quant),
                       local_steps=h, **base),
            plan) == full / h
        for h in hs)
    return {
        "h1_accum_bitwise": bool(h1_ok),
        "quant_conservation_max_err": cons_err,
        "quant_bit_identical": bool(bit_ok),
        "quant_accounting_exact": bool(acc_ok),
        "amortized_ratio_exact": bool(ratio_ok),
    }


def repack_selfcheck(mesh, ratio: float = 0.05, eta: float = 0.3,
                     ks=(9, 4)) -> dict:
    """Probe the header-aware repack transport invariants on ``mesh``
    (axes ``("pod", "data")``). Same tiny 2-bucket tree as
    ``two_level_selfcheck`` (bucket 0 dense, bucket 1 sparse at
    cols=128). Reports:

    * **repack_bitwise** — on the runtime-k path, ``SyncConfig.repack``
      on/off x overlap in {None, False, True}, chained across a mid-run
      live-k switch (``ks[0] -> ks[1]``), all produce BITWISE equal
      applied params and memory: the in-jit R stage is the identity and
      only grows the schedule (invariants 10 + 11).
    * **transport_roundtrip_bitwise** — host-side
      ``distributed.repack_transport`` (inline and over an
      ``EmulatedLink`` future) returns the k_max-padded buffer BITWISE
      unchanged: repack -> link -> repad is invisible to the consumer.
    * **transport_accounting_exact** — the bytes the transport puts on
      the wire equal ``encoding.message_nbytes(..., live_k)`` AND the
      sparse cross-pod term of ``bucketed_message_bytes(...,
      pod_ks=...)``: realized cross-pod bytes == live-k accounting
      (invariant 11).
    * **padded_vs_live_bytes** — the (padded, live) cross-pod byte pair
      for the probe's sparse bucket, the gap the transport closes.
    """
    import dataclasses

    from repro.core import encoding as enc
    from repro.core import pipeline
    from repro.core.distributed import repack_transport
    from repro.kernels.topk_select import mask_live_k

    W = int(np.prod([mesh.shape[a] for a in ("pod", "data")]))
    n_data = int(mesh.shape["data"])
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 384)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (40,))}
    plan = bk.make_plan(tree, cols=128, dense_below=64)
    gs = jax.tree.map(lambda x: jnp.stack(
        [x * (1 + 0.1 * i) + 0.01 * i for i in range(W)]), tree)
    mem0 = tuple(
        jax.random.normal(jax.random.PRNGKey(9 + b), (W,) + s.shape)
        * (0.1 if s.kind == "sparse" else 0.0)
        for b, s in enumerate(plan.buckets))

    def run(cfg, mem_, pod_ks):
        def sync(m_, g_):
            upd, new_mem, _ = bucketed_sync_gradients(
                cfg, plan, jax.tree.map(lambda m: m[0], m_),
                jax.tree.map(lambda x: x[0], g_), jnp.float32(eta),
                pod_ks=pod_ks)
            return upd, jax.tree.map(lambda m: m[None], new_mem)

        wspec = jax.tree.map(lambda _: P(("pod", "data")), mem_)
        gspec = jax.tree.map(lambda _: P(("pod", "data")), gs)
        return shard_map(
            sync, mesh=mesh, in_specs=(wspec, gspec),
            out_specs=(jax.tree.map(lambda _: P(), tree), wspec))(mem_, gs)

    dyn = SyncConfig(ratio=ratio, strategy="hierarchical",
                     data_axes=("data",), pod_axis="pod", bucketed=True,
                     bucket_cols=128, wire="packed",
                     pod_ratios=(1.0, ks[0] / 128), pod_dynamic=True)
    outs = {}
    for rp in (False, True):
        for ov in (None, False, True):
            c = dataclasses.replace(dyn, repack=rp, overlap=ov)
            mem_, applied = mem0, []
            for k_live in ks:  # chained steps across the live-k switch
                upd, mem_ = run(c, mem_,
                                jnp.asarray([1, k_live], jnp.int32))
                applied.append(jax.tree.map(lambda t, u: t - u, tree, upd))
            outs[(rp, ov)] = (applied, mem_)
    ref = outs[(False, None)]
    repack_bitwise = all(bitwise_equal(ref, v) for v in outs.values())

    # host transport on a real k_max-padded pod summary: bucket 1 (the
    # sparse one), tail masked to (-0.0, 0) past the live k
    spec = plan.buckets[1]
    k_max = dyn.pod_k_max_for_bucket(1, spec.cols, n_data)
    k_live = int(ks[-1])
    u = jax.random.normal(jax.random.PRNGKey(3), (spec.rows, spec.cols))
    _, idx = jax.lax.top_k(jnp.abs(u), k_max)
    vals = jnp.take_along_axis(u, idx, axis=-1)
    vals, idx = mask_live_k(vals, idx.astype(jnp.int32), k_live)
    wspec = enc.WireSpec(spec.rows, spec.cols, k_max)
    buf = enc.encode(wspec, vals, idx, live_n=k_live)
    out_inline, nb_inline = repack_transport(wspec, buf)
    link = pipeline.EmulatedLink(latency_s=0.0)
    fut, nb_link = repack_transport(wspec, buf, link=link)
    roundtrip = (bitwise_equal(out_inline, buf)
                 and bitwise_equal(fut.result(), buf))
    live_bytes = enc.message_nbytes(
        spec.rows, spec.cols, k_live, "float32", "packed")
    lv = bucketed_message_bytes(dyn, plan, by_level=True, n_data=n_data,
                                pod_ks=[1, k_live])
    dense_cross = plan.buckets[0].rows * plan.buckets[0].cols * 4
    acc_ok = (nb_inline == live_bytes and nb_link == live_bytes
              and lv["cross"] - dense_cross == live_bytes)
    return {
        "repack_bitwise": bool(repack_bitwise),
        "transport_roundtrip_bitwise": bool(roundtrip),
        "transport_accounting_exact": bool(acc_ok),
        "padded_vs_live_bytes": [wspec.nbytes, live_bytes],
    }
