"""Core library: the paper's contribution (Mem-SGD) as composable JAX modules.

Public API:

* ``repro.core.compression`` — k-contraction operators (Def. 2.1/2.2).
* ``repro.core.memory``      — error-feedback memory primitive.
* ``repro.core.memsgd``      — Algorithm 1 as a GradientTransformation.
* ``repro.core.buckets``     — flat-buffer engine (pytree -> few buckets).
* ``repro.core.distributed`` — PARALLEL-MEM-SGD sparse all-gather sync.
* ``repro.core.theory``      — Theorem 2.4 stepsizes / averaging / bounds.
* ``repro.core.encoding``    — packed sparse wire codec + bit accounting.
"""
from repro.core.compression import (
    Compressor,
    top_k,
    rand_k,
    blockwise_top_k,
    random_coordinate,
    identity,
    make_compressor,
)
from repro.core.memory import init_memory, memory_step, tree_memory_step
from repro.core.memsgd import (
    memsgd,
    memsgd_bucketed,
    memsgd_flat,
    MemSGDState,
    leaf_compressor_from_ratio,
    constant_eta,
)
from repro.core.buckets import (
    BucketPlan,
    accumulate_local,
    bucket_memory_step,
    init_bucket_memory,
    init_local_accum,
    make_plan,
    pack,
    unpack,
)
from repro.core.distributed import (
    PodConfig,
    SyncConfig,
    TransportConfig,
    WireConfig,
    amortized_bytes_per_step,
    bucketed_message_bytes,
    bucketed_sync_gradients,
    message_bytes,
    sparse_sync_gradients,
)
from repro.core.encoding import WireSpec, decode as wire_decode, encode as wire_encode

__all__ = [
    "Compressor",
    "top_k",
    "rand_k",
    "blockwise_top_k",
    "random_coordinate",
    "identity",
    "make_compressor",
    "init_memory",
    "memory_step",
    "tree_memory_step",
    "memsgd",
    "memsgd_bucketed",
    "memsgd_flat",
    "MemSGDState",
    "leaf_compressor_from_ratio",
    "constant_eta",
    "BucketPlan",
    "accumulate_local",
    "bucket_memory_step",
    "init_bucket_memory",
    "init_local_accum",
    "make_plan",
    "pack",
    "unpack",
    "PodConfig",
    "SyncConfig",
    "TransportConfig",
    "WireConfig",
    "amortized_bytes_per_step",
    "bucketed_message_bytes",
    "bucketed_sync_gradients",
    "message_bytes",
    "sparse_sync_gradients",
    "WireSpec",
    "wire_decode",
    "wire_encode",
]
