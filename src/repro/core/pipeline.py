"""Software-pipelined bucket schedule: overlap encode / all-gather / decode.

The bucketed sync (``core.distributed.bucketed_sync_gradients``) runs a
handful of independent per-bucket stage chains, alternating compute
(top-k select + wire encode, decode + densify) and communication (the
all-gather). Run strictly bucket-after-bucket, the wire sits idle while
a bucket computes and the ALUs sit idle while it gathers. This module
plans and executes a DOUBLE-BUFFERED schedule instead: while bucket b's
gather is in flight, bucket b+1 runs its select/encode — the classic
software pipeline, parameterized by ``depth`` (how many buckets may be
in flight at once; 1 degenerates to strict sequential, 2 is the double
buffer).

Three entry points share ONE planner, so the schedule the tests verify
is the schedule both executors run:

* ``plan_schedule(kinds, depth)`` — pure planning: per-bucket stage
  kinds ("compute" / "comm") in, a total order of (bucket, stage)
  emissions out. The planner walks the oldest in-flight bucket up to
  and through its next comm issue, then advances younger in-flight
  buckets' compute stages (the work that hides behind the comm), and
  admits bucket b only after bucket b-depth fully retired — the
  depth-bucket memory bound.

* ``run_schedule(...)`` — the IN-JIT executor. Stages are traced in
  schedule order and the depth window is enforced with
  ``jax.lax.optimization_barrier``: bucket b's input is passed through
  one barrier together with a leaf of bucket (b-depth)'s final output,
  which creates a scheduling dependency WITHOUT changing any value —
  this is why ``overlap=True`` is bitwise-identical to
  ``overlap=False`` by construction (the barrier only orders; all
  data-flow edges, and hence all float results, are untouched). On
  backends with async collectives (see ``utils.platform`` — XLA splits
  each all-gather into start/done and the latency-hiding scheduler
  moves independent compute between them) the depth-2 trace order
  yields real comm/compute concurrency; the barrier chain simultaneously
  CAPS liveness at ``depth`` buckets of gather buffers, so the donated
  double buffers never grow with bucket count.

* ``run_host_pipeline(...)`` — the HOST executor for transports that
  live outside the jit (an RPC link, the bench's emulated-latency
  wire). Comm stages return a future; the executor resolves it lazily,
  exactly where the planner scheduled the dependent stage — so with
  depth 1 every transfer is issue-then-wait (the honest sequential
  baseline) and with depth 2 the transfer of bucket b overlaps the
  compute of bucket b+1 on the SAME schedule the in-jit executor
  traces.
"""
from __future__ import annotations

import threading
from typing import Callable, List, Optional, Sequence, Tuple

COMPUTE = "compute"
COMM = "comm"
# A repack boundary stage: the header-aware transport's compaction of a
# k-padded wire buffer down to its live payload right before the slow
# link (see ``core.encoding.repack``). Schedules like compute — it is
# local work that hides behind an in-flight transfer — but is named so
# chains and tests can assert where the byte shrink happens. In-jit the
# stage is the identity (static shapes cannot shrink inside a trace);
# the host executor's repack stage does the real byte reduction.
REPACK = "repack"
# A quantization stage: stochastic-rounding of the selected values into
# wire codes (Qsparse-local-SGD's Q step). Pure local ALU work — it
# schedules exactly like compute, hiding behind an in-flight transfer —
# but is named so stage chains and tests can assert where the value
# precision drops (always BEFORE the encode that feeds a gather).
QUANT = "quant"


def overlap_depth(overlap: Optional[bool]) -> Optional[int]:
    """Map ``SyncConfig.overlap`` to a pipeline depth: ``None`` keeps
    the legacy unconstrained emission (no barriers at all), ``False``
    pins the strict sequential schedule (depth 1), ``True`` double-
    buffers (depth 2)."""
    if overlap is None:
        return None
    return 2 if overlap else 1


def plan_schedule(kinds: Sequence[Sequence[str]], depth: int
                  ) -> List[Tuple[int, int]]:
    """Total order of (bucket, stage) emissions for the given depth.

    ``kinds[b][s]`` is "compute", "comm", "quant" or "repack" (quant and
    repack stages schedule exactly like compute: local work that hides
    behind an in-flight transfer). At most ``depth`` buckets are in flight at any
    point; bucket b is admitted only once bucket b-depth has fully
    retired. Depth 1 reproduces the strict sequential order; depth 2
    produces the classic double buffer (for per-bucket kinds [E, G, D]:
    E0 G0 E1 D0 G1 E2 D1 ... — bucket b+1's encode hides behind bucket
    b's gather).
    """
    if depth < 1:
        raise ValueError(f"pipeline depth must be >= 1, got {depth}")
    n = len(kinds)
    for b, ks in enumerate(kinds):
        for s, kind in enumerate(ks):
            if kind not in (COMPUTE, COMM, QUANT, REPACK):
                raise ValueError(
                    f"unknown stage kind {kind!r} at bucket {b} stage {s}")
    order: List[Tuple[int, int]] = []
    ptr = [0] * n
    window: List[int] = []
    next_b = 0
    while next_b < n or window:
        while len(window) < depth and next_b < n:
            window.append(next_b)
            next_b += 1
        b = window[0]
        # walk the oldest bucket through its pending local stages ...
        while ptr[b] < len(kinds[b]) and kinds[b][ptr[b]] != COMM:
            order.append((b, ptr[b]))
            ptr[b] += 1
        # ... and through its next comm issue, hiding younger buckets'
        # compute stages behind the in-flight transfer
        if ptr[b] < len(kinds[b]):
            order.append((b, ptr[b]))
            ptr[b] += 1
            for b2 in window[1:]:
                while (ptr[b2] < len(kinds[b2])
                       and kinds[b2][ptr[b2]] != COMM):
                    order.append((b2, ptr[b2]))
                    ptr[b2] += 1
        if ptr[b] == len(kinds[b]):
            window.pop(0)
    return order


def validate_schedule(order: Sequence[Tuple[int, int]],
                      kinds: Sequence[Sequence[str]], depth: int) -> None:
    """Raise unless ``order`` is a legal depth-bounded schedule of
    ``kinds``: a permutation of every (bucket, stage), per-bucket stages
    ascending, and no bucket starting before bucket b-depth retired."""
    n = len(kinds)
    want = {(b, s) for b in range(n) for s in range(len(kinds[b]))}
    if len(order) != len(want) or set(order) != want:
        raise AssertionError(
            f"schedule is not a permutation of all stages: {order}")
    pos = {bs: i for i, bs in enumerate(order)}
    for b in range(n):
        for s in range(1, len(kinds[b])):
            if pos[(b, s)] < pos[(b, s - 1)]:
                raise AssertionError(
                    f"bucket {b} stage {s} scheduled before stage {s - 1}")
    for b in range(depth, n):
        started = pos[(b, 0)]
        retired = pos[(b - depth, len(kinds[b - depth]) - 1)]
        if started < retired:
            raise AssertionError(
                f"bucket {b} started before bucket {b - depth} retired "
                f"(depth {depth} window violated)")


def _first_leaf(tree):
    import jax

    return jax.tree.leaves(tree)[0]


def barrier_after(x, dep):
    """Pass ``x`` through an ``optimization_barrier`` tied to ``dep``:
    the returned value EQUALS ``x`` (bitwise — the barrier is the
    identity on every leaf) but cannot be scheduled before ``dep`` is
    available. ``dep=None`` is the no-op."""
    if dep is None:
        return x
    import jax

    out, _ = jax.lax.optimization_barrier((x, dep))
    return out


def run_schedule(inits: Sequence, stage_lists: Sequence[Sequence[Callable]],
                 kinds: Sequence[Sequence[str]],
                 depth: Optional[int]) -> list:
    """Trace every bucket's stage chain in the planned order (in-jit).

    ``inits[b]`` is bucket b's input (fed to stage 0); each stage is a
    callable ``state -> state``; the final stage's output is returned
    per bucket. ``depth=None`` runs the chains bucket-by-bucket with no
    barriers — the legacy emission, byte-for-byte what the sequential
    loop produced. An integer depth emits in ``plan_schedule`` order
    and gates bucket b's INPUT on bucket (b-depth)'s final output via
    ``barrier_after``, bounding liveness at depth buckets without
    touching any value.
    """
    n = len(inits)
    if depth is None:
        out = []
        for init, stages in zip(inits, stage_lists):
            st = init
            for f in stages:
                st = f(st)
            out.append(st)
        return out
    order = plan_schedule(kinds, depth)
    state: list = [None] * n
    done: list = [None] * n
    for b, s in order:
        if s == 0:
            dep_b = b - depth
            dep = _first_leaf(done[dep_b]) if dep_b >= 0 else None
            x = barrier_after(inits[b], dep)
        else:
            x = state[b]
        out = stage_lists[b][s](x)
        if s == len(stage_lists[b]) - 1:
            done[b] = out
        else:
            state[b] = out
    return done


def _is_future(x) -> bool:
    return callable(getattr(x, "result", None))


def run_host_pipeline(inits: Sequence,
                      stage_lists: Sequence[Sequence[Callable]],
                      kinds: Sequence[Sequence[str]], depth: int) -> list:
    """Host-side executor on the SAME planner: comm stages may return a
    future (anything with ``.result()``); it is resolved lazily, right
    where the schedule runs the dependent stage — so the transfer's
    latency is exposed (depth 1) or hidden behind younger buckets'
    compute (depth >= 2) exactly as planned. Returns each bucket's
    final state (futures resolved)."""
    n = len(inits)
    order = plan_schedule(kinds, depth)
    state: list = [None] * n
    done: list = [None] * n
    for b, s in order:
        x = inits[b] if s == 0 else state[b]
        if _is_future(x):
            x = x.result()
        out = stage_lists[b][s](x)
        if s == len(stage_lists[b]) - 1:
            done[b] = out
        else:
            state[b] = out
    return [d.result() if _is_future(d) else d for d in done]


class EmulatedLink:
    """A wire with real wall-clock latency for the host pipeline.

    ``transfer(payload, nbytes)`` returns a future that resolves to
    ``payload`` after ``latency_s + nbytes / bandwidth_Bps`` of real
    time on a single background transfer thread (one thread == one
    serialized link, like a NIC). The bench drives the pipelined
    executor over this to measure the schedule's overlap on hardware
    with no async collectives (this CPU container); tests use it with
    microsecond latencies to assert ordering, not timing.
    """

    def __init__(self, latency_s: float = 0.0,
                 bandwidth_Bps: Optional[float] = None):
        self.latency_s = float(latency_s)
        self.bandwidth_Bps = bandwidth_Bps
        self._lock = threading.Lock()
        self._busy_until = 0.0
        self.transfers: List[Tuple[float, float]] = []  # (issue, done)

    def delay_for(self, nbytes: int) -> float:
        d = self.latency_s
        if self.bandwidth_Bps:
            d += nbytes / self.bandwidth_Bps
        return d

    def transfer(self, payload, nbytes: int):
        import time

        delay = self.delay_for(nbytes)
        issue = time.monotonic()
        with self._lock:
            # a single serialized link: a transfer starts only when the
            # previous one has drained
            start = max(issue, self._busy_until)
            ready = start + delay
            self._busy_until = ready
            self.transfers.append((issue, ready))

        class _F:
            def result(self_f):
                now = time.monotonic()
                if ready > now:
                    time.sleep(ready - now)
                return payload

        return _F()
