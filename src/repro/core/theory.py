"""Theorem 2.4 machinery: stepsizes, shifts, weighted averaging, bounds.

Paper's experimental stepsize (Table 2):   eta_t = gamma / (lambda * (t + a))
Theorem stepsize:                          eta_t = 8 / (mu * (a + t))
Shift recommendation (Remark 2.5/2.6):     a = (alpha + 2) * d / k, alpha = 5;
                                           in practice a = d/k suffices.
Averaging (Thm 2.4): x_bar = (1/S_T) * sum_t w_t x_t with w_t = (a + t)^2.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def theoretical_shift(d: int, k: float, alpha: float = 5.0) -> float:
    """a = (alpha+2) d/k — sufficient per Remark 2.5."""
    return (alpha + 2.0) * d / k


def practical_shift(d: int, k: float, factor: float = 1.0) -> float:
    """a = factor * d/k — the paper uses d/k (epsilon) and 10 d/k (RCV1)."""
    return factor * d / k


def paper_stepsize(gamma: float, lam: float, a: float) -> Callable[[Array], Array]:
    """eta_t = gamma / (lambda (t + a)) — paper Table 2."""

    def eta(t: Array) -> Array:
        return gamma / (lam * (t.astype(jnp.float32) + a))

    return eta


def theorem_stepsize(mu: float, a: float) -> Callable[[Array], Array]:
    """eta_t = 8 / (mu (a + t)) — Theorem 2.4."""

    def eta(t: Array) -> Array:
        return 8.0 / (mu * (a + t.astype(jnp.float32)))

    return eta


def bottou_stepsize(gamma0: float, lam: float) -> Callable[[Array], Array]:
    """eta_t = gamma0 / (1 + gamma0 * lambda * t) — used for the QSGD
    comparison (paper §4.3, Bottou '12)."""

    def eta(t: Array) -> Array:
        return gamma0 / (1.0 + gamma0 * lam * t.astype(jnp.float32))

    return eta


# ---------------------------------------------------------------------------
# Quadratically-weighted running average of iterates (w_t = (a+t)^2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WeightedAverage:
    """Streaming x_bar_T = sum w_t x_t / S_T without storing the iterates.

    Maintains (running weighted sum, running weight). Works on pytrees.
    """

    a: float

    def init(self, params):
        return (
            jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
            jnp.zeros((), jnp.float32),
        )

    def update(self, avg_state, params, t: Array):
        wsum, stot = avg_state
        w = jnp.square(self.a + t.astype(jnp.float32))
        wsum = jax.tree.map(lambda s, p: s + w * p.astype(jnp.float32), wsum, params)
        return (wsum, stot + w)

    def value(self, avg_state):
        wsum, stot = avg_state
        return jax.tree.map(lambda s: s / jnp.maximum(stot, 1e-30), wsum)


def S_T(T: int, a: float) -> float:
    """Closed form S_T = sum_{t=0}^{T-1} (a+t)^2 from Lemma 3.3."""
    return T / 6.0 * (2 * T * T + 6 * a * T - 3 * T + 6 * a * a - 6 * a + 1)


def theorem_bound(
    T: int, d: int, k: float, mu: float, L: float, G2: float, x0_dist2: float,
    alpha: float = 5.0,
) -> float:
    """RHS of (9) — the explicit Theorem 2.4 suboptimality bound.

    Useful for sanity checks: measured E f(x_bar) - f* must lie below this.
    """
    a = theoretical_shift(d, k, alpha)
    st = S_T(T, a)
    c_alpha = 4 * alpha / (alpha - 4.0)
    term1 = 4 * T * (T + 2 * a) / (mu * st) * G2
    term2 = mu * a**3 / (8 * st) * x0_dist2
    term3 = 64 * T * (1 + 2 * L / mu) / (mu * st) * c_alpha * (d / k) ** 2 * G2
    return term1 + term2 + term3
