"""Theorem 2.4 machinery: stepsizes, shifts, weighted averaging, bounds.

Paper's experimental stepsize (Table 2):   eta_t = gamma / (lambda * (t + a))
Theorem stepsize:                          eta_t = 8 / (mu * (a + t))
Shift recommendation (Remark 2.5/2.6):     a = (alpha + 2) * d / k, alpha = 5;
                                           in practice a = d/k suffices.
Averaging (Thm 2.4): x_bar = (1/S_T) * sum_t w_t x_t with w_t = (a + t)^2.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def qsgd_variance_bound(d: int, s: int) -> float:
    """QSGD Lemma 3.1 (Alistarh et al.): the s-level stochastic quantizer
    is unbiased with relative variance
    E||Q_s(x) - x||^2 <= beta ||x||^2, beta = min(d/s^2, sqrt(d)/s)."""
    return min(d / float(s) ** 2, math.sqrt(d) / s)


def composed_contraction(d: int, k: float,
                         s: Optional[int] = None) -> float:
    """Contraction factor delta of the composed compressor
    ``Q_s ∘ top_k`` (Qsparse-local-SGD, Basu et al.):
    E||C(x) - x||^2 <= (1 - delta) ||x||^2.

    top_k alone keeps mass >= (k/d)||x||^2, so delta = k/d (paper
    eq. (3)). Quantizing the k kept entries re-injects QSGD variance on
    the kept mass only: with beta_k = min(k/s^2, sqrt(k)/s),

        E||Q(top_k(x)) - x||^2
          = E||Q(x_k) - x_k||^2 + ||x - x_k||^2     (Q unbiased on x_k)
          <= beta_k ||x_k||^2 + (||x||^2 - ||x_k||^2)
          = ||x||^2 - (1 - beta_k) ||x_k||^2,

    giving delta = (k/d) * (1 - beta_k) — a strict contraction whenever
    beta_k < 1, which is what keeps the error-feedback memory bounded
    (Thm 2.4's (1-delta)/delta^2 residual term) under the composition.
    ``s=None`` (no quantization) reduces to the paper's k/d."""
    base = k / float(d)
    if s is None:
        return base
    beta_k = qsgd_variance_bound(max(1, int(math.ceil(k))), s)
    return base * max(0.0, 1.0 - beta_k)


def local_steps_residual_factor(H: int) -> float:
    """Scale of Thm 2.4's memory-residual term when syncing every H
    steps: the committed displacement is the H-step accumulation
    sum_h eta_h g_h, so the 4 eta^2 G^2 (1-delta)/delta^2 bound on
    ||memory||^2 grows by H^2 (Qsparse-local-SGD's H-dependence; the
    leading 1/(mu T) term is unchanged)."""
    if H < 1:
        raise ValueError(f"local_steps must be >= 1, got {H}")
    return float(H) ** 2


def theoretical_shift(d: int, k: float, alpha: float = 5.0) -> float:
    """a = (alpha+2) d/k — sufficient per Remark 2.5."""
    return (alpha + 2.0) * d / k


def practical_shift(d: int, k: float, factor: float = 1.0) -> float:
    """a = factor * d/k — the paper uses d/k (epsilon) and 10 d/k (RCV1)."""
    return factor * d / k


def paper_stepsize(gamma: float, lam: float, a: float) -> Callable[[Array], Array]:
    """eta_t = gamma / (lambda (t + a)) — paper Table 2."""

    def eta(t: Array) -> Array:
        return gamma / (lam * (t.astype(jnp.float32) + a))

    return eta


def theorem_stepsize(mu: float, a: float) -> Callable[[Array], Array]:
    """eta_t = 8 / (mu (a + t)) — Theorem 2.4."""

    def eta(t: Array) -> Array:
        return 8.0 / (mu * (a + t.astype(jnp.float32)))

    return eta


def bottou_stepsize(gamma0: float, lam: float) -> Callable[[Array], Array]:
    """eta_t = gamma0 / (1 + gamma0 * lambda * t) — used for the QSGD
    comparison (paper §4.3, Bottou '12)."""

    def eta(t: Array) -> Array:
        return gamma0 / (1.0 + gamma0 * lam * t.astype(jnp.float32))

    return eta


# ---------------------------------------------------------------------------
# Quadratically-weighted running average of iterates (w_t = (a+t)^2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WeightedAverage:
    """Streaming x_bar_T = sum w_t x_t / S_T without storing the iterates.

    Maintains (running weighted sum, running weight). Works on pytrees.
    """

    a: float

    def init(self, params):
        return (
            jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
            jnp.zeros((), jnp.float32),
        )

    def update(self, avg_state, params, t: Array):
        wsum, stot = avg_state
        w = jnp.square(self.a + t.astype(jnp.float32))
        wsum = jax.tree.map(lambda s, p: s + w * p.astype(jnp.float32), wsum, params)
        return (wsum, stot + w)

    def value(self, avg_state):
        wsum, stot = avg_state
        return jax.tree.map(lambda s: s / jnp.maximum(stot, 1e-30), wsum)


def S_T(T: int, a: float) -> float:
    """Closed form S_T = sum_{t=0}^{T-1} (a+t)^2 from Lemma 3.3."""
    return T / 6.0 * (2 * T * T + 6 * a * T - 3 * T + 6 * a * a - 6 * a + 1)


def theorem_bound(
    T: int, d: int, k: float, mu: float, L: float, G2: float, x0_dist2: float,
    alpha: float = 5.0,
) -> float:
    """RHS of (9) — the explicit Theorem 2.4 suboptimality bound.

    Useful for sanity checks: measured E f(x_bar) - f* must lie below this.
    """
    a = theoretical_shift(d, k, alpha)
    st = S_T(T, a)
    c_alpha = 4 * alpha / (alpha - 4.0)
    term1 = 4 * T * (T + 2 * a) / (mu * st) * G2
    term2 = mu * a**3 / (8 * st) * x0_dist2
    term3 = 64 * T * (1 + 2 * L / mu) / (mu * st) * c_alpha * (d / k) ** 2 * G2
    return term1 + term2 + term3
