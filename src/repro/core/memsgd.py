"""Mem-SGD (Algorithm 1) as a composable GradientTransformation.

The transformation receives RAW gradients and returns the ADDITIVE update
-comp_k(m + eta*g); the stepsize eta is consumed HERE (at memory-insertion
time, per the paper — not applied downstream), so Mem-SGD must be the final
element of an optimizer chain.

Three constructors:

* ``memsgd(compressor, eta_schedule)`` — sequential Algorithm 1 on a
  parameter pytree with per-leaf compression.
* ``memsgd_bucketed(...)`` — Algorithm 1 on the bucketed flat-buffer
  engine (``repro.core.buckets``): the pytree is packed into <= ~4 big
  (rows, cols) buffers, the memory lives in bucket space, and each step
  runs one fused Pallas dispatch per bucket instead of one compressor per
  leaf. Row-block top-k over a bucket is ``blockwise_top_k(k, cols)`` over
  the concatenated parameters — a k-contraction, so Theorem 2.4 holds.
* ``memsgd_flat(...)`` — operates on a single flat vector (used for the
  paper's logistic-regression reproduction where x ∈ R^d).

The distributed PARALLEL-MEM-SGD (per-worker memory + sparse all-gather) is
in ``repro.core.distributed`` and reuses these semantics.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import compression as comp_lib
from repro.core.compression import Compressor
from repro.core.memory import init_memory, tree_memory_step
from repro.optim.base import GradientTransformation

Array = jax.Array
Schedule = Callable[[Array], Array]


class MemSGDState(NamedTuple):
    count: Array  # step t
    memory: object  # pytree like params
    rng: Array


def constant_eta(eta: float) -> Schedule:
    return lambda t: jnp.asarray(eta, jnp.float32)


def leaf_compressor_from_ratio(ratio: float, block: Optional[int] = None,
                               mode: str = "top_k") -> Callable:
    """Per-leaf compressor: k = max(1, round(ratio*size)).

    ``mode`` in {"top_k", "rand_k", "blockwise"}; blockwise uses
    k_per_block = max(1, round(ratio*block)).
    """

    def for_leaf(g: Array) -> Compressor:
        d = g.size
        if mode == "blockwise":
            b = block or 1024
            return comp_lib.blockwise_top_k(max(1, int(round(ratio * b))), b)
        k = max(1, min(d, int(round(ratio * d))))
        if mode == "top_k":
            return comp_lib.top_k(k)
        if mode == "rand_k":
            return comp_lib.rand_k(k)
        raise ValueError(mode)

    return for_leaf


def memsgd(
    compressor_for_leaf: Callable[[Array], Compressor],
    eta_schedule: Schedule,
    seed: int = 0,
    needs_rng: bool = True,
) -> GradientTransformation:
    """Sequential Mem-SGD over a parameter pytree (Algorithm 1)."""

    def init(params):
        return MemSGDState(
            count=jnp.zeros((), jnp.int32),
            memory=init_memory(params),
            rng=jax.random.PRNGKey(seed),
        )

    def update(grads, state: MemSGDState, params=None, **_):
        eta = eta_schedule(state.count)
        if needs_rng:
            rng, sub = jax.random.split(state.rng)
        else:
            rng, sub = state.rng, None
        applied, new_mem = tree_memory_step(
            compressor_for_leaf, state.memory, grads, eta, sub
        )
        updates = jax.tree.map(lambda a: -a, applied)
        return updates, MemSGDState(count=state.count + 1, memory=new_mem, rng=rng)

    return GradientTransformation(init, update)


# ---------------------------------------------------------------------------
# Bucketed variant (flat-buffer engine; repro.core.buckets)
# ---------------------------------------------------------------------------


def memsgd_bucketed(
    ratio: float,
    eta_schedule: Schedule,
    *,
    cols: Optional[int] = None,
    dense_below: Optional[int] = None,
    k_min: int = 1,
    method: str = "auto",
    seed: int = 0,
) -> GradientTransformation:
    """Mem-SGD over dtype-homogeneous flat buckets (<= ~4 dispatches/step).

    ``ratio`` sets the per-row k = max(k_min, round(ratio * cols)); small
    leaves (< dense_below) ride in a dense bucket, uncompressed.
    """
    from repro.core import buckets as bk

    cols = bk.DEFAULT_BUCKET_COLS if cols is None else cols
    dense_below = bk.DEFAULT_DENSE_BELOW if dense_below is None else dense_below

    def k_for(c: int) -> int:
        return max(k_min, min(c, int(round(ratio * c))))

    def plan_of(tree) -> "bk.BucketPlan":
        return bk.make_plan(tree, cols=cols, dense_below=dense_below)

    def init(params):
        return MemSGDState(
            count=jnp.zeros((), jnp.int32),
            memory=bk.init_bucket_memory(plan_of(params)),
            rng=jax.random.PRNGKey(seed),
        )

    def update(grads, state: MemSGDState, params=None, **_):
        eta = eta_schedule(state.count)
        applied, new_mem, _ = bk.bucket_memory_step(
            plan_of(grads), state.memory, grads, eta, k_for, method=method
        )
        updates = jax.tree.map(lambda a: -a, applied)
        return updates, MemSGDState(
            count=state.count + 1, memory=new_mem, rng=state.rng
        )

    return GradientTransformation(init, update)


# ---------------------------------------------------------------------------
# Flat-vector variant (paper's setting: x in R^d)
# ---------------------------------------------------------------------------


class FlatMemSGDState(NamedTuple):
    count: Array
    memory: Array  # (d,)
    rng: Array


def memsgd_flat(
    compressor: Compressor, eta_schedule: Schedule, d: int, seed: int = 0
) -> GradientTransformation:
    def init(params):
        del params
        return FlatMemSGDState(
            count=jnp.zeros((), jnp.int32),
            memory=jnp.zeros((d,), jnp.float32),
            rng=jax.random.PRNGKey(seed),
        )

    def update(grad, state: FlatMemSGDState, params=None, **_):
        eta = eta_schedule(state.count)
        rng, sub = jax.random.split(state.rng)
        u = state.memory + eta * grad
        applied = compressor.dense(u, sub if compressor.needs_rng else None)
        new_mem = u - applied
        return -applied, FlatMemSGDState(
            count=state.count + 1, memory=new_mem, rng=rng
        )

    return GradientTransformation(init, update)
