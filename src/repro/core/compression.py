"""k-contraction compression operators (paper Definitions 2.1 and 2.2).

A k-contraction operator ``comp: R^d -> R^d`` satisfies

    E ||x - comp(x)||^2  <=  (1 - k/d) ||x||^2        (Definition 2.1)

All operators here are implemented in two dual forms:

* ``dense(x)   -> R^d``          — the compressed vector, zeros elsewhere.
* ``sparse(x)  -> (values, idx)``— the k transmitted (value, index) pairs,
  which is what actually travels over the interconnect in the distributed
  runtime (``repro.core.distributed``).

Operators provided
------------------
* ``top_k``         — paper Definition 2.2 (largest-|.| coordinates).
* ``rand_k``        — paper Definition 2.2 (uniform random k-subset).
* ``blockwise_top_k`` — TPU-native variant: exact top-k_b per VMEM block.
  Still a k-contraction: per-block top-k_b dominates per-block rand-k_b
  coordinate-wise in captured mass, and per-block rand-k_b with uniform
  blocks equals rand_k in expectation, so (4) holds with k = sum_b k_b.
* ``random_coordinate`` — Remark 2.3 ultra-sparsification: each coordinate
  kept independently with probability k/d, valid for 0 < k <= 1 (and any
  0 < k <= d). E||x-comp(x)||^2 = (1-k/d)||x||^2 exactly.
* ``identity``      — k = d (vanilla SGD), for baselines.

Every operator is a pure jax function usable under jit/vmap/shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


Array = jax.Array
SparsePair = Tuple[Array, Array]  # (values (k,), indices (k,) int32)


@dataclasses.dataclass(frozen=True)
class Compressor:
    """A k-contraction operator in dense and sparse form.

    Attributes:
      name: identifier used in configs / logs.
      k_of: maps vector length d -> number of transmitted coordinates k
        (static python int; may be fractional semantics for ultra-sparse,
        in which case ``sparse`` is unavailable and only ``dense`` exists).
      dense: (x, key) -> compressed dense vector, same shape as x.
      sparse: (x, key) -> (values, indices) with static size k, or None if
        the operator has no fixed-size sparse encoding (e.g. ultra-sparse
        Bernoulli selection has random support size).
      needs_rng: whether the operator consumes the PRNG key.
      bits_per_step: (d,) -> transmitted bits per application (for the
        communication accounting in ``repro.core.encoding``).
    """

    name: str
    k_of: Callable[[int], float]
    dense: Callable[[Array, Optional[Array]], Array]
    sparse: Optional[Callable[[Array, Optional[Array]], SparsePair]]
    needs_rng: bool


# ---------------------------------------------------------------------------
# top_k (Definition 2.2)
# ---------------------------------------------------------------------------


def _topk_sparse(x: Array, k: int) -> SparsePair:
    """(values, indices) of the k largest-magnitude entries of x."""
    mag = jnp.abs(x)
    _, idx = jax.lax.top_k(mag, k)
    vals = jnp.take(x, idx)
    return vals, idx.astype(jnp.int32)


def _densify(x_like: Array, vals: Array, idx: Array) -> Array:
    return jnp.zeros_like(x_like).at[idx].set(vals, mode="drop")


def top_k(k: int) -> Compressor:
    def dense(x, key=None):
        vals, idx = _topk_sparse(x, min(k, x.size))
        return _densify(x, vals, idx)

    def sparse(x, key=None):
        return _topk_sparse(x, min(k, x.size))

    return Compressor(
        name=f"top_{k}", k_of=lambda d: min(k, d), dense=dense, sparse=sparse,
        needs_rng=False,
    )


def top_k_ratio(ratio: float, k_min: int = 1) -> Callable[[int], int]:
    """k as a fraction of d (used for per-leaf compression of pytrees)."""

    def k_of(d: int) -> int:
        return max(k_min, min(d, int(round(ratio * d))))

    return k_of


# ---------------------------------------------------------------------------
# rand_k (Definition 2.2)
# ---------------------------------------------------------------------------


def rand_k(k: int) -> Compressor:
    def sparse(x, key):
        kk = min(k, x.size)
        idx = jax.random.choice(key, x.size, shape=(kk,), replace=False)
        idx = idx.astype(jnp.int32)
        return jnp.take(x, idx), idx

    def dense(x, key):
        vals, idx = sparse(x, key)
        return _densify(x, vals, idx)

    return Compressor(
        name=f"rand_{k}", k_of=lambda d: min(k, d), dense=dense, sparse=sparse,
        needs_rng=True,
    )


# ---------------------------------------------------------------------------
# blockwise top-k (TPU-native; mirrors the Pallas kernel's semantics)
# ---------------------------------------------------------------------------


def blockwise_top_k(k_per_block: int, block: int = 1024) -> Compressor:
    """Exact top-k_b within each contiguous block of ``block`` entries.

    The Pallas kernels in ``repro.kernels.topk_select`` implement exactly
    this operator (the k-argmax loop and the single-pass threshold select
    are bitwise-identical); ``repro.kernels.ref`` is the oracle and this
    function is the framework-level (pure jnp) form used on CPU and in
    tests. It is also the operator the bucketed flat-buffer engine
    (``repro.core.buckets``) applies per bucket: per-row top-k over a
    (rows, cols) bucket == blockwise_top_k(k, cols) over the concatenated
    leaves, which is how Theorem 2.4 carries over to the bucketed path.

    Contraction: for each block b of size B, top-k_b captures at least the
    mass of a uniform random k_b-subset, whose expected residual is
    (1 - k_b/B)·||x_b||². Summing over blocks gives Definition 2.1 with
    k/d = k_b/B.
    """

    def sparse(x, key=None):
        d = x.size
        nb = -(-d // block)  # ceil
        pad = nb * block - d
        xp = jnp.pad(x, (0, pad))
        xb = xp.reshape(nb, block)
        kk = min(k_per_block, block)
        _, local_idx = jax.lax.top_k(jnp.abs(xb), kk)  # (nb, kk)
        vals = jnp.take_along_axis(xb, local_idx, axis=1)
        gidx = local_idx + (jnp.arange(nb, dtype=jnp.int32) * block)[:, None]
        # padded positions carry value 0: zero the value and clamp the index
        # so the scatter in the dense form is a no-op for them.
        in_range = gidx < d
        gidx = jnp.where(in_range, gidx, 0)
        vals = jnp.where(in_range, vals, 0.0)
        return vals.reshape(-1), gidx.reshape(-1).astype(jnp.int32)

    def dense_simple(x, key=None):
        vals, idx = sparse(x, key)
        # ``add`` (not ``set``): padded duplicates at index 0 carry value 0,
        # and real indices are unique within/across blocks.
        return jnp.zeros_like(x).at[idx].add(vals)

    return Compressor(
        name=f"blocktop_{k_per_block}x{block}",
        k_of=lambda d: min(k_per_block, block) * (-(-d // block)),
        dense=dense_simple,
        sparse=sparse,
        needs_rng=False,
    )


# ---------------------------------------------------------------------------
# random-coordinate ultra-sparsification (Remark 2.3)
# ---------------------------------------------------------------------------


def random_coordinate(k: float) -> Compressor:
    """Keep each coordinate independently with probability k/d, 0 < k <= d.

    Valid even for k < 1 (ultra-sparsification): on average fewer than one
    coordinate is transmitted per step. Support size is random, so only the
    dense form exists (the distributed runtime uses fixed-size operators).
    """

    def dense(x, key):
        p = jnp.minimum(k / x.size, 1.0)
        keep = jax.random.bernoulli(key, p, shape=x.shape)
        return jnp.where(keep, x, 0.0)

    return Compressor(
        name=f"randcoord_{k}", k_of=lambda d: min(k, d), dense=dense, sparse=None,
        needs_rng=True,
    )


# ---------------------------------------------------------------------------
# identity (k = d)
# ---------------------------------------------------------------------------


def identity() -> Compressor:
    def dense(x, key=None):
        return x

    def sparse(x, key=None):
        return x, jnp.arange(x.size, dtype=jnp.int32)

    return Compressor(
        name="identity", k_of=lambda d: d, dense=dense, sparse=sparse,
        needs_rng=False,
    )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def make_compressor(name: str, **kw) -> Compressor:
    """Factory from string names used in configs.

    Examples: ``top_k(k=10)``, ``rand_k(k=10)``, ``blockwise(k_per_block=2,
    block=1024)``, ``random_coordinate(k=0.5)``, ``identity``.
    """
    table = {
        "top_k": top_k,
        "rand_k": rand_k,
        "blockwise": blockwise_top_k,
        "random_coordinate": random_coordinate,
        "identity": identity,
    }
    if name not in table:
        raise ValueError(f"unknown compressor {name!r}; options: {sorted(table)}")
    return table[name](**kw)


def contraction_residual(x: Array, compressed: Array) -> Array:
    """||x - comp(x)||^2, the LHS of Definition 2.1 (before expectation)."""
    r = x - compressed
    return jnp.sum(jnp.square(r))
