"""Error-feedback memory (the 'Memory' in Mem-SGD).

The memory vector m_t accumulates the information suppressed by the
compression operator and re-injects it in later steps:

    u_t    = m_t + eta_t * g_t          (gradient scaled at INSERTION time)
    out_t  = comp_k(u_t)                (what is applied / transmitted)
    m_{t+1}= u_t - out_t                (residual kept)

This module provides the per-tensor primitive plus pytree-level helpers.
The per-worker replication used by PARALLEL-MEM-SGD / the distributed
runtime simply adds a leading worker axis to every leaf (handled in
``repro.core.distributed``).

``tree_memory_step`` dispatches one compressor per leaf — fine for a
handful of tensors, but models with hundreds of small leaves should use
the bucket-space memory in ``repro.core.buckets`` (one buffer per dtype
bucket, <= ~4 fused dispatches per step) via ``memsgd_bucketed`` /
``bucketed_sync_gradients``. The semantics here are the reference the
bucketed engine is tested against.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import Compressor

Array = jax.Array


class MemoryUpdate(NamedTuple):
    """Result of one error-feedback compression step on one tensor."""

    applied: Array  # dense comp_k(m + eta*g), same shape as g
    new_memory: Array  # m' = m + eta*g - applied
    sparse: Optional[Tuple[Array, Array]]  # (values, indices) if available


def memory_step(
    compressor: Compressor,
    memory: Array,
    grad: Array,
    eta: Array,
    key: Optional[Array] = None,
) -> MemoryUpdate:
    """One Mem-SGD line-4/6 step on a flat tensor (any shape; flattened)."""
    shape = grad.shape
    u = memory.reshape(-1) + eta * grad.reshape(-1).astype(memory.dtype)
    applied_flat = compressor.dense(u, key)
    # repro-lint: disable=RL003  (dense and sparse are two encodings of
    # the SAME compression: they must draw identical coordinates, so
    # sharing the key is required — not a reuse bug)
    sparse = compressor.sparse(u, key) if compressor.sparse is not None else None
    new_mem = u - applied_flat
    return MemoryUpdate(
        applied=applied_flat.reshape(shape),
        new_memory=new_mem.reshape(shape) if memory.ndim == len(shape) else new_mem,
        sparse=sparse,
    )


def init_memory(params, dtype=jnp.float32):
    """Zero memory pytree matching ``params`` (m_0 = 0)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype=dtype), params)


def tree_memory_step(
    compressor_for_leaf: Callable[[Array], Compressor],
    memory_tree,
    grad_tree,
    eta: Array,
    key: Optional[Array] = None,
):
    """Apply ``memory_step`` to every leaf of a gradient pytree.

    ``compressor_for_leaf`` maps a leaf (by its static shape) to the
    Compressor to use — this is how the framework expresses per-tensor k
    (e.g. k = ratio * leaf_size).

    Returns (applied_tree, new_memory_tree).
    """
    leaves, treedef = jax.tree.flatten(grad_tree)
    mem_leaves = treedef.flatten_up_to(memory_tree)
    if key is not None:
        keys = list(jax.random.split(key, len(leaves)))
    else:
        keys = [None] * len(leaves)
    applied, new_mem = [], []
    for g, m, k in zip(leaves, mem_leaves, keys):
        upd = memory_step(compressor_for_leaf(g), m, g, eta, k)
        applied.append(upd.applied)
        new_mem.append(upd.new_memory.reshape(m.shape))
    return treedef.unflatten(applied), treedef.unflatten(new_mem)
