"""Communication accounting: bits transmitted per step per scheme.

Reproduces the accounting used in the paper (§4.2, §4.3, Appendix B):

* dense SGD:     32 * d bits (fp32) — or 16*d for bf16.
* top-k/rand-k:  k * (32 + ceil(log2 d)) bits (value + index).
* QSGD with s levels (Alistarh et al., Thm 3.2 estimates):
      min( (log2(s) + 1) * d,  3*s*(s + sqrt(d)) + 32 ) bits.
* sparse-aware QSGD (RCV1 case): replace d by the gradient's nnz.

These are *accounting* functions (python floats), used by the benchmark
harness and by the distributed runtime's metrics.
"""
from __future__ import annotations

import math


def dense_bits(d: int, bits_per_value: int = 32) -> float:
    return float(bits_per_value * d)


def index_bits(d: int) -> int:
    return max(1, math.ceil(math.log2(max(2, d))))


def sparse_bits(d: int, k: float, bits_per_value: int = 32) -> float:
    """k (value, index) pairs."""
    return k * (bits_per_value + index_bits(d))


def qsgd_bits(d: int, s: int) -> float:
    """Paper Appendix B formula for s quantization levels."""
    naive = (math.log2(s) + 1.0) * d
    elias = 3.0 * s * (s + math.sqrt(d)) + 32.0
    return min(naive, elias)


def memsgd_message_bits(d: int, k: int, bits_per_value: int = 32) -> float:
    """Bits per worker per step for the distributed sparse all-gather."""
    return sparse_bits(d, k, bits_per_value)


def reduction_factor(d: int, k: float, bits_per_value: int = 32) -> float:
    """Communication reduction vs dense SGD (the paper's headline d/k gain)."""
    return dense_bits(d, bits_per_value) / sparse_bits(d, k, bits_per_value)
