"""Packed sparse wire codec + communication accounting.

Two layers live here:

1. **Accounting** (python floats/ints) — bits transmitted per step per
   scheme, reproducing the paper's formulas (§4.2, §4.3, Appendix B):

   * dense SGD:     32 * d bits (fp32) — or 16*d for bf16.
   * top-k/rand-k:  k * (bits_per_value + ceil(log2 d)) bits.
   * QSGD with s levels (Alistarh et al., Thm 3.2 estimates):
         min( (log2(s) + 1) * d,  3*s*(s + sqrt(d)) + 32 ) bits.
   * sparse-aware QSGD (RCV1 case): replace d by the gradient's nnz.

2. **Codec** (`WireSpec` + `encode`/`decode`) — the wire format the
   runtime actually transmits. A sparse message of k (value, index)
   pairs per row of an (rows, cols) buffer is bit-packed into a single
   dtype-uniform ``uint32`` buffer::

       [ header : HEADER_WORDS words ]
       [ values : rows * value_words words  (f32 bitcast | bf16 pairs) ]
       [ packed_indices : rows * index_words words
                          (row-local indices, ceil(log2 cols) bits each,
                           LSB-first within each 32-bit word) ]

   Everything is static given the ``WireSpec`` (derived from a
   ``BucketPlan`` bucket or a leaf's row layout), so encode/decode are
   pure shift/mask tensor ops — jit/vmap/shard_map compatible, with no
   python loops over k — and round-trip exactly: ``decode(encode(v, i))``
   recovers ``i`` bitwise and ``v`` bitwise in the wire value dtype.

   The unpacked baseline ships the same message as separate f32/int32
   arrays, i.e. k * (32 + 32) bits; the packed format costs
   k * (value_bits + ceil(log2 cols)) plus word-alignment slack — e.g.
   2.46x fewer bytes at k=64, cols=1024, bf16 values.

The accounting functions for the packed format are exact: the test suite
asserts ``WireSpec.nbits == 8 * encoded.nbytes``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

MAGIC = 0x53505257  # "SPRW"
VERSION = 1
HEADER_WORDS = 8
_DTYPE_CODES = {"float32": 0, "bfloat16": 1}
_DTYPE_NAMES = {v: k for k, v in _DTYPE_CODES.items()}
_KIND_CODES = {"sparse": 0, "dense": 1}
_KIND_NAMES = {v: k for k, v in _KIND_CODES.items()}


# ---------------------------------------------------------------------------
# accounting (paper Appendix B + exact packed-wire byte counts)
# ---------------------------------------------------------------------------


def dense_bits(d: int, bits_per_value: int = 32) -> float:
    return float(bits_per_value * d)


def index_bits(d: int) -> int:
    """Bits to address one of d positions (>= 1)."""
    return max(1, math.ceil(math.log2(max(2, d))))


def value_bits(value_dtype) -> int:
    """Wire bits per value for a sync value dtype (f32: 32, bf16: 16)."""
    return jnp.dtype(value_dtype).itemsize * 8


def sparse_bits(d: int, k: float, bits_per_value: int = 32) -> float:
    """k (value, index) pairs against a d-long address space.

    Pass ``bits_per_value=value_bits(cfg.value_dtype)`` so bf16 syncs are
    accounted at 16 bits/value, matching what the codec emits.
    """
    return k * (bits_per_value + index_bits(d))


def qsgd_bits(d: int, s: int) -> float:
    """Paper Appendix B formula for s quantization levels."""
    naive = (math.log2(s) + 1.0) * d
    elias = 3.0 * s * (s + math.sqrt(d)) + 32.0
    return min(naive, elias)


def memsgd_message_bits(d: int, k: int, value_dtype="float32") -> float:
    """Bits per worker per step for the distributed sparse all-gather."""
    return sparse_bits(d, k, value_bits(value_dtype))


def reduction_factor(d: int, k: float, bits_per_value: int = 32) -> float:
    """Communication reduction vs dense SGD (the paper's headline d/k gain)."""
    return dense_bits(d, bits_per_value) / sparse_bits(d, k, bits_per_value)


# ---------------------------------------------------------------------------
# packed wire codec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """Static layout of one packed wire message.

    ``kind="sparse"``: k (value, row-local index) pairs per row.
    ``kind="dense"``:  all cols values per row, no index section (used by
    the delta stream for uncompressed dense buckets); ``k`` is ignored.
    """

    rows: int
    cols: int
    k: int
    value_dtype: str = "float32"
    kind: str = "sparse"

    def __post_init__(self):
        if self.value_dtype not in _DTYPE_CODES:
            raise ValueError(
                f"unsupported wire value dtype {self.value_dtype!r}"
            )
        if self.kind not in _KIND_CODES:
            raise ValueError(f"unknown wire kind {self.kind!r}")
        if self.kind == "sparse" and not 1 <= self.k <= self.cols:
            raise ValueError(
                f"k={self.k} out of range for cols={self.cols}"
            )

    # -- static layout ------------------------------------------------------

    @property
    def n_sel(self) -> int:
        """Entries per row on the wire (k, or cols for dense messages)."""
        return self.cols if self.kind == "dense" else self.k

    @property
    def index_bits(self) -> int:
        return 0 if self.kind == "dense" else index_bits(self.cols)

    @property
    def value_bits(self) -> int:
        return value_bits(self.value_dtype)

    @property
    def value_words(self) -> int:
        """uint32 words per row for the value section."""
        return -(-(self.n_sel * self.value_bits) // 32)

    @property
    def index_words(self) -> int:
        """uint32 words per row for the packed index section."""
        return -(-(self.n_sel * self.index_bits) // 32)

    @property
    def words(self) -> int:
        return HEADER_WORDS + self.rows * (self.value_words + self.index_words)

    @property
    def nbytes(self) -> int:
        """Exact bytes of the encoded buffer."""
        return 4 * self.words

    @property
    def nbits(self) -> int:
        return 32 * self.words

    # -- self-describing header --------------------------------------------

    def header(self) -> Array:
        return jnp.array(
            [MAGIC, VERSION, self.rows, self.cols, self.n_sel,
             _DTYPE_CODES[self.value_dtype], _KIND_CODES[self.kind], 0],
            jnp.uint32,
        )

    @classmethod
    def from_header(cls, buf) -> "WireSpec":
        """Reconstruct the spec from a received buffer's header words
        (host-side; the payload layout is fully determined by it)."""
        import numpy as np

        h = np.asarray(buf[:HEADER_WORDS], dtype=np.uint32)
        if int(h[0]) != MAGIC or int(h[1]) != VERSION:
            raise ValueError(
                f"bad wire header magic/version {h[0]:#x}/{h[1]}"
            )
        return cls(
            rows=int(h[2]), cols=int(h[3]), k=int(h[4]),
            value_dtype=_DTYPE_NAMES[int(h[5])],
            kind=_KIND_NAMES[int(h[6])],
        )


def _pack_bits(ints: Array, nbits: int, words: int) -> Array:
    """(R, n) non-negative ints -> (R, words) uint32, an LSB-first bit
    stream of nbits-wide fields (vectorized shift/mask, no loop over n)."""
    rows, n = ints.shape
    bitpos = jnp.arange(nbits, dtype=jnp.uint32)
    bits = (ints.astype(jnp.uint32)[:, :, None] >> bitpos) & jnp.uint32(1)
    flat = bits.reshape(rows, n * nbits)
    pad = words * 32 - n * nbits
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    lanes = flat.reshape(rows, words, 32)
    shift = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(lanes << shift, axis=-1, dtype=jnp.uint32)


def _unpack_bits(packed: Array, nbits: int, n: int) -> Array:
    """(R, words) uint32 -> (R, n) uint32, inverse of ``_pack_bits``."""
    rows, words = packed.shape
    shift = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[:, :, None] >> shift) & jnp.uint32(1)
    fields = bits.reshape(rows, words * 32)[:, : n * nbits]
    fields = fields.reshape(rows, n, nbits)
    bitpos = jnp.arange(nbits, dtype=jnp.uint32)
    return jnp.sum(fields << bitpos, axis=-1, dtype=jnp.uint32)


def _pack_values(spec: WireSpec, vals: Array) -> Array:
    """(R, n_sel) values -> (R, value_words) uint32 (bitcast; bf16 packs
    two values per word, low half first)."""
    v = vals.astype(jnp.dtype(spec.value_dtype))
    if spec.value_dtype == "float32":
        return jax.lax.bitcast_convert_type(v, jnp.uint32)
    u16 = jax.lax.bitcast_convert_type(v, jnp.uint16).astype(jnp.uint32)
    pad = 2 * spec.value_words - spec.n_sel
    if pad:
        u16 = jnp.pad(u16, ((0, 0), (0, pad)))
    pairs = u16.reshape(vals.shape[0], spec.value_words, 2)
    return pairs[..., 0] | (pairs[..., 1] << jnp.uint32(16))


def _unpack_values(spec: WireSpec, packed: Array) -> Array:
    """(R, value_words) uint32 -> (R, n_sel) values in the wire dtype."""
    if spec.value_dtype == "float32":
        return jax.lax.bitcast_convert_type(packed, jnp.float32)
    lo = packed & jnp.uint32(0xFFFF)
    hi = packed >> jnp.uint32(16)
    u16 = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
    u16 = u16[:, : spec.n_sel].astype(jnp.uint16)
    return jax.lax.bitcast_convert_type(u16, jnp.bfloat16)


def encode(spec: WireSpec, vals: Array, idx: Optional[Array] = None) -> Array:
    """(values (rows, k), indices (rows, k)) -> flat uint32 wire buffer
    of exactly ``spec.words`` words (see the module docstring for the
    layout). For ``kind="dense"`` pass the (rows, cols) values only."""
    if vals.shape != (spec.rows, spec.n_sel):
        raise ValueError(
            f"values shape {vals.shape} != {(spec.rows, spec.n_sel)}"
        )
    sections = [spec.header(), _pack_values(spec, vals).reshape(-1)]
    if spec.kind == "sparse":
        if idx is None:
            raise ValueError("sparse wire message needs indices")
        if idx.shape != (spec.rows, spec.k):
            raise ValueError(
                f"index shape {idx.shape} != {(spec.rows, spec.k)}"
            )
        sections.append(
            _pack_bits(idx, spec.index_bits, spec.index_words).reshape(-1)
        )
    return jnp.concatenate(sections)


def decode(spec: WireSpec, buf: Array) -> Tuple[Array, Optional[Array]]:
    """Inverse of ``encode``: wire buffer -> (values (rows, n_sel) in the
    wire dtype, indices (rows, k) int32 | None for dense messages)."""
    if buf.shape != (spec.words,):
        raise ValueError(f"buffer shape {buf.shape} != {(spec.words,)}")
    off = HEADER_WORDS
    nv = spec.rows * spec.value_words
    vals = _unpack_values(
        spec, buf[off : off + nv].reshape(spec.rows, spec.value_words)
    )
    if spec.kind == "dense":
        return vals, None
    ni = spec.rows * spec.index_words
    packed_idx = buf[off + nv : off + nv + ni].reshape(
        spec.rows, spec.index_words
    )
    idx = _unpack_bits(packed_idx, spec.index_bits, spec.k)
    return vals, idx.astype(jnp.int32)
