"""Packed sparse wire codec + communication accounting.

Two layers live here:

1. **Accounting** (python floats/ints) — bits transmitted per step per
   scheme, reproducing the paper's formulas (§4.2, §4.3, Appendix B):

   * dense SGD:     32 * d bits (fp32) — or 16*d for bf16.
   * top-k/rand-k:  k * (bits_per_value + ceil(log2 d)) bits.
   * QSGD with s levels (Alistarh et al., Thm 3.2 estimates):
         min( (log2(s) + 1) * d,  3*s*(s + sqrt(d)) + 32 ) bits.
   * sparse-aware QSGD (RCV1 case): replace d by the gradient's nnz.

2. **Codec** (`WireSpec` + `encode`/`decode`) — the wire format the
   runtime actually transmits. A sparse message of k (value, index)
   pairs per row of an (rows, cols) buffer is bit-packed into a single
   dtype-uniform ``uint32`` buffer::

       [ header : HEADER_WORDS words ]
       [ values : rows * value_words words  (f32 bitcast | bf16 pairs) ]
       [ packed_indices : rows * index_words words
                          (row-local indices, ceil(log2 cols) bits each,
                           LSB-first within each 32-bit word) ]

   Header words: [magic, version, rows, cols, n_sel, dtype, kind,
   live_n]. ``live_n`` (word ``LIVE_N_WORD``) is the only DYNAMIC header
   field: a k-padded message (the runtime-k pod sync) is laid out at a
   static ``n_sel == k_max`` but carries only ``live_n <= k_max``
   meaningful pairs per row — the padded tail slots hold (-0.0, 0)
   (the additive identity; see ``kernels.topk_select.mask_live_k``)
   and scatter as exact no-ops. ``live_n == 0`` means "all n_sel slots live" (the
   historical layout, where word 7 was reserved-zero). A header-aware
   transport re-packs to ``live_n`` slots before hitting the network —
   ``repack``/``repad`` below are that transport's codec half: because
   selections are contract-ordered (the first ``live_n`` slots of a
   top-``k_max`` select ARE the top-``live_n`` select) and the padded
   tail is exactly (-0.0, 0), slicing the first ``live_n`` slots per row
   is lossless and re-padding restores the padded buffer BITWISE.
   ``message_nbytes(rows, cols, live_n, ...)`` is the effective size.

   Everything is static given the ``WireSpec`` (derived from a
   ``BucketPlan`` bucket or a leaf's row layout), so encode/decode are
   pure shift/mask tensor ops — jit/vmap/shard_map compatible, with no
   python loops over k — and round-trip exactly: ``decode(encode(v, i))``
   recovers ``i`` bitwise and ``v`` bitwise in the wire value dtype.

   The unpacked baseline ships the same message as separate f32/int32
   arrays, i.e. k * (32 + 32) bits; the packed format costs
   k * (value_bits + ceil(log2 cols)) plus word-alignment slack — e.g.
   2.46x fewer bytes at k=64, cols=1024, bf16 values.

   **Quantized value tier** (``WireSpec(quant=s)``): beside the f32/bf16
   tiers, a sparse message may carry QSGD-style s-level stochastically
   quantized values (Alistarh et al.; composed with top-k + memory per
   Qsparse-local-SGD). The value section per row becomes::

       [ row_norm : 1 word (f32 bitcast) ]
       [ codes    : ceil(n_sel * quant_bits / 32) words ]

   where each code is ``(level << 1) | sign_bit`` at
   ``quant_bits = 1 + ceil(log2(s+1))`` bits, and the decoded value is
   ``±norm * level / s``. The sign bit is stored SEPARATELY from the
   magnitude so a (-0.0, 0) padded tail slot (level 0, sign 1) survives
   the round trip as exact -0.0 — the runtime-k masking invariant holds
   through quantization. ``decode`` returns dequantized f32 values (the
   canonical dequant lives here, ``dequantize_rows``, so every consumer
   applies bit-identical math); ``decode_quant`` exposes the raw
   (norms, codes). Quantization itself (stochastic rounding, PRNG) is
   ``optim.qsgd.quantize_rows``.

The accounting functions for the packed format are exact: the test suite
asserts ``WireSpec.nbits == 8 * encoded.nbytes``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

MAGIC = 0x53505257  # "SPRW"
VERSION = 1
HEADER_WORDS = 8
# header slot carrying the runtime live entry count of a k-padded
# message (0 = every n_sel slot is live). The only header word that may
# be a traced value — all layout-defining words stay static.
LIVE_N_WORD = 7
_DTYPE_CODES = {"float32": 0, "bfloat16": 1}
_DTYPE_NAMES = {v: k for k, v in _DTYPE_CODES.items()}
_KIND_CODES = {"sparse": 0, "dense": 1}
_KIND_NAMES = {v: k for k, v in _KIND_CODES.items()}
# quantization levels ride in the high bits of the header dtype word
# (code | s << 8); capped so a code (sign bit + level) fits 16 bits
_QUANT_MAX = (1 << 15) - 1


# ---------------------------------------------------------------------------
# accounting (paper Appendix B + exact packed-wire byte counts)
# ---------------------------------------------------------------------------


def dense_bits(d: int, bits_per_value: int = 32) -> float:
    return float(bits_per_value * d)


def index_bits(d: int) -> int:
    """Bits to address one of d positions (>= 1)."""
    return max(1, math.ceil(math.log2(max(2, d))))


def value_bits(value_dtype) -> int:
    """Wire bits per value for a sync value dtype (f32: 32, bf16: 16)."""
    return jnp.dtype(value_dtype).itemsize * 8


def sparse_bits(d: int, k: float, bits_per_value: int = 32) -> float:
    """k (value, index) pairs against a d-long address space.

    Pass ``bits_per_value=value_bits(cfg.value_dtype)`` so bf16 syncs are
    accounted at 16 bits/value, matching what the codec emits.
    """
    return k * (bits_per_value + index_bits(d))


def qsgd_bits(d: int, s: int) -> float:
    """Paper Appendix B formula for s quantization levels."""
    naive = (math.log2(s) + 1.0) * d
    elias = 3.0 * s * (s + math.sqrt(d)) + 32.0
    return min(naive, elias)


def quant_code_bits(s: int) -> int:
    """Wire bits per quantized value: a sign bit plus a level in
    [0, s] — ``1 + ceil(log2(s+1))`` (s=1 ternary: 2 bits, s=15: 5)."""
    if s < 1:
        raise ValueError(f"quantization levels must be >= 1, got {s}")
    return 1 + max(1, math.ceil(math.log2(s + 1)))


def memsgd_message_bits(d: int, k: int, value_dtype="float32") -> float:
    """Bits per worker per step for the distributed sparse all-gather."""
    return sparse_bits(d, k, value_bits(value_dtype))


def reduction_factor(d: int, k: float, bits_per_value: int = 32) -> float:
    """Communication reduction vs dense SGD (the paper's headline d/k gain)."""
    return dense_bits(d, bits_per_value) / sparse_bits(d, k, bits_per_value)


def message_nbytes(
    rows: int, cols: int, k: int, value_dtype="float32",
    wire: str = "unpacked", quant: Optional[int] = None,
) -> int:
    """Exact bytes one sparse (rows, cols, k) message puts on the wire:
    the packed ``WireSpec`` buffer size (header + bit-packed sections) or
    the raw (value_dtype values, int32 indices) pair arrays. This is the
    single source of truth for per-gather-stage byte accounting — the
    two-level bucketed sync calls it once per level. ``quant=s`` accounts
    the s-level quantized value tier (packed wire only; the unpacked
    baseline ships dequantized values at full width)."""
    if wire == "packed":
        return WireSpec(
            rows, cols, k, jnp.dtype(value_dtype).name, quant=quant
        ).nbytes
    return rows * k * (jnp.dtype(value_dtype).itemsize + 4)


# ---------------------------------------------------------------------------
# packed wire codec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """Static layout of one packed wire message.

    ``kind="sparse"``: k (value, row-local index) pairs per row.
    ``kind="dense"``:  all cols values per row, no index section (used by
    the delta stream for uncompressed dense buckets); ``k`` is ignored.
    ``quant=s``: the value section carries s-level quantized codes plus
    one f32 row norm instead of full-width values (sparse only).
    """

    rows: int
    cols: int
    k: int
    value_dtype: str = "float32"
    kind: str = "sparse"
    quant: Optional[int] = None

    def __post_init__(self):
        if self.value_dtype not in _DTYPE_CODES:
            raise ValueError(
                f"unsupported wire value dtype {self.value_dtype!r}"
            )
        if self.kind not in _KIND_CODES:
            raise ValueError(f"unknown wire kind {self.kind!r}")
        if self.kind == "sparse" and not 1 <= self.k <= self.cols:
            raise ValueError(
                f"k={self.k} out of range for cols={self.cols}"
            )
        if self.quant is not None:
            if self.kind != "sparse":
                raise ValueError("quantized wire tier is sparse-only")
            if self.value_dtype != "float32":
                raise ValueError(
                    "quantized wire tier carries f32 row norms; "
                    f"value_dtype={self.value_dtype!r} is not composable"
                )
            if not 1 <= self.quant <= _QUANT_MAX:
                raise ValueError(
                    f"quant={self.quant} out of range [1, {_QUANT_MAX}]"
                )

    # -- static layout ------------------------------------------------------

    @property
    def n_sel(self) -> int:
        """Entries per row on the wire (k, or cols for dense messages)."""
        return self.cols if self.kind == "dense" else self.k

    @property
    def index_bits(self) -> int:
        return 0 if self.kind == "dense" else index_bits(self.cols)

    @property
    def value_bits(self) -> int:
        """Wire bits per value entry (code bits on the quantized tier)."""
        if self.quant is not None:
            return quant_code_bits(self.quant)
        return value_bits(self.value_dtype)

    @property
    def code_words(self) -> int:
        """uint32 words per row holding the packed quantized codes."""
        if self.quant is None:
            return 0
        return -(-(self.n_sel * self.value_bits) // 32)

    @property
    def value_words(self) -> int:
        """uint32 words per row for the value section (quantized tier:
        one f32 norm word + the packed codes)."""
        if self.quant is not None:
            return 1 + self.code_words
        return -(-(self.n_sel * self.value_bits) // 32)

    @property
    def index_words(self) -> int:
        """uint32 words per row for the packed index section."""
        return -(-(self.n_sel * self.index_bits) // 32)

    @property
    def words(self) -> int:
        return HEADER_WORDS + self.rows * (self.value_words + self.index_words)

    @property
    def nbytes(self) -> int:
        """Exact bytes of the encoded buffer."""
        return 4 * self.words

    @property
    def nbits(self) -> int:
        return 32 * self.words

    def with_value_dtype(self, value_dtype: str) -> "WireSpec":
        """Same message layout with another wire value dtype (the index
        section and k are unchanged; bf16 halves the value words)."""
        if self.quant is not None:
            raise ValueError(
                "quantized wire messages have no alternate value dtype; "
                "dequantize and re-encode instead"
            )
        return dataclasses.replace(self, value_dtype=value_dtype)

    # -- self-describing header --------------------------------------------

    def header(self) -> Array:
        dtype_word = _DTYPE_CODES[self.value_dtype] | ((self.quant or 0) << 8)
        return jnp.array(
            [MAGIC, VERSION, self.rows, self.cols, self.n_sel,
             dtype_word, _KIND_CODES[self.kind], 0],
            jnp.uint32,
        )

    @classmethod
    def from_header(cls, buf) -> "WireSpec":
        """Reconstruct the spec from a received buffer's header words
        (host-side; the payload layout is fully determined by it)."""
        import numpy as np

        h = np.asarray(buf[:HEADER_WORDS], dtype=np.uint32)
        if int(h[0]) != MAGIC or int(h[1]) != VERSION:
            raise ValueError(
                f"bad wire header magic/version {h[0]:#x}/{h[1]}"
            )
        return cls(
            rows=int(h[2]), cols=int(h[3]), k=int(h[4]),
            value_dtype=_DTYPE_NAMES[int(h[5]) & 0xFF],
            kind=_KIND_NAMES[int(h[6])],
            quant=(int(h[5]) >> 8) or None,
        )


def _pack_bits(ints: Array, nbits: int, words: int) -> Array:
    """(R, n) non-negative ints -> (R, words) uint32, an LSB-first bit
    stream of nbits-wide fields (vectorized shift/mask, no loop over n)."""
    rows, n = ints.shape
    bitpos = jnp.arange(nbits, dtype=jnp.uint32)
    bits = (ints.astype(jnp.uint32)[:, :, None] >> bitpos) & jnp.uint32(1)
    flat = bits.reshape(rows, n * nbits)
    pad = words * 32 - n * nbits
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    lanes = flat.reshape(rows, words, 32)
    shift = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(lanes << shift, axis=-1, dtype=jnp.uint32)


def _unpack_bits(packed: Array, nbits: int, n: int) -> Array:
    """(R, words) uint32 -> (R, n) uint32, inverse of ``_pack_bits``."""
    rows, words = packed.shape
    shift = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[:, :, None] >> shift) & jnp.uint32(1)
    fields = bits.reshape(rows, words * 32)[:, : n * nbits]
    fields = fields.reshape(rows, n, nbits)
    bitpos = jnp.arange(nbits, dtype=jnp.uint32)
    return jnp.sum(fields << bitpos, axis=-1, dtype=jnp.uint32)


def _pack_values(spec: WireSpec, vals: Array) -> Array:
    """(R, n_sel) values -> (R, value_words) uint32 (bitcast; bf16 packs
    two values per word, low half first)."""
    v = vals.astype(jnp.dtype(spec.value_dtype))
    if spec.value_dtype == "float32":
        return jax.lax.bitcast_convert_type(v, jnp.uint32)
    u16 = jax.lax.bitcast_convert_type(v, jnp.uint16).astype(jnp.uint32)
    pad = 2 * spec.value_words - spec.n_sel
    if pad:
        u16 = jnp.pad(u16, ((0, 0), (0, pad)))
    pairs = u16.reshape(vals.shape[0], spec.value_words, 2)
    return pairs[..., 0] | (pairs[..., 1] << jnp.uint32(16))


def _unpack_values(spec: WireSpec, packed: Array) -> Array:
    """(R, value_words) uint32 -> (R, n_sel) values in the wire dtype."""
    if spec.value_dtype == "float32":
        return jax.lax.bitcast_convert_type(packed, jnp.float32)
    lo = packed & jnp.uint32(0xFFFF)
    hi = packed >> jnp.uint32(16)
    u16 = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
    u16 = u16[:, : spec.n_sel].astype(jnp.uint16)
    return jax.lax.bitcast_convert_type(u16, jnp.bfloat16)


def dequantize_rows(norms: Array, codes: Array, s: int) -> Array:
    """Canonical dequant of the quantized wire tier: codes
    ``(level << 1) | sign`` -> ``±norm * level / s`` per row.

    Every consumer (in-jit decode, the sender's own-contribution densify,
    host repack) calls THIS function, so the memory absorbs exactly the
    quantization error the receivers see. A (level 0, sign 1) code
    dequantizes to -0.0 — the padded-tail identity survives."""
    sign = (codes & 1).astype(jnp.bool_)
    level = (codes >> 1).astype(jnp.float32)
    mag = norms.astype(jnp.float32)[..., None] * (level / float(s))
    return jnp.where(sign, -mag, mag)


def _pack_values_quant(spec: WireSpec, codes: Array, norms: Array) -> Array:
    """(R, n_sel) codes + (R,) norms -> (R, value_words) uint32: one
    bitcast f32 norm word, then the LSB-first packed code stream."""
    nw = jax.lax.bitcast_convert_type(
        norms.astype(jnp.float32), jnp.uint32
    )[:, None]
    cw = _pack_bits(codes.astype(jnp.uint32), spec.value_bits,
                    spec.code_words)
    return jnp.concatenate([nw, cw], axis=1)


def _unpack_values_quant(spec: WireSpec,
                         packed: Array) -> Tuple[Array, Array]:
    """Inverse of ``_pack_values_quant`` -> (norms (R,), codes (R, n_sel)
    int32)."""
    norms = jax.lax.bitcast_convert_type(packed[:, 0], jnp.float32)
    codes = _unpack_bits(packed[:, 1:], spec.value_bits, spec.n_sel)
    return norms, codes.astype(jnp.int32)


def encode(spec: WireSpec, vals: Array, idx: Optional[Array] = None,
           live_n: Optional[Array] = None,
           norms: Optional[Array] = None) -> Array:
    """(values (rows, k), indices (rows, k)) -> flat uint32 wire buffer
    of exactly ``spec.words`` words (see the module docstring for the
    layout). For ``kind="dense"`` pass the (rows, cols) values only.

    ``live_n`` (python int or traced scalar) stamps the runtime live
    entry count of a k-padded message into header word ``LIVE_N_WORD``
    — the layout stays the static ``spec``; only the first ``live_n``
    slots per row are meaningful (the padded tail must already be
    masked to (-0.0, 0) by the caller — see
    ``kernels.topk_select.mask_live_k``).

    On the quantized tier (``spec.quant``) ``vals`` are the integer
    CODES (rows, k) and ``norms`` (rows,) the f32 row norms."""
    if vals.shape != (spec.rows, spec.n_sel):
        raise ValueError(
            f"values shape {vals.shape} != {(spec.rows, spec.n_sel)}"
        )
    header = spec.header()
    if live_n is not None:
        header = header.at[LIVE_N_WORD].set(
            jnp.asarray(live_n).astype(jnp.uint32)
        )
    if spec.quant is not None:
        if norms is None:
            raise ValueError("quantized wire message needs row norms")
        if norms.shape != (spec.rows,):
            raise ValueError(
                f"norms shape {norms.shape} != {(spec.rows,)}"
            )
        packed_vals = _pack_values_quant(spec, vals, norms)
    elif norms is not None:
        raise ValueError("norms only apply to the quantized tier")
    else:
        packed_vals = _pack_values(spec, vals)
    sections = [header, packed_vals.reshape(-1)]
    if spec.kind == "sparse":
        if idx is None:
            raise ValueError("sparse wire message needs indices")
        if idx.shape != (spec.rows, spec.k):
            raise ValueError(
                f"index shape {idx.shape} != {(spec.rows, spec.k)}"
            )
        sections.append(
            _pack_bits(idx, spec.index_bits, spec.index_words).reshape(-1)
        )
    return jnp.concatenate(sections)


def decode(spec: WireSpec, buf: Array) -> Tuple[Array, Optional[Array]]:
    """Inverse of ``encode``: wire buffer -> (values (rows, n_sel) in the
    wire dtype, indices (rows, k) int32 | None for dense messages).

    On a CONCRETE buffer the dynamic header word is validated: a
    ``live_n`` exceeding the ``n_sel`` laid-out slots means the header
    and the payload disagree (corruption or a spec mismatch), and
    silently decoding would hand the caller padded garbage as live
    data — raise instead. Traced buffers (the in-jit decode path) skip
    the check; their live count is clamped by the producer."""
    if buf.shape != (spec.words,):
        raise ValueError(f"buffer shape {buf.shape} != {(spec.words,)}")
    if not isinstance(buf, jax.core.Tracer):
        import numpy as np

        ln = int(np.asarray(buf[LIVE_N_WORD], dtype=np.uint32))
        if ln > spec.n_sel:
            raise ValueError(
                f"corrupt wire header: live_n={ln} exceeds the "
                f"{spec.n_sel} laid-out slots per row"
            )
    off = HEADER_WORDS
    nv = spec.rows * spec.value_words
    packed_vals = buf[off : off + nv].reshape(spec.rows, spec.value_words)
    if spec.quant is not None:
        norms, codes = _unpack_values_quant(spec, packed_vals)
        vals = dequantize_rows(norms, codes, spec.quant)
    else:
        vals = _unpack_values(spec, packed_vals)
    if spec.kind == "dense":
        return vals, None
    ni = spec.rows * spec.index_words
    packed_idx = buf[off + nv : off + nv + ni].reshape(
        spec.rows, spec.index_words
    )
    idx = _unpack_bits(packed_idx, spec.index_bits, spec.k)
    return vals, idx.astype(jnp.int32)


def decode_quant(spec: WireSpec, buf: Array
                 ) -> Tuple[Array, Array, Array]:
    """Raw reader for the quantized tier: wire buffer -> (norms (rows,),
    codes (rows, k) int32, indices (rows, k) int32), without
    dequantizing — the repack transport and tests need the exact code
    stream."""
    if spec.quant is None:
        raise ValueError("decode_quant wants a quantized WireSpec")
    if buf.shape != (spec.words,):
        raise ValueError(f"buffer shape {buf.shape} != {(spec.words,)}")
    off = HEADER_WORDS
    nv = spec.rows * spec.value_words
    norms, codes = _unpack_values_quant(
        spec, buf[off : off + nv].reshape(spec.rows, spec.value_words)
    )
    ni = spec.rows * spec.index_words
    packed_idx = buf[off + nv : off + nv + ni].reshape(
        spec.rows, spec.index_words
    )
    idx = _unpack_bits(packed_idx, spec.index_bits, spec.k)
    return norms, codes, idx.astype(jnp.int32)


def live_n_of(buf) -> Optional[int]:
    """Host-side reader for the dynamic live entry count of a received
    buffer: the number of meaningful slots per row, or ``None`` when the
    message was encoded without one (word ``LIVE_N_WORD`` == 0, i.e.
    every ``n_sel`` slot is live). Raises on a header whose live count
    exceeds its own ``n_sel`` layout word — an inconsistent message must
    not be silently read as fully live."""
    import numpy as np

    h = np.asarray(buf[:HEADER_WORDS], dtype=np.uint32)
    n = int(h[LIVE_N_WORD])
    n_sel = int(h[4])
    if n > n_sel:
        raise ValueError(
            f"corrupt wire header: live_n={n} exceeds the {n_sel} "
            f"laid-out slots per row"
        )
    return n or None


def repack_spec(spec: WireSpec, live_n: int) -> WireSpec:
    """Layout of the compacted message a k-padded ``spec`` shrinks to at
    ``live_n`` live slots per row: the same (rows, cols, dtype) at
    ``k = max(1, live_n)`` (the codec ships at least one slot; a
    zero-live message carries one (-0.0, 0) no-op pair)."""
    if spec.kind != "sparse":
        raise ValueError("repack applies to sparse wire messages only")
    if not 0 <= live_n <= spec.n_sel:
        raise ValueError(
            f"live_n={live_n} out of range for n_sel={spec.n_sel}"
        )
    return dataclasses.replace(spec, k=max(1, int(live_n)))


def repack(spec: WireSpec, buf: Array,
           live_n: Optional[int] = None) -> Tuple[WireSpec, Array]:
    """Compact a k-padded message down to its live payload before it
    crosses a slow link: -> ``(small_spec, small_buf)`` laid out at
    ``repack_spec(spec, live_n)``.

    ``live_n`` defaults to the buffer's own header word (host-side
    read); ``None``-live (header 0 = all slots live) and ``live_n >=
    n_sel`` messages pass through unchanged. The compaction is LOSSLESS:
    selections are contract-ordered, so the first ``live_n`` slots per
    row are exactly the live pairs and the dropped tail is the (-0.0, 0)
    identity. The small header keeps the original live count, so
    ``repad`` restores the padded buffer bitwise."""
    if spec.kind != "sparse":
        return spec, buf
    if live_n is None:
        live_n = live_n_of(buf)
        if live_n is None:
            return spec, buf
    live_n = int(live_n)
    if live_n >= spec.n_sel:
        return spec, buf
    small = repack_spec(spec, live_n)
    if spec.quant is not None:
        norms, codes, idx = decode_quant(spec, buf)
        return small, encode(
            small, codes[:, : small.k], idx[:, : small.k],
            live_n=live_n, norms=norms,
        )
    vals, idx = decode(spec, buf)
    return small, encode(
        small, vals[:, : small.k], idx[:, : small.k], live_n=live_n
    )


def repad(spec: WireSpec, small_spec: WireSpec, small_buf: Array) -> Array:
    """Inverse of ``repack``: re-expand a compacted message to the
    static padded ``spec`` layout the in-jit consumer expects, bitwise
    equal to the buffer ``repack`` was given — tail slots refill with
    the (-0.0, 0) identity and the dynamic header word is carried over
    from the small message."""
    if small_spec == spec:
        return small_buf
    if spec.kind != "sparse" or small_spec.kind != "sparse":
        raise ValueError("repad applies to sparse wire messages only")
    if (small_spec.rows, small_spec.cols, small_spec.value_dtype) != (
            spec.rows, spec.cols, spec.value_dtype):
        raise ValueError(
            f"repacked layout {small_spec} does not shrink {spec}"
        )
    if small_spec.k > spec.n_sel:
        raise ValueError(
            f"repacked k={small_spec.k} exceeds padded n_sel={spec.n_sel}"
        )
    if small_spec.quant != spec.quant:
        raise ValueError(
            f"repacked quant tier {small_spec.quant} does not match "
            f"{spec.quant}"
        )
    import numpy as np

    raw_live = int(np.asarray(small_buf[LIVE_N_WORD], dtype=np.uint32))
    pad = spec.n_sel - small_spec.k
    if spec.quant is not None:
        norms, codes, idx = decode_quant(small_spec, small_buf)
        # code 1 = (level 0, sign 1) — dequantizes to the -0.0 identity
        codes = jnp.concatenate(
            [codes, jnp.ones((spec.rows, pad), jnp.int32)], axis=1
        )
        idx = jnp.concatenate(
            [idx, jnp.zeros((spec.rows, pad), jnp.int32)], axis=1
        )
        return encode(spec, codes, idx, live_n=raw_live, norms=norms)
    vals, idx = decode(small_spec, small_buf)
    dtype = jnp.dtype(spec.value_dtype)
    vals = jnp.concatenate(
        [vals, jnp.full((spec.rows, pad), -0.0, dtype)], axis=1
    )
    idx = jnp.concatenate(
        [idx, jnp.zeros((spec.rows, pad), jnp.int32)], axis=1
    )
    return encode(spec, vals, idx, live_n=raw_live)


def transcode(
    spec: WireSpec, buf: Array, value_dtype: str = "bfloat16"
) -> Array:
    """Re-encode a wire message's VALUE section in another dtype without
    touching the (already minimal) index section — the fan-out hub's
    lossy tier: one f32 message from the trainer, transcoded once, serves
    every bandwidth-starved bf16 replica. f32 -> bf16 is
    round-to-nearest-even truncation (lossy); bf16 -> f32 is exact.
    Pure tensor ops — jit-safe, so the hub can fold it into its publish
    path. The returned buffer's layout is the static
    ``spec.with_value_dtype(value_dtype)``."""
    new_spec = spec.with_value_dtype(value_dtype)
    vals, idx = decode(spec, buf)
    return encode(new_spec, vals, idx)


# ---------------------------------------------------------------------------
# snapshot records (wire-compressed buffer dumps; checkpoint + fan-out resync)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SnapshotRecord:
    """One (rows, cols) buffer serialized through the packed wire codec.

    Three encodings, chosen by ``snapshot_encode``:

    * exact sparse  — every entry differing from the reference (``base``
      buffer, or zero) is on the wire; decode reproduces the buffer
      BITWISE. Used for parameter buckets, whose drift from the boot
      checkpoint has bounded support under sparse training.
    * lossy sparse  — per-row top-k by magnitude (``k=`` cap); decode
      reproduces the selected support bitwise and zeros the rest.
      ``dropped_frac`` reports the discarded mass. Used for the
      heavy-tailed error-feedback memory.
    * dense fallback — when the sparse layout would not be smaller than
      the dense one, the buffer ships as a ``kind="dense"`` message
      (exact in the wire dtype).
    """

    spec: WireSpec
    buf: Array  # uint32 wire buffer of exactly spec.words words
    vs_base: bool  # decode overlays onto the base buffer (else onto zeros)
    exact: bool  # True when decode(..) reproduces the buffer bitwise
    dense_nbytes: int  # what the dense f32-per-item dump would have cost
    dropped_frac: float  # squared-mass fraction lost (0.0 when exact)

    @property
    def nbytes(self) -> int:
        """Exact bytes on the wire / in the checkpoint file."""
        return self.spec.nbytes


def _bitpattern(x: Array) -> Array:
    """Values -> unsigned bit patterns, so the support mask sees every
    BITWISE difference (float != misses -0.0 vs +0.0, which would break
    the exact records' bitwise-restore guarantee)."""
    nbits = jnp.dtype(x.dtype).itemsize * 8
    return jax.lax.bitcast_convert_type(
        x, {16: jnp.uint16, 32: jnp.uint32}[nbits]
    )


def _snapshot_indices_exact(mask: Array, k: int) -> Array:
    """Per row: the indices of True entries (ascending), padded with
    False-entry indices (ascending). All indices within a row are
    distinct, so a scatter-SET with the buffer's own values at these
    positions is always exact."""
    order = jnp.argsort(~mask, axis=1, stable=True)
    return order[:, :k].astype(jnp.int32)


def snapshot_encode(
    buf: Array,
    base: Optional[Array] = None,
    *,
    k: Optional[int] = None,
    value_dtype: Optional[str] = None,
) -> SnapshotRecord:
    """Serialize one 2D buffer through the packed codec.

    ``base``: encode only entries that differ from ``base`` (exact
    delta-vs-reference; decode needs the same base). ``k``: lossy per-row
    top-|.| cap (only without ``base``). With neither, every nonzero is
    encoded exactly. Falls back to a dense message whenever sparse would
    not be smaller — so the record is never worse than a dense dump plus
    one header."""
    if buf.ndim != 2:
        raise ValueError(f"snapshot_encode wants a 2D buffer, got {buf.shape}")
    rows, cols = buf.shape
    vd = value_dtype or jnp.dtype(buf.dtype).name
    if base is not None and k is not None:
        raise ValueError("lossy k-cap and diff-vs-base are exclusive")
    if base is not None and base.shape != buf.shape:
        raise ValueError(f"base shape {base.shape} != buffer {buf.shape}")
    dense_nbytes = int(rows * cols * 4)

    if base is not None:
        mask = _bitpattern(buf) != _bitpattern(base)
    else:
        mask = _bitpattern(buf) != 0  # -0.0 counts as a set entry
    nnz = int(jnp.max(jnp.sum(mask, axis=1)))
    need_k = max(1, nnz)
    k_use = need_k if k is None else max(1, min(k, cols))
    exact = k is None or need_k <= k_use
    if exact:
        k_use = need_k  # never ship more slots than the support needs

    sparse_spec = WireSpec(rows, cols, min(k_use, cols), vd)
    dense_spec = WireSpec(rows, cols, cols, vd, kind="dense")
    if sparse_spec.nbytes >= dense_spec.nbytes:
        # dense fallback: exact (in the wire dtype), one header of slack
        lossless = vd == jnp.dtype(buf.dtype).name
        return SnapshotRecord(
            spec=dense_spec, buf=encode(dense_spec, buf.astype(jnp.dtype(vd))),
            vs_base=False, exact=lossless, dense_nbytes=dense_nbytes,
            dropped_frac=0.0,
        )
    if exact:
        idx = _snapshot_indices_exact(mask, sparse_spec.k)
        dropped = 0.0
    else:  # lossy top-k by magnitude (base is None here)
        _, idx = jax.lax.top_k(jnp.abs(buf.astype(jnp.float32)), sparse_spec.k)
        idx = idx.astype(jnp.int32)
        total = float(jnp.sum(jnp.square(buf.astype(jnp.float32))))
        kept = float(
            jnp.sum(
                jnp.square(
                    jnp.take_along_axis(buf, idx, axis=1).astype(jnp.float32)
                )
            )
        )
        dropped = 0.0 if total == 0.0 else max(0.0, 1.0 - kept / total)
    vals = jnp.take_along_axis(buf, idx, axis=1)
    return SnapshotRecord(
        spec=sparse_spec, buf=encode(sparse_spec, vals, idx),
        vs_base=base is not None,
        exact=exact and vd == jnp.dtype(buf.dtype).name,
        dense_nbytes=dense_nbytes, dropped_frac=dropped,
    )


def snapshot_decode(rec: SnapshotRecord, base: Optional[Array] = None) -> Array:
    """Inverse of ``snapshot_encode``: record (+ the same ``base`` for
    ``vs_base`` records) -> the (rows, cols) buffer, bitwise for exact
    records."""
    spec = rec.spec
    vals, idx = decode(spec, rec.buf)
    if spec.kind == "dense":
        return vals
    if rec.vs_base:
        if base is None:
            raise ValueError("record was encoded vs a base buffer")
        out = base.astype(jnp.dtype(spec.value_dtype))
    else:
        out = jnp.zeros((spec.rows, spec.cols), jnp.dtype(spec.value_dtype))
    rows_iota = jnp.arange(spec.rows, dtype=jnp.int32)[:, None]
    return out.at[rows_iota, idx].set(vals)
