"""Learning-rate schedules for the large-model training driver."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array
Schedule = Callable[[Array], Array]


def constant(v: float) -> Schedule:
    return lambda t: jnp.asarray(v, jnp.float32)


def inverse_time(gamma: float, lam: float, a: float) -> Schedule:
    """gamma / (lam * (t + a)) — the paper's schedule family."""
    return lambda t: gamma / (lam * (t.astype(jnp.float32) + a))


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0) -> Schedule:
    def sched(t: Array) -> Array:
        tf = t.astype(jnp.float32)
        warm = peak * tf / max(1, warmup_steps)
        frac = jnp.clip(
            (tf - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0
        )
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(tf < warmup_steps, warm, cos)

    return sched


def linear_decay(peak: float, total_steps: int) -> Schedule:
    def sched(t: Array) -> Array:
        frac = jnp.clip(t.astype(jnp.float32) / max(1, total_steps), 0.0, 1.0)
        return peak * (1.0 - frac)

    return sched
