"""Adam / AdamW (Kingma & Ba '14), used by the large-model training driver."""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import GradientTransformation

Array = jax.Array
Schedule = Callable[[Array], Array]


class AdamState(NamedTuple):
    count: Array
    mu: object
    nu: object


def adam(
    eta: Schedule | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> GradientTransformation:
    sched = eta if callable(eta) else (lambda t: jnp.asarray(eta, jnp.float32))

    def init(params):
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        return AdamState(count=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())

    def update(grads, state: AdamState, params=None, **_):
        t = state.count + 1
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        e = sched(state.count)

        def leaf(m, v, p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and params is not None:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-e * step).astype(p.dtype if p is not None else step.dtype)

        if params is not None:
            updates = jax.tree.map(leaf, mu, nu, params)
        else:
            updates = jax.tree.map(lambda m, v: leaf(m, v, m), mu, nu)
        return updates, AdamState(count=t, mu=mu, nu=nu)

    return GradientTransformation(init, update)
