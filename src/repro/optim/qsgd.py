"""QSGD (Alistarh et al., NIPS 2017) — the paper's quantization baseline,
and the quantizer half of the Qsparse-local-SGD composition.

QSGD quantizes each gradient coordinate to one of s levels of |g|/||g||_2
with stochastic rounding so that the quantized vector is an UNBIASED
estimator of g (no memory needed). The paper (§4.3) compares Mem-SGD
against QSGD with s = 2^b levels, b in {2, 4, 8}.

Q_s(g)_i = ||g||_2 * sign(g_i) * xi_i(g, s)

where xi_i = (l+1)/s with probability |g_i|/||g|| * s - l, else l/s,
with l = floor(|g_i|/||g|| * s).

``quantize_rows`` is the bucket-space form: normalization is PER ROW of
an (..., C) buffer (so it composes with the (R, C) bucket layout and the
top-k's (rows, k) selections), the PRNG key is a threaded argument (no
python-side seed state — callers fold step count / bucket / worker into
the key themselves), and the output is the wire-code representation of
``core.encoding``'s quantized tier: ``(level << 1) | sign_bit`` plus the
f32 row norm. Dequantization (``encoding.dequantize_rows``) is the
single shared formula, so the sender's own-contribution densify, the
in-jit decode, and the host repack all see bit-identical values — the
error-feedback memory absorbs exactly the quantization error that ships.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.encoding import dequantize_rows
from repro.optim.base import GradientTransformation

Array = jax.Array
Schedule = Callable[[Array], Array]


def quantize_rows(vals: Array, s: int, key: Array) -> Tuple[Array, Array]:
    """Stochastic s-level quantization of (..., C) rows -> (norms (...,),
    codes (..., C) int32).

    Unbiased per entry: E[dequantize_rows(norms, codes, s)] == vals.
    jit/vmap/shard_map-safe — pure tensor ops on a threaded ``key``.
    Sign and level are coded separately, so an exact -0.0 input (the
    runtime-k padded tail) maps to code 1 = (level 0, sign 1), which
    dequantizes back to -0.0: masking survives quantization. A zero-norm
    row emits all-zero levels (its entries are all ±0 already)."""
    v = vals.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(jnp.square(v), axis=-1))
    safe = jnp.where(norm > 0, norm, 1.0)
    r = jnp.abs(v) / safe[..., None] * s  # in [0, s]
    lo = jnp.floor(r)
    p_up = jnp.clip(r - lo, 0.0, 1.0)
    up = jax.random.bernoulli(key, p_up, shape=v.shape)
    level = jnp.minimum(lo + up.astype(jnp.float32), float(s))
    sign = jnp.signbit(v).astype(jnp.int32)
    codes = (level.astype(jnp.int32) << 1) | sign
    return norm, codes


def qsgd_quantize(g: Array, s: int, key: Array) -> Array:
    """Unbiased s-level stochastic quantization (quantize + dequantize).

    Rows are the trailing axis; pass a 1-D vector for the paper's
    whole-vector normalization."""
    norm, codes = quantize_rows(g, s, key)
    return dequantize_rows(norm, codes, s).astype(g.dtype)


class QSGDState(NamedTuple):
    count: Array
    rng: Array


def qsgd(eta: Schedule | float, s: int, seed: int = 0) -> GradientTransformation:
    """SGD with QSGD-quantized gradients (per-leaf quantization)."""
    sched = eta if callable(eta) else (lambda t: jnp.asarray(eta, jnp.float32))

    def init(params):
        return QSGDState(count=jnp.zeros((), jnp.int32), rng=jax.random.PRNGKey(seed))

    def update(grads, state: QSGDState, params=None, **_):
        rng, sub = jax.random.split(state.rng)
        leaves, treedef = jax.tree.flatten(grads)
        keys = jax.random.split(sub, len(leaves))
        e = sched(state.count)
        out = [
            (-e * qsgd_quantize(g.reshape(-1), s, k).reshape(g.shape)).astype(g.dtype)
            for g, k in zip(leaves, keys)
        ]
        return treedef.unflatten(out), QSGDState(count=state.count + 1, rng=rng)

    return GradientTransformation(init, update)
