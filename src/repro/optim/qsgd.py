"""QSGD (Alistarh et al., NIPS 2017) — the paper's quantization baseline.

QSGD quantizes each gradient coordinate to one of s levels of |g|/||g||_2
with stochastic rounding so that the quantized vector is an UNBIASED
estimator of g (no memory needed). The paper (§4.3) compares Mem-SGD
against QSGD with s = 2^b levels, b in {2, 4, 8}.

Q_s(g)_i = ||g||_2 * sign(g_i) * xi_i(g, s)

where xi_i = (l+1)/s with probability |g_i|/||g|| * s - l, else l/s,
with l = floor(|g_i|/||g|| * s).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import GradientTransformation

Array = jax.Array
Schedule = Callable[[Array], Array]


def qsgd_quantize(g: Array, s: int, key: Array) -> Array:
    """Unbiased s-level stochastic quantization of a flat vector."""
    norm = jnp.linalg.norm(g)
    safe = jnp.where(norm > 0, norm, 1.0)
    r = jnp.abs(g) / safe * s  # in [0, s]
    lo = jnp.floor(r)
    p_up = r - lo  # probability of rounding up
    up = jax.random.bernoulli(key, jnp.clip(p_up, 0.0, 1.0), shape=g.shape)
    level = (lo + up.astype(lo.dtype)) / s
    q = norm * jnp.sign(g) * level
    return jnp.where(norm > 0, q, jnp.zeros_like(g))


class QSGDState(NamedTuple):
    count: Array
    rng: Array


def qsgd(eta: Schedule | float, s: int, seed: int = 0) -> GradientTransformation:
    """SGD with QSGD-quantized gradients (per-leaf quantization)."""
    sched = eta if callable(eta) else (lambda t: jnp.asarray(eta, jnp.float32))

    def init(params):
        return QSGDState(count=jnp.zeros((), jnp.int32), rng=jax.random.PRNGKey(seed))

    def update(grads, state: QSGDState, params=None, **_):
        rng, sub = jax.random.split(state.rng)
        leaves, treedef = jax.tree.flatten(grads)
        keys = jax.random.split(sub, len(leaves))
        e = sched(state.count)
        out = [
            (-e * qsgd_quantize(g.reshape(-1), s, k).reshape(g.shape)).astype(g.dtype)
            for g, k in zip(leaves, keys)
        ]
        return treedef.unflatten(out), QSGDState(count=state.count + 1, rng=rng)

    return GradientTransformation(init, update)
