"""SGD and momentum transformations (descent direction, additive updates)."""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import GradientTransformation

Array = jax.Array
Schedule = Callable[[Array], Array]


class SGDState(NamedTuple):
    count: Array


def sgd(eta: Schedule | float) -> GradientTransformation:
    """x' = x - eta_t * g."""
    sched = eta if callable(eta) else (lambda t: jnp.asarray(eta, jnp.float32))

    def init(params):
        return SGDState(count=jnp.zeros((), jnp.int32))

    def update(grads, state: SGDState, params=None, **_):
        e = sched(state.count)
        return (
            jax.tree.map(lambda g: -e * g, grads),
            SGDState(count=state.count + 1),
        )

    return GradientTransformation(init, update)


class MomentumState(NamedTuple):
    count: Array
    velocity: object


def sgd_momentum(
    eta: Schedule | float, beta: float = 0.9, nesterov: bool = False
) -> GradientTransformation:
    sched = eta if callable(eta) else (lambda t: jnp.asarray(eta, jnp.float32))

    def init(params):
        return MomentumState(
            count=jnp.zeros((), jnp.int32),
            velocity=jax.tree.map(jnp.zeros_like, params),
        )

    def update(grads, state: MomentumState, params=None, **_):
        v = jax.tree.map(lambda vel, g: beta * vel + g, state.velocity, grads)
        if nesterov:
            d = jax.tree.map(lambda vel, g: beta * vel + g, v, grads)
        else:
            d = v
        e = sched(state.count)
        return (
            jax.tree.map(lambda x: -e * x, d),
            MomentumState(count=state.count + 1, velocity=v),
        )

    return GradientTransformation(init, update)


def add_weight_decay(lam: float) -> GradientTransformation:
    """g <- g + lam * params (L2 regularization as in the paper's logreg)."""

    def update(grads, state, params=None, **_):
        assert params is not None, "weight decay needs params"
        return jax.tree.map(lambda g, p: g + lam * p, grads, params), state

    return GradientTransformation(lambda p: (), update)


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def update(grads, state, params=None, **_):
        leaves = jax.tree.leaves(grads)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
        scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
        return jax.tree.map(lambda g: g * scale, grads), state

    return GradientTransformation(lambda p: (), update)
