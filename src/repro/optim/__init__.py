from repro.optim.base import (
    GradientTransformation,
    apply_updates,
    chain,
    identity_tx,
    scale,
    scale_by_schedule,
)
from repro.optim.sgd import sgd, sgd_momentum, add_weight_decay, clip_by_global_norm
from repro.optim.adam import adam
from repro.optim.qsgd import qsgd, qsgd_quantize
from repro.optim import schedules

__all__ = [
    "GradientTransformation",
    "apply_updates",
    "chain",
    "identity_tx",
    "scale",
    "scale_by_schedule",
    "sgd",
    "sgd_momentum",
    "add_weight_decay",
    "clip_by_global_norm",
    "adam",
    "qsgd",
    "qsgd_quantize",
    "schedules",
]
