"""Minimal optax-style GradientTransformation protocol, built in-repo.

optax is not available offline; Mem-SGD and the baselines compose through
this tiny protocol instead. Semantics match optax:

    state = tx.init(params)
    updates, state = tx.update(grads, state, params=None, **extra)
    params = apply_updates(params, updates)       # params + updates

Updates returned by transformations are ADDITIVE (already negated where a
descent step is intended), exactly like optax.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class GradientTransformation(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (updates, state, params=None, **extra)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def chain(*txs: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(tx.init(params) for tx in txs)

    def update(updates, state, params=None, **extra):
        new_state = []
        for tx, s in zip(txs, state):
            updates, s = tx.update(updates, s, params=params, **extra)
            new_state.append(s)
        return updates, tuple(new_state)

    return GradientTransformation(init, update)


def identity_tx() -> GradientTransformation:
    return GradientTransformation(lambda p: (), lambda u, s, params=None, **_: (u, s))


class ScaleByScheduleState(NamedTuple):
    count: jax.Array


def scale(factor: float) -> GradientTransformation:
    return GradientTransformation(
        lambda p: (),
        lambda u, s, params=None, **_: (
            jax.tree.map(lambda x: factor * x, u),
            s,
        ),
    )


def scale_by_schedule(schedule: Callable[[jax.Array], jax.Array]) -> GradientTransformation:
    """Multiply updates by schedule(count); count increments per update."""

    def init(params):
        return ScaleByScheduleState(count=jnp.zeros((), jnp.int32))

    def update(updates, state, params=None, **_):
        s = schedule(state.count)
        updates = jax.tree.map(lambda x: s * x, updates)
        return updates, ScaleByScheduleState(count=state.count + 1)

    return GradientTransformation(init, update)
