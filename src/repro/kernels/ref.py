"""Pure-jnp oracles for the Pallas kernels.

Semantics contract (shared by kernel and oracle):

* ``row_topk_ref(x, k)`` — per-row top-k by |.|: for each row of x (R, C),
  the k largest-magnitude entries, returned as (values (R,k), idx (R,k)).
  Ties broken by LOWEST index (matches the kernel's iterative argmax,
  which scans from index 0). This is the row-block contraction operator of
  ``repro.core.distributed`` (a k-contraction; per-row top-k dominates
  per-row rand-k, which equals rand_k in expectation — Def. 2.1 holds
  with k/d = k/C).

* ``fused_memsgd_ref(m, g, eta, k)`` — the fused Mem-SGD hot loop:
      u      = m + eta * g
      vals,i = row_topk(u, k)
      m'     = u with the selected entries zeroed
  returning (m', vals, idx).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def row_topk_ref(x: Array, k: int) -> Tuple[Array, Array]:
    """Oracle with lowest-index tie-breaking to match the kernel."""
    # jax.lax.top_k on (|x|, then -index) composite: emulate by biasing
    # equal magnitudes with a tiny index-dependent epsilon is fragile;
    # instead replicate the kernel's iterative argmax exactly.
    R, C = x.shape
    absx = jnp.abs(x).astype(jnp.float32)
    neg_inf = jnp.asarray(-jnp.inf, jnp.float32)

    def step(carry, _):
        vals, idxs, absm, i = carry
        j = jnp.argmax(absm, axis=1)  # first max (lowest index on ties)
        v = jnp.take_along_axis(x, j[:, None], axis=1)[:, 0]
        vals = vals.at[:, i].set(v)
        idxs = idxs.at[:, i].set(j.astype(jnp.int32))
        absm = absm.at[jnp.arange(R), j].set(neg_inf)
        return (vals, idxs, absm, i + 1), None

    vals0 = jnp.zeros((R, k), x.dtype)
    idxs0 = jnp.zeros((R, k), jnp.int32)
    (vals, idxs, _, _), _ = jax.lax.scan(
        step, (vals0, idxs0, absx, 0), None, length=k
    )
    return vals, idxs


def densify_rows_ref(x_like: Array, vals: Array, idx: Array) -> Array:
    """Scatter per-row (vals, idx) pairs back to a dense (R, C) array —
    the inverse of ``row_topk_ref`` restricted to the selected support."""
    R = x_like.shape[0]
    return jnp.zeros_like(x_like).at[
        jnp.arange(R)[:, None], idx
    ].set(vals.astype(x_like.dtype))


def fused_memsgd_ref(m: Array, g: Array, eta, k: int
                     ) -> Tuple[Array, Array, Array]:
    u = m + jnp.asarray(eta, m.dtype) * g.astype(m.dtype)
    vals, idxs = row_topk_ref(u, k)
    R = u.shape[0]
    new_m = u.at[jnp.arange(R)[:, None], idxs].set(0)
    return new_m, vals, idxs
