"""Pallas TPU kernels for the Mem-SGD compression hot-spot.

* ``topk_select``  — per-row top-k selection (pl.pallas_call + BlockSpec).
* ``fused_memsgd`` — fused memory update + compression (scalar-prefetch eta).
* ``ops``          — jitted wrappers (interpret mode on CPU).
* ``ref``          — pure-jnp oracles.
"""
from repro.kernels.ops import (
    row_topk,
    fused_memsgd_update,
    row_topk_ref,
    fused_memsgd_ref,
)
from repro.kernels.ref import densify_rows_ref
from repro.kernels.topk_select import LOOP_MAX_K

__all__ = [
    "LOOP_MAX_K",
    "row_topk",
    "fused_memsgd_update",
    "row_topk_ref",
    "fused_memsgd_ref",
    "densify_rows_ref",
]
