"""Pallas TPU kernels for the Mem-SGD compression hot-spot.

* ``topk_select``  — per-row top-k selection (pl.pallas_call + BlockSpec).
* ``fused_memsgd`` — fused memory update + compression (scalar-prefetch eta).
* ``ops``          — jitted wrappers (interpret mode on CPU).
* ``ref``          — pure-jnp oracles.
"""
from repro.kernels.ops import (
    row_topk,
    fused_memsgd_update,
    row_topk_ref,
    fused_memsgd_ref,
)

__all__ = ["row_topk", "fused_memsgd_update", "row_topk_ref", "fused_memsgd_ref"]
