"""Pallas TPU kernels: per-row top-k selection (the compression hot-spot).

Two selection algorithms share one output contract (top-|.|-k per row,
emitted in decreasing-magnitude order, magnitude ties broken by LOWEST
index — identical to ``repro.kernels.ref``):

* ``loop`` — k iterations of masked row-argmax on an in-VMEM tile. O(k*C)
  VPU work with k sequential dependent passes; cheap for tiny k.

* ``threshold`` (single-pass) — per-row bisection on the *bit patterns* of
  the f32 magnitudes finds the exact k-th magnitude threshold in <= 32
  compare+count sweeps (O(32*C), independent of k), then ONE masked-cumsum
  compaction emits the (value, index) pairs and an O(k^2) rank pass puts
  them in the contract order. Because the bisection runs over int32
  bitcasts of the magnitudes (monotone for non-negative floats) the
  threshold is exact — outputs are bitwise-equal to the loop kernel.

The threshold kernel also comes in a COLUMN-TILED form with grid
``(R // RB, C // CB)``: each (RB, CB) tile is merged into a running
(RB, k) candidate buffer kept in the revisited output block (VMEM), so C
no longer has to fit in a single VMEM tile and the whole selection remains
a single pass over HBM. The merge invariant that makes tie-breaking exact:
within the concatenated [candidates | tile] axis, entries of equal
magnitude always appear in ascending-index order (candidates are kept
sorted by (-|v|, index) and all candidate indices precede the tile's).

Grid/BlockSpec layout (tiled form):
  grid  = (R // RB, C_padded // CB)         # last dim innermost
  x     : BlockSpec((RB, CB), (i, j) -> (i, j))
  vals  : BlockSpec((RB, k),  (i, j) -> (i, 0))   # revisited accumulator
  idx   : BlockSpec((RB, k),  (i, j) -> (i, 0))
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_ROW_BLOCK = 8
# an (8, 4096) f32 tile is 128 KB — far under VMEM; wider tiles amortize
# the per-merge fixed cost (bisection + rank sort) over more columns.
DEFAULT_COL_BLOCK = 4096
# columns appended by jnp.pad / sentinel candidate slots carry this index;
# larger than any real column index so they lose every magnitude tie.
_IDX_SENTINEL = 2**30  # python int: kernels must not capture traced consts
# |x| bitcasts are >= 0; bisection over [-1, max_bits] converges in <= 32
# halvings (the f32 magnitude bit range is < 2^31).
_N_BISECT = 32
# up to this k the k-pass argmax loop beats the fixed-cost threshold
# select. Historical default — the per-backend MEASURED table in
# ``repro.utils.platform.topk_loop_cutover`` supersedes it wherever a
# backend entry exists (the interpret-mode CPU crossover sits at 4).
LOOP_MAX_K = 8


def _auto_interpret(interpret: Optional[bool]) -> bool:
    """Resolve ``interpret=None``: compiled lowering on TPU and GPU
    (Mosaic / Triton), interpret fallback on CPU — with the
    ``REPRO_PALLAS_INTERPRET=0/1`` env override taking priority either
    way (see ``repro.utils.platform.pallas_interpret_default``). An
    explicit ``interpret=`` argument always wins."""
    if interpret is None:
        from repro.utils.platform import pallas_interpret_default

        return pallas_interpret_default()
    return interpret


# ---------------------------------------------------------------------------
# loop selection (fallback for tiny k)
# ---------------------------------------------------------------------------


def _topk_loop(x: Array, k: int) -> Tuple[Array, Array]:
    """k iterations of masked row-argmax on an in-VMEM tile."""
    Rb, C = x.shape
    absx = jnp.abs(x).astype(jnp.float32)
    neg_inf = jnp.asarray(-jnp.inf, jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (Rb,), 0)

    def step(i, carry):
        vals, idxs, absm = carry
        j = jnp.argmax(absm, axis=1).astype(jnp.int32)  # (Rb,)
        v = jnp.take_along_axis(x, j[:, None], axis=1)[:, 0]
        vals = jax.lax.dynamic_update_slice(vals, v[:, None], (0, i))
        idxs = jax.lax.dynamic_update_slice(idxs, j[:, None], (0, i))
        absm = absm.at[rows, j].set(neg_inf)
        return vals, idxs, absm

    vals0 = jnp.zeros((Rb, k), x.dtype)
    idxs0 = jnp.zeros((Rb, k), jnp.int32)
    vals, idxs, _ = jax.lax.fori_loop(0, k, step, (vals0, idxs0, absx))
    return vals, idxs


# ---------------------------------------------------------------------------
# threshold selection (single-pass) — shared math
# ---------------------------------------------------------------------------


def _mag_bits(v: Array, valid: Optional[Array] = None) -> Array:
    """Monotone int32 ordering key for |v| (f32 bitcast); invalid -> -1."""
    bits = jax.lax.bitcast_convert_type(
        jnp.abs(v).astype(jnp.float32), jnp.int32
    )
    if valid is not None:
        bits = jnp.where(valid, bits, jnp.int32(-1))
    return bits


def _kth_largest_bits(bits: Array, k: int) -> Array:
    """Exact k-th largest of ``bits`` along the last axis via bisection.

    Returns the largest t with count(bits >= t) >= k, shape (..., 1).
    Requires at least k entries per row with bits > -1 when sentinels are
    in play (guaranteed by the CB >= k / C >= k preconditions).
    """
    lo = jnp.full(bits.shape[:-1] + (1,), -1, jnp.int32)
    hi = jnp.max(bits, axis=-1, keepdims=True)

    def body(_, carry):
        lo, hi = carry
        mid = lo + (hi - lo + 1) // 2
        cnt = jnp.sum((bits >= mid).astype(jnp.int32), axis=-1, keepdims=True)
        ge = cnt >= k
        return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid - 1)

    lo, hi = jax.lax.fori_loop(0, _N_BISECT, body, (lo, hi))
    return lo


def _select_mask(bits: Array, tau: Array, k: int) -> Array:
    """Exactly-k per-row mask: all > tau, plus the first (k - #gt) ties in
    axis order. Correct iff equal magnitudes appear in ascending-index
    order along the axis (see module docstring)."""
    gt = bits > tau
    eq = bits == tau
    n_gt = jnp.sum(gt.astype(jnp.int32), axis=-1, keepdims=True)
    tie_rank = jnp.cumsum(eq.astype(jnp.int32), axis=-1) - 1
    return gt | (eq & (tie_rank < (k - n_gt)))


def _compact_selected(sel: Array, k: int) -> Array:
    """Positions (along the last axis) of the k selected entries, in axis
    order: a masked-cumsum compaction realised as a vectorized binary
    search over the running count — slot s is the first n with
    cumsum(sel)[n] == s+1. Gathers only (no scatter, no sort): scatters
    serialize on CPU and replicate under GSPMD."""
    N = sel.shape[-1]
    cums = jnp.cumsum(sel.astype(jnp.int32), axis=-1)
    targets = 1 + jax.lax.broadcasted_iota(
        jnp.int32, sel.shape[:-1] + (k,), sel.ndim - 1
    )
    lo = jnp.zeros(targets.shape, jnp.int32)
    hi = jnp.full(targets.shape, N - 1, jnp.int32)
    n_steps = max(1, (N - 1).bit_length())
    for _ in range(n_steps):  # static unroll: ceil(log2(N)) halvings
        mid = (lo + hi) // 2
        ge = jnp.take_along_axis(cums, mid, axis=-1) >= targets
        lo = jnp.where(ge, lo, mid + 1)
        hi = jnp.where(ge, mid, hi)
    return lo


def _order_pairs(cv: Array, ci: Array, cb: Array) -> Tuple[Array, Array]:
    """Permute compacted (Rb, k) pairs into the contract's (-|v|, index)
    order: O(k^2) rank + one-hot permutation (exact — each output sums
    exactly one nonzero term, so even bf16 values pass through bitwise)."""
    k = cv.shape[-1]
    prec = (cb[..., None, :] > cb[..., :, None]) | (
        (cb[..., None, :] == cb[..., :, None])
        & (ci[..., None, :] < ci[..., :, None])
    )
    rank = jnp.sum(prec.astype(jnp.int32), axis=-1)  # (..., k) permutation
    slots = jnp.arange(k, dtype=jnp.int32)
    perm = (rank[..., None] == slots).astype(jnp.int32)  # (..., src, dst)
    out_v = jnp.einsum(
        "...sd,...s->...d", perm.astype(cv.dtype), cv
    )
    out_i = jnp.sum(perm * ci[..., None], axis=-2)
    return out_v, out_i


def _threshold_select(V: Array, I: Array, valid: Optional[Array], k: int
                      ) -> Tuple[Array, Array]:
    """Top-|.|-k of (V, I) pairs along the last axis, emitted sorted by
    (-|v|, index). Entries of equal magnitude must appear in
    ascending-index order along the axis."""
    bits = _mag_bits(V, valid)
    tau = _kth_largest_bits(bits, k)
    sel = _select_mask(bits, tau, k)
    n_sel = _compact_selected(sel, k)  # (..., k) positions, axis order
    cv = jnp.take_along_axis(V, n_sel, axis=-1)
    ci = jnp.take_along_axis(I, n_sel, axis=-1)
    cb = jnp.take_along_axis(bits, n_sel, axis=-1)
    return _order_pairs(cv, ci, cb)


def _threshold_topk_tile(x: Array, k: int) -> Tuple[Array, Array]:
    """Single-pass top-k of a resident (Rb, C) tile (C >= k)."""
    Rb, C = x.shape
    I = jax.lax.broadcasted_iota(jnp.int32, (Rb, C), 1)
    return _threshold_select(x, I, None, k)


def mask_live_k(vals: Array, idx: Array, k_live) -> Tuple[Array, Array]:
    """Restrict a contract-ordered top-``k_max`` selection to a RUNTIME
    ``k_live <= k_max`` without changing shapes: slots ``>= k_live``
    become (-0.0, 0), which densify/scatter as EXACT no-ops.

    Because every selector here emits pairs sorted by (-|v|, index), the
    first ``k_live`` slots of a top-``k_max`` selection ARE the
    top-``k_live`` selection — so masking the tail of one static-shape
    select is exactly equivalent to selecting at ``k_live``, for any
    traced ``k_live``. (The bisection threshold in ``_kth_largest_bits``
    is itself count-parameterized — ``k`` appears only in arithmetic
    comparisons — but the compaction/ordering stages need a static slot
    count, so the runtime-k path selects at the static ``k_max`` and
    masks.) This is what lets the distributed pod stage move its k at
    runtime while every buffer, wire message and all-gather stays shaped
    at the compile-time ``k_max``.

    The padded value is NEGATIVE zero on purpose: -0.0 is the additive
    identity of IEEE float addition (``x + -0.0 == x`` bitwise for every
    x, including both signed zeros), so a scatter-add densify over the
    padded slots is an exact no-op and the error-feedback memory stays
    BITWISE identical to the static-k computation (a +0.0 fill flips
    -0.0 entries: ``-0.0 + 0.0 == +0.0``). One caveat survives: XLA
    compiles a k=1 one-hot-einsum densify without a reduce (keeping
    ``0*v`` signed zeros) while any multi-slot reduce inits at +0.0, so
    the RAW update of a masked k_max select can differ from a static
    k_live=1 compile in the sign of all-zero columns. That transient
    ±0.0 cancels at application — ``p - (+/-0.0) == p`` for every
    nonzero parameter — so applied params (and memory) remain bitwise
    identical; compare those, not the raw update's zero signs."""
    slot = jax.lax.broadcasted_iota(jnp.int32, idx.shape, idx.ndim - 1)
    live = slot < jnp.asarray(k_live, jnp.int32)
    return (
        jnp.where(live, vals, jnp.full_like(vals, -0.0)),
        jnp.where(live, idx, jnp.zeros_like(idx)),
    )


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k: int, selection: str):
    x = x_ref[...]
    if selection == "threshold":
        vals, idxs = _threshold_topk_tile(x, k)
    else:
        vals, idxs = _topk_loop(x, k)
    vals_ref[...] = vals
    idx_ref[...] = idxs


def _topk_tiled_kernel(x_ref, vals_ref, idx_ref, *, k: int, col_block: int):
    """Merge one (RB, CB) tile into the (RB, k) candidate accumulator."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.zeros_like(vals_ref)
        idx_ref[...] = jnp.full(idx_ref.shape, _IDX_SENTINEL, jnp.int32)

    x = x_ref[...]
    Rb = x.shape[0]
    tile_i = j * col_block + jax.lax.broadcasted_iota(
        jnp.int32, x.shape, 1
    )
    cand_v, cand_i = vals_ref[...], idx_ref[...]
    V = jnp.concatenate([cand_v, x], axis=1)
    I = jnp.concatenate([cand_i, tile_i], axis=1)
    valid = I < _IDX_SENTINEL  # sentinel candidate slots never compete
    vals_ref[...], idx_ref[...] = _threshold_select(V, I, valid, k)


def row_topk_pallas(
    x: Array, k: int, *, row_block: int = DEFAULT_ROW_BLOCK,
    interpret: Optional[bool] = None, selection: str = "loop",
) -> Tuple[Array, Array]:
    """Per-row top-|.|-k with the full row as one VMEM tile.

    x: (R, C) with R % row_block == 0 and k <= C. ``selection`` in
    {"loop", "threshold"}.
    """
    R, C = x.shape
    assert R % row_block == 0, (R, row_block)
    assert k <= C, (k, C)
    grid = (R // row_block,)
    kernel = functools.partial(_topk_kernel, k=k, selection=selection)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((row_block, C), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((row_block, k), lambda i: (i, 0)),
            pl.BlockSpec((row_block, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, k), x.dtype),
            jax.ShapeDtypeStruct((R, k), jnp.int32),
        ],
        interpret=_auto_interpret(interpret),
    )(x)


def row_topk_tiled_pallas(
    x: Array, k: int, *, row_block: int = DEFAULT_ROW_BLOCK,
    col_block: int = DEFAULT_COL_BLOCK, interpret: Optional[bool] = None,
) -> Tuple[Array, Array]:
    """Single-pass column-tiled threshold top-k.

    x: (R, C) with R % row_block == 0 and k <= C. C is padded up to a
    multiple of the column block with zeros; padded columns carry indices
    >= C and (with C >= k real entries available) are never selected.
    """
    R, C = x.shape
    assert R % row_block == 0, (R, row_block)
    assert k <= C, (k, C)
    cb = max(k, min(col_block, C))  # merge needs >= k entries per tile
    pad = (-C) % cb
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    grid = (R // row_block, (C + pad) // cb)
    kernel = functools.partial(_topk_tiled_kernel, k=k, col_block=cb)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((row_block, cb), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((row_block, k), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, k), x.dtype),
            jax.ShapeDtypeStruct((R, k), jnp.int32),
        ],
        interpret=_auto_interpret(interpret),
    )(x)
