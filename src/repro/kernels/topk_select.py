"""Pallas TPU kernel: per-row top-k selection (the compression hot-spot).

TPU adaptation of the GPU radix-select/sort used by CUDA top-k
implementations: a radix sort does not map onto the VPU/MXU. Instead each
grid step loads a (ROW_BLOCK, C) tile into VMEM and runs k iterations of a
masked row-argmax — pure VPU work over data that stays resident in VMEM,
one HBM read of the tile total. k is small (<= 64 per row in all sync
configs), so the loop is short; the selected (value, index) pairs are the
only outputs (k << C), which is precisely the communication object of
Mem-SGD.

Grid/BlockSpec layout:
  grid  = (R // ROW_BLOCK,)
  x     : BlockSpec((ROW_BLOCK, C),  i -> (i, 0))   # VMEM tile
  vals  : BlockSpec((ROW_BLOCK, k),  i -> (i, 0))
  idx   : BlockSpec((ROW_BLOCK, k),  i -> (i, 0))

C is the full row (the row is the selection domain); rows are the grid.
For the framework's sync, rows are hardware-aligned slices that never
cross a model shard (see repro.core.distributed docstring).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_ROW_BLOCK = 8


def _topk_loop(x: Array, k: int) -> Tuple[Array, Array]:
    """k iterations of masked row-argmax on an in-VMEM tile."""
    Rb, C = x.shape
    absx = jnp.abs(x).astype(jnp.float32)
    neg_inf = jnp.asarray(-jnp.inf, jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (Rb,), 0)

    def step(i, carry):
        vals, idxs, absm = carry
        j = jnp.argmax(absm, axis=1).astype(jnp.int32)  # (Rb,)
        v = jnp.take_along_axis(x, j[:, None], axis=1)[:, 0]
        vals = jax.lax.dynamic_update_slice(vals, v[:, None], (0, i))
        idxs = jax.lax.dynamic_update_slice(idxs, j[:, None], (0, i))
        absm = absm.at[rows, j].set(neg_inf)
        return vals, idxs, absm

    vals0 = jnp.zeros((Rb, k), x.dtype)
    idxs0 = jnp.zeros((Rb, k), jnp.int32)
    vals, idxs, _ = jax.lax.fori_loop(0, k, step, (vals0, idxs0, absx))
    return vals, idxs


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k: int):
    x = x_ref[...]
    vals, idxs = _topk_loop(x, k)
    vals_ref[...] = vals
    idx_ref[...] = idxs


def row_topk_pallas(
    x: Array, k: int, *, row_block: int = DEFAULT_ROW_BLOCK,
    interpret: bool = True,
) -> Tuple[Array, Array]:
    """Per-row top-|.|-k. x: (R, C) with R % row_block == 0."""
    R, C = x.shape
    assert R % row_block == 0, (R, row_block)
    grid = (R // row_block,)
    kernel = functools.partial(_topk_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((row_block, C), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((row_block, k), lambda i: (i, 0)),
            pl.BlockSpec((row_block, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, k), x.dtype),
            jax.ShapeDtypeStruct((R, k), jnp.int32),
        ],
        interpret=interpret,
    )(x)
