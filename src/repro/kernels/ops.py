"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as Python/jnp over the same BlockSpec tiling, which is what the
tests validate against ``ref.py``. On a real TPU ``interpret=None``
auto-detects the backend and compiles for real.

``method`` picks the selection algorithm:

* ``"loop"``      — k masked-argmax iterations, whole row in one VMEM tile.
* ``"threshold"`` — single-pass bisection select, column-tiled grid so C
  is not limited by VMEM (see ``topk_select.row_topk_tiled_pallas``).
* ``"auto"``      — threshold for k above the backend's measured cutover
  (``repro.utils.platform.topk_loop_cutover``), loop otherwise (tiny k:
  the k dependent passes are cheaper than the fixed 32 bisection sweeps).

All methods emit bitwise-identical (value, index) outputs.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fused_memsgd import fused_memsgd_pallas
from repro.kernels.topk_select import (
    DEFAULT_COL_BLOCK,
    DEFAULT_ROW_BLOCK,
    row_topk_pallas,
    row_topk_tiled_pallas,
)

Array = jax.Array


def _resolve_method(method: str, k: int) -> str:
    if method == "auto":
        from repro.utils.platform import topk_loop_cutover

        return "threshold" if k > topk_loop_cutover() else "loop"
    if method not in ("loop", "threshold"):
        raise ValueError(f"unknown top-k method {method!r}")
    return method


def _pad_rows(x: Array, row_block: int) -> Tuple[Array, int]:
    R = x.shape[0]
    pad = (-R) % row_block
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, pad


@functools.partial(
    jax.jit,
    static_argnames=("k", "row_block", "col_block", "interpret", "method"),
)
def row_topk(x: Array, k: int, row_block: int = DEFAULT_ROW_BLOCK,
             interpret: Optional[bool] = None, method: str = "auto",
             col_block: int = DEFAULT_COL_BLOCK) -> Tuple[Array, Array]:
    """Per-row top-|.|-k of x (R, C) -> (vals (R,k), idx (R,k))."""
    xp, pad = _pad_rows(x, row_block)
    if _resolve_method(method, k) == "threshold":
        vals, idx = row_topk_tiled_pallas(
            xp, k, row_block=row_block, col_block=col_block,
            interpret=interpret,
        )
    else:
        vals, idx = row_topk_pallas(
            xp, k, row_block=row_block, interpret=interpret,
        )
    if pad:
        vals, idx = vals[: x.shape[0]], idx[: x.shape[0]]
    return vals, idx


@functools.partial(
    jax.jit, static_argnames=("k", "row_block", "interpret", "method")
)
def fused_memsgd_update(
    m: Array, g: Array, eta, k: int, row_block: int = DEFAULT_ROW_BLOCK,
    interpret: Optional[bool] = None, method: str = "auto",
) -> Tuple[Array, Array, Array]:
    """Fused u = m + eta*g -> top-k -> residual memory.

    Returns (new_m (R,C), vals (R,k), idx (R,k)).
    """
    mp, pad = _pad_rows(m, row_block)
    gp, _ = _pad_rows(g, row_block)
    new_m, vals, idx = fused_memsgd_pallas(
        mp, gp, eta, k, row_block=row_block, interpret=interpret,
        selection=_resolve_method(method, k),
    )
    if pad:
        new_m = new_m[: m.shape[0]]
        vals, idx = vals[: m.shape[0]], idx[: m.shape[0]]
    return new_m, vals, idx


# re-export oracles for test convenience
row_topk_ref = ref.row_topk_ref
fused_memsgd_ref = ref.fused_memsgd_ref
