"""Pallas TPU kernel: fused Mem-SGD memory update + compression.

The unfused sequence reads/writes the full (param-sized) tensors three
times per step (u = m + eta*g; top-k over u; m' = u - selection). At
k << C the tensors are HBM-bandwidth bound, so fusing them into a single
pass over each VMEM tile cuts the HBM traffic of the compression stage
from ~5 R*C transfers (read m, read g, write u, read u, write m') to the
3 unavoidable ones (read m, read g, write m') — a ~1.7x reduction on the
memory roofline term of the sync stage.

Per grid step (one (ROW_BLOCK, C) tile resident in VMEM):
    u     = m + eta * g           # elementwise, VPU
    v,i   = row_topk(u, k)        # k masked argmax iterations
    m'    = u zeroed at selected  # elementwise scatter within the tile

eta arrives via scalar prefetch (SMEM) so the same compiled kernel serves
every step of a stepsize schedule.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.topk_select import DEFAULT_ROW_BLOCK, _topk_loop

Array = jax.Array


def _fused_kernel(eta_ref, m_ref, g_ref, newm_ref, vals_ref, idx_ref, *, k: int):
    eta = eta_ref[0, 0]
    m = m_ref[...]
    g = g_ref[...]
    u = m + eta.astype(m.dtype) * g.astype(m.dtype)
    vals, idxs = _topk_loop(u, k)
    Rb = u.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (Rb, k), 0)
    new_m = u.at[rows, idxs].set(0)
    newm_ref[...] = new_m
    vals_ref[...] = vals
    idx_ref[...] = idxs


def fused_memsgd_pallas(
    m: Array, g: Array, eta, k: int, *,
    row_block: int = DEFAULT_ROW_BLOCK, interpret: bool = True,
) -> Tuple[Array, Array, Array]:
    """(m, g): (R, C); eta scalar. Returns (new_m (R,C), vals (R,k),
    idx (R,k))."""
    R, C = m.shape
    assert m.shape == g.shape
    assert R % row_block == 0, (R, row_block)
    grid = (R // row_block,)
    kernel = functools.partial(_fused_kernel, k=k)
    eta_arr = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # eta (SMEM-sized)
            pl.BlockSpec((row_block, C), lambda i: (i, 0)),
            pl.BlockSpec((row_block, C), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((row_block, C), lambda i: (i, 0)),
            pl.BlockSpec((row_block, k), lambda i: (i, 0)),
            pl.BlockSpec((row_block, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), m.dtype),
            jax.ShapeDtypeStruct((R, k), m.dtype),
            jax.ShapeDtypeStruct((R, k), jnp.int32),
        ],
        interpret=interpret,
    )(eta_arr, m, g)
