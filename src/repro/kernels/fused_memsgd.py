"""Pallas TPU kernel: fused Mem-SGD memory update + compression.

The unfused sequence reads/writes the full (param-sized) tensors three
times per step (u = m + eta*g; top-k over u; m' = u - selection). At
k << C the tensors are HBM-bandwidth bound, so fusing them into a single
pass over each VMEM tile cuts the HBM traffic of the compression stage
from ~5 R*C transfers (read m, read g, write u, read u, write m') to the
3 unavoidable ones (read m, read g, write m') — a ~1.7x reduction on the
memory roofline term of the sync stage.

Per grid step (one (ROW_BLOCK, C) tile resident in VMEM):
    u     = m + eta * g           # elementwise, VPU
    v,i   = row_topk(u, k)        # loop or single-pass threshold select
    m'    = u zeroed at selected  # elementwise scatter within the tile

``selection`` picks the in-tile selection algorithm: "loop" (k masked
argmax iterations, cheap for tiny k) or "threshold" (single-pass bisection
select, O(32*C) independent of k — see ``repro.kernels.topk_select``).
Both emit bitwise-identical outputs.

eta arrives via scalar prefetch (SMEM) so the same compiled kernel serves
every step of a stepsize schedule.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.topk_select import (
    DEFAULT_ROW_BLOCK,
    _auto_interpret,
    _threshold_topk_tile,
    _topk_loop,
)

Array = jax.Array


def _fused_kernel(eta_ref, m_ref, g_ref, newm_ref, vals_ref, idx_ref, *,
                  k: int, selection: str):
    eta = eta_ref[0, 0]
    m = m_ref[...]
    g = g_ref[...]
    u = m + eta.astype(m.dtype) * g.astype(m.dtype)
    if selection == "threshold":
        vals, idxs = _threshold_topk_tile(u, k)
    else:
        vals, idxs = _topk_loop(u, k)
    Rb = u.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (Rb, k), 0)
    new_m = u.at[rows, idxs].set(0)
    newm_ref[...] = new_m
    vals_ref[...] = vals
    idx_ref[...] = idxs


def fused_memsgd_pallas(
    m: Array, g: Array, eta, k: int, *,
    row_block: int = DEFAULT_ROW_BLOCK, interpret: Optional[bool] = None,
    selection: str = "loop",
) -> Tuple[Array, Array, Array]:
    """(m, g): (R, C); eta scalar. Returns (new_m (R,C), vals (R,k),
    idx (R,k))."""
    R, C = m.shape
    assert m.shape == g.shape
    assert R % row_block == 0, (R, row_block)
    assert k <= C, (k, C)
    grid = (R // row_block,)
    kernel = functools.partial(_fused_kernel, k=k, selection=selection)
    eta_arr = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # eta (SMEM-sized)
            pl.BlockSpec((row_block, C), lambda i: (i, 0)),
            pl.BlockSpec((row_block, C), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((row_block, C), lambda i: (i, 0)),
            pl.BlockSpec((row_block, k), lambda i: (i, 0)),
            pl.BlockSpec((row_block, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), m.dtype),
            jax.ShapeDtypeStruct((R, k), m.dtype),
            jax.ShapeDtypeStruct((R, k), jnp.int32),
        ],
        interpret=_auto_interpret(interpret),
    )(eta_arr, m, g)
