"""Input pipeline: host-side batching, device placement, prefetch.

The training driver consumes ``ShardedBatcher`` which yields device-ready
global batches laid out for the (pod, data, model) mesh: the batch axis is
sharded over the data axes, everything else replicated.
"""
from __future__ import annotations

import threading
import queue
from typing import Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


class InfiniteStream:
    """Wrapper marking an iterator as EXPLICITLY unbounded.

    ``repro.data.token_batches`` (and friends) never terminate, so
    ``list(...)`` would loop forever eating RAM and ``len(...)`` is
    meaningless — both have burned real CPU time. This wrapper makes the
    misuse fail fast instead:

    * ``len(stream)`` raises ``TypeError``.
    * ``list(stream)`` / anything going through ``operator.length_hint``
      raises ``RuntimeError`` up front (CPython swallows ``TypeError``
      from ``__length_hint__`` and would happily iterate forever, so the
      hint must raise a non-TypeError to stop ``list()``).

    The sanctioned way to bound a stream is ``repro.data.take(it, n)``
    (or ``itertools.islice``).
    """

    def __init__(self, it: Iterator):
        self._it = iter(it)

    def __iter__(self) -> "InfiniteStream":
        return self

    def __next__(self):
        return next(self._it)

    def __len__(self) -> int:
        raise TypeError(
            "infinite stream: len() is undefined — bound it with "
            "repro.data.take(it, n)"
        )

    def __bool__(self) -> bool:
        # without this, bool() falls back to the raising __len__
        return True

    def __length_hint__(self) -> int:
        raise RuntimeError(
            "infinite stream: list()/tuple() would never terminate — "
            "bound it with repro.data.take(it, n)"
        )


class Prefetcher:
    """Background-thread prefetch of an iterator (depth-bounded)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


class ShardedBatcher:
    """Places host batches onto the mesh with batch-axis data sharding.

    Typically wraps an unbounded token stream, so ``len(...)`` and
    ``list(...)`` are guarded the same way as ``InfiniteStream`` — bound
    consumption with ``repro.data.take`` / ``itertools.islice``."""

    def __init__(self, mesh, it: Iterator[dict], batch_axes=("data",),
                 prefetch: int = 2):
        self.mesh = mesh
        self.batch_axes = batch_axes
        self._it = Prefetcher(it, prefetch) if prefetch else it

    def _sharding(self, ndim: int) -> NamedSharding:
        spec = P(self.batch_axes) if ndim >= 1 else P()
        return NamedSharding(self.mesh, spec)

    def __len__(self) -> int:
        raise TypeError(
            "ShardedBatcher wraps an (typically infinite) stream: len() "
            "is undefined — bound it with repro.data.take(iter(b), n)"
        )

    def __bool__(self) -> bool:
        # without this, bool() falls back to the raising __len__
        return True

    def __length_hint__(self) -> int:
        raise RuntimeError(
            "ShardedBatcher wraps an (typically infinite) stream: "
            "list() may never terminate — bound it with "
            "repro.data.take(iter(b), n)"
        )

    def __iter__(self):
        for batch in self._it:
            yield {
                k: jax.device_put(np.asarray(v), self._sharding(np.ndim(v)))
                for k, v in batch.items()
            }


def take(it: Iterator, n: int):
    """The sanctioned bound for the infinite streams in this package:
    yield the first ``n`` items, then stop — consuming EXACTLY ``n``
    from the underlying iterator (the old ``enumerate``-based form
    pulled and discarded an (n+1)th item, losing a batch at every
    bound when consumers share one stream)."""
    it = iter(it)
    for _ in range(n):
        try:
            yield next(it)
        except StopIteration:
            return
