"""Input pipeline: host-side batching, device placement, prefetch.

The training driver consumes ``ShardedBatcher`` which yields device-ready
global batches laid out for the (pod, data, model) mesh: the batch axis is
sharded over the data axes, everything else replicated.
"""
from __future__ import annotations

import threading
import queue
from typing import Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


class Prefetcher:
    """Background-thread prefetch of an iterator (depth-bounded)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


class ShardedBatcher:
    """Places host batches onto the mesh with batch-axis data sharding."""

    def __init__(self, mesh, it: Iterator[dict], batch_axes=("data",),
                 prefetch: int = 2):
        self.mesh = mesh
        self.batch_axes = batch_axes
        self._it = Prefetcher(it, prefetch) if prefetch else it

    def _sharding(self, ndim: int) -> NamedSharding:
        spec = P(self.batch_axes) if ndim >= 1 else P()
        return NamedSharding(self.mesh, spec)

    def __iter__(self):
        for batch in self._it:
            yield {
                k: jax.device_put(np.asarray(v), self._sharding(np.ndim(v)))
                for k, v in batch.items()
            }


def take(it: Iterator, n: int):
    for i, item in enumerate(it):
        if i >= n:
            return
        yield item
