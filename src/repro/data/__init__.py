from repro.data.synthetic import (
    token_batches,
    LogRegData,
    make_epsilon_like,
    make_rcv1_like,
    logreg_loss_np,
    logreg_grad_np,
)
from repro.data.pipeline import (
    InfiniteStream,
    Prefetcher,
    ShardedBatcher,
    take,
)

__all__ = [
    "token_batches",
    "LogRegData",
    "make_epsilon_like",
    "make_rcv1_like",
    "logreg_loss_np",
    "logreg_grad_np",
    "InfiniteStream",
    "Prefetcher",
    "ShardedBatcher",
    "take",
]
