"""Synthetic datasets.

Two kinds:

1. Token streams for the language-model training examples/smoke tests
   (Zipf-distributed ids with a deterministic next-token structure so that
   a learning model measurably reduces loss).
2. The paper's logistic-regression datasets (Section 4.1): an
   epsilon-like DENSE dataset and an RCV1-like SPARSE dataset, with a
   planted ground-truth separator + label noise, matching the paper's
   (n, d, density) regimes at configurable scale.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


# ---------------------------------------------------------------------------
# token streams
# ---------------------------------------------------------------------------


def token_batches(
    vocab_size: int,
    batch: int,
    seq_len: int,
    seed: int = 0,
    structured: bool = True,
) -> Iterator[dict]:
    """INFINITE iterator of {'tokens', 'labels'} numpy batches.

    ``structured`` plants a learnable pattern: token_{t+1} depends on
    token_t via a fixed random permutation with noise, so cross-entropy
    can drop below the unigram entropy.

    The stream never terminates: ``len(...)`` raises ``TypeError`` and
    ``list(...)`` fails fast instead of hanging (see
    ``repro.data.pipeline.InfiniteStream``); bound consumption with
    ``repro.data.take(it, n)``.
    """
    from repro.data.pipeline import InfiniteStream

    return InfiniteStream(
        _token_batches_gen(vocab_size, batch, seq_len, seed, structured)
    )


def _token_batches_gen(
    vocab_size: int,
    batch: int,
    seq_len: int,
    seed: int = 0,
    structured: bool = True,
) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(vocab_size)
    zipf_p = 1.0 / np.arange(1, vocab_size + 1)
    zipf_p /= zipf_p.sum()
    while True:
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.choice(vocab_size, size=batch, p=zipf_p)
        if structured:
            noise = rng.random((batch, seq_len)) < 0.2
            rand_tok = rng.choice(vocab_size, size=(batch, seq_len), p=zipf_p)
            for t in range(seq_len):
                nxt = perm[toks[:, t]]
                toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        else:
            toks[:, 1:] = rng.choice(
                vocab_size, size=(batch, seq_len), p=zipf_p
            )
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


# ---------------------------------------------------------------------------
# logistic regression (paper Section 4)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LogRegData:
    """a_i in R^d, b_i in {-1, +1}; f(x) = mean log(1+exp(-b a^T x)) + l2/2 |x|^2."""

    A: np.ndarray  # (n, d)
    b: np.ndarray  # (n,)
    lam: float  # L2 regularizer (paper: 1/n)
    name: str = ""

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def d(self) -> int:
        return self.A.shape[1]


def make_epsilon_like(
    n: int = 10_000, d: int = 2_000, seed: int = 0, noise: float = 0.1
) -> LogRegData:
    """Dense dataset in the spirit of `epsilon` (d=2000, 100% density)."""
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((n, d)).astype(np.float64) / np.sqrt(d)
    w_star = rng.standard_normal(d)
    logits = A @ w_star
    b = np.sign(logits + noise * rng.standard_normal(n)).astype(np.float64)
    b[b == 0] = 1.0
    return LogRegData(A=A, b=b, lam=1.0 / n, name="epsilon-like")


def make_rcv1_like(
    n: int = 20_000, d: int = 47_236, density: float = 0.0015, seed: int = 0,
    noise: float = 0.1,
) -> LogRegData:
    """Sparse dataset in the spirit of RCV1-test (density 0.15%).

    Stored dense (numpy) for simplicity; the gradients inherit the sparsity
    pattern, which is what matters for the communication accounting.
    """
    rng = np.random.default_rng(seed)
    A = np.zeros((n, d))
    nnz = max(1, int(density * d))
    for i in range(n):
        idx = rng.choice(d, size=nnz, replace=False)
        A[i, idx] = rng.standard_normal(nnz) / np.sqrt(nnz)
    w_star = rng.standard_normal(d)
    logits = A @ w_star
    b = np.sign(logits + noise * rng.standard_normal(n)).astype(np.float64)
    b[b == 0] = 1.0
    return LogRegData(A=A, b=b, lam=1.0 / n, name="rcv1-like")


def logreg_loss_np(data: LogRegData, x: np.ndarray) -> float:
    z = -data.b * (data.A @ x)
    # stable log(1+exp(z))
    loss = np.mean(np.logaddexp(0.0, z))
    return float(loss + 0.5 * data.lam * np.dot(x, x))


def logreg_grad_np(data: LogRegData, x: np.ndarray, idx) -> np.ndarray:
    """Stochastic gradient over sample indices ``idx``."""
    Ai = data.A[idx]
    bi = data.b[idx]
    z = -bi * (Ai @ x)
    sig = 1.0 / (1.0 + np.exp(-z))  # sigmoid(z)
    g = -(Ai * (bi * sig)[:, None]).mean(axis=0)
    return g + data.lam * x
