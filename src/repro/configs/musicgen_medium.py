"""MusicGen-medium — decoder-only transformer over EnCodec tokens.

Source: arXiv:2306.05284. 48L, d_model=1536, 24 heads (MHA), d_ff=6144,
vocab=2048 (EnCodec codebook). The EnCodec conv frontend is a STUB:
``n_prefix_embeddings`` conditioning frames are provided as precomputed
embeddings by ``input_specs()`` (carve-out per the assignment).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="musicgen-medium", family="dense",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
        d_ff=6144, vocab_size=2048, vocab_pad_multiple=64,
        n_prefix_embeddings=256,  # stub conditioning frames
        source="arXiv:2306.05284",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab_size=512, vocab_pad_multiple=16, n_prefix_embeddings=8,
    )
