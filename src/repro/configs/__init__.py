"""Architecture config registry.

``get_config(arch_id)`` returns the full production ``ModelConfig``;
``get_smoke_config(arch_id)`` the reduced same-family variant (<=2 layers,
d_model <= 512, <= 4 experts) used by the CPU smoke tests.

Assigned architectures (public pool, source in each module):
  rwkv6-3b, qwen1.5-4b, yi-9b, musicgen-medium, qwen3-moe-30b-a3b,
  qwen3-4b, internvl2-26b, granite-3-8b, recurrentgemma-9b,
  granite-moe-3b-a800m
plus the paper's own workload: logreg (logistic regression, Section 4).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (
    MESHES,
    MeshConfig,
    ModelConfig,
    PodRefreshConfig,
    SHAPES,
    ShapeConfig,
)

ARCH_IDS = (
    "rwkv6-3b",
    "qwen1.5-4b",
    "yi-9b",
    "musicgen-medium",
    "qwen3-moe-30b-a3b",
    "qwen3-4b",
    "internvl2-26b",
    "granite-3-8b",
    "recurrentgemma-9b",
    "granite-moe-3b-a800m",
)


def _module(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch_id!r}; options: {ARCH_IDS}")
    return _module(arch_id).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch_id!r}; options: {ARCH_IDS}")
    return _module(arch_id).smoke_config()


__all__ = [
    "ModelConfig",
    "MeshConfig",
    "MESHES",
    "PodRefreshConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCH_IDS",
    "get_config",
    "get_smoke_config",
]
