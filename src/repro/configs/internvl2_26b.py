"""InternVL2-26B — InternViT vision encoder + InternLM2-20B LM.

Source: arXiv:2404.16821. LM backbone (what we implement): 48L,
d_model=6144, 48 heads (kv=8), d_ff=16384, vocab=92553. The InternViT
encoder + MLP projector are a STUB: ``n_prefix_embeddings`` image-patch
embeddings are provided precomputed by ``input_specs()``.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2-26b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=92553,
        n_prefix_embeddings=256,  # stub ViT patch embeddings per image
        source="arXiv:2404.16821",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=512, vocab_pad_multiple=16, n_prefix_embeddings=8,
    )
