"""RWKV-6 "Finch" 3B — attention-free SSM with data-dependent decay.

Source: arXiv:2404.05892 (Eagle and Finch). 32L, d_model=2560,
d_ff=8960, vocab=65536, head_dim=64 (40 wkv heads).
"""
from repro.configs.base import ModelConfig, RWKVConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="rwkv6-3b", family="rwkv",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=8960, vocab_size=65536,
        rwkv=RWKVConfig(head_dim=64, lora_rank_decay=64, lora_rank_mix=32,
                        chunk_size=16),
        source="arXiv:2404.05892",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab_size=512, vocab_pad_multiple=16,
        rwkv=RWKVConfig(head_dim=64, lora_rank_decay=8, lora_rank_mix=4,
                        chunk_size=16),
    )
