"""Yi-9B — llama-architecture dense decoder with GQA.

Source: arXiv:2403.04652. 48L, d_model=4096, 32 heads, kv=4,
d_ff=11008, vocab=64000.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="yi-9b", family="dense",
        n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab_size=64000, rope_theta=5e6,
        source="arXiv:2403.04652",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=512, vocab_pad_multiple=16,
    )
