"""Model / run configuration dataclasses.

One ``ModelConfig`` instance per assigned architecture lives in
``repro/configs/<arch_id>.py``; each exposes ``config()`` (full size, used
only via the dry-run) and ``smoke_config()`` (reduced: <=2 layers,
d_model<=512, <=4 experts — runnable on CPU).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    aux_loss_weight: float = 0.01
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    lora_rank_decay: int = 64  # low-rank size for data-dependent decay
    lora_rank_mix: int = 32  # low-rank size for ddlerp token-shift
    chunk_size: int = 128  # chunkwise-parallel scan chunk (MXU-friendly)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    # RecurrentGemma-style: repeating block pattern, e.g. ("rec","rec","attn")
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    lru_width: Optional[int] = None  # defaults to d_model
    conv_width: int = 4
    attn_window: int = 2048


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | rwkv | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None  # set -> sliding-window attention
    # norms / activations
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # modality: number of stub frontend embedding positions prepended to the
    # token sequence (audio frames / vision patches). 0 for text-only.
    n_prefix_embeddings: int = 0
    moe: Optional[MoEConfig] = None
    rwkv: Optional[RWKVConfig] = None
    hybrid: Optional[HybridConfig] = None
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    # remat policy for the stacked-layer scan: none | full
    remat: str = "none"
    source: str = ""  # citation

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class PodRefreshConfig:
    """Cadence + targets for the LIVE pod-ratio refresh (two-level
    bucketed sync only): every ``every`` steps the train driver
    re-measures each bucket's realized mass capture on the live
    memory+gradient buffers (``distributed.autotune_pod_ratios``) and
    feeds the new per-bucket pod ks into the SAME jitted step — the
    k-padded wire (``SyncConfig.pod_dynamic``) makes that a pure data
    change, zero recompiles.
    """

    every: int = 0  # steps between re-calibrations (0 = off)
    # mass-capture target for refreshes (None: SyncConfig.pod_mass_target)
    mass_target: Optional[float] = None
    # cross-pod bytes/step/worker each refresh re-spends via the
    # water-filling allocator (core.budget.BudgetController) instead of
    # sizing for the mass target (None: SyncConfig.byte_budget; both
    # None: mass-target sizing)
    byte_budget: Optional[int] = None
    # cap on the static padded pod k as a fraction of bucket cols
    # (None: the n_data * k_row support bound) — smaller caps shrink the
    # padded gather buffer but bound how far a refresh can raise k
    k_max_ratio: Optional[float] = None

    @property
    def enabled(self) -> bool:
        return self.every > 0


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """One named device-mesh layout.

    ``n_pods > 1`` declares a ``(pod, data, model)`` mesh: the data
    axes become ``("pod", "data")``, the two-level hierarchical sync
    re-compresses at the pod boundary, and the batch shards over both.
    ``launch.mesh.mesh_from_config`` materializes it.
    """

    name: str
    n_pods: int
    n_data: int
    n_model: int = 1

    @property
    def n_devices(self) -> int:
        return self.n_pods * self.n_data * self.n_model

    @property
    def axis_names(self) -> Tuple[str, ...]:
        if self.n_pods > 1:
            return ("pod", "data", "model")
        return ("data", "model")

    @property
    def shape(self) -> Tuple[int, ...]:
        if self.n_pods > 1:
            return (self.n_pods, self.n_data, self.n_model)
        return (self.n_data, self.n_model)


MESHES = {
    # CPU smoke meshes (8 forced host devices — the subprocess-test and
    # bench mesh for the two-level pod sync)
    "smoke_1pod": MeshConfig("smoke_1pod", 1, 8, 1),
    "smoke_2pod": MeshConfig("smoke_2pod", 2, 4, 1),
    # production pods: 16x16 per pod, 2 pods across the DCI link
    "pod_256": MeshConfig("pod_256", 1, 16, 16),
    "pod_2x256": MeshConfig("pod_2x256", 2, 16, 16),
}
