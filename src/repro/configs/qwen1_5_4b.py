"""Qwen1.5-4B — dense decoder with QKV bias.

Source: hf:Qwen/Qwen1.5-0.5B (family card; 4B point). 40L,
d_model=2560, 20 heads (GQA kv=20 i.e. MHA), d_ff=6912, vocab=151936.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen1.5-4b", family="dense",
        n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
        d_ff=6912, vocab_size=151936,
        qkv_bias=True, rope_theta=1e6,
        source="hf:Qwen/Qwen1.5-0.5B",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab_size=512, vocab_pad_multiple=16,
    )
