"""Qwen3-30B-A3B — MoE decoder, 128 experts top-8, GQA + qk_norm.

Source: hf:Qwen/Qwen3-30B-A3B. 48L, d_model=2048, 32 heads (kv=4,
head_dim=128), per-expert d_ff=768, vocab=151936.
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
        head_dim=128, d_ff=768, vocab_size=151936,
        qk_norm=True, rope_theta=1e6,
        moe=MoEConfig(n_experts=128, top_k=8, d_expert=768),
        source="hf:Qwen/Qwen3-30B-A3B",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=128, vocab_size=512, vocab_pad_multiple=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128),
    )
