"""Granite-3.0-8B — dense decoder with GQA.

Source: hf:ibm-granite/granite-3.0-2b-base (family card; 8B point).
40L, d_model=4096, 32 heads (kv=8), d_ff=12800, vocab=49155.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-3-8b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12800, vocab_size=49155, rope_theta=1e4,
        source="hf:ibm-granite/granite-3.0-2b-base",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=512, vocab_pad_multiple=16,
    )
