"""Granite-3.0-3B-A800M — MoE decoder, 40 experts top-8.

Source: hf:ibm-granite/granite-3.0-1b-a400m-base (family card; 3b-a800m
point). 32L, d_model=1536, 24 heads (kv=8), per-expert d_ff=512,
vocab=49155.
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab_size=49155,
        moe=MoEConfig(n_experts=40, top_k=8, d_expert=512),
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, vocab_pad_multiple=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128),
    )
