"""RecurrentGemma-9B — Griffin: RG-LRU recurrent blocks + local
attention, pattern 1 attention per 2 recurrent layers.

Source: arXiv:2402.19427. 38L, d_model=4096, 16 heads (kv=1 => MQA,
head_dim=256), d_ff=12288, vocab=256000, window=2048.
"""
from repro.configs.base import ModelConfig, HybridConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab_size=256000,
        hybrid=HybridConfig(pattern=("rec", "rec", "attn"),
                            lru_width=4096, conv_width=4,
                            attn_window=2048),
        source="arXiv:2402.19427",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, d_ff=512,
        vocab_size=512, vocab_pad_multiple=16,
        hybrid=HybridConfig(pattern=("rec", "rec", "attn"),
                            lru_width=256, conv_width=4,
                            attn_window=64),
    )
