"""Qwen3-4B — dense decoder with qk_norm and GQA.

Source: hf:Qwen/Qwen3-8B (family card; 4B point). 36L, d_model=2560,
32 heads (kv=8, head_dim=128), d_ff=9728, vocab=151936.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-4b", family="dense",
        n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
        head_dim=128, d_ff=9728, vocab_size=151936,
        qk_norm=True, rope_theta=1e6,
        source="hf:Qwen/Qwen3-8B",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, vocab_pad_multiple=16,
    )
