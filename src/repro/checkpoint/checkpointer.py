"""Pytree checkpointing to .npz (offline-friendly; no orbax dependency).

Layout: one ``step_<N>.npz`` per checkpoint with '/'-joined tree paths as
array keys, plus a tiny JSON sidecar for metadata. Keeps the last
``max_to_keep`` checkpoints.

Wire-compressed checkpoints (``save_wire``/``restore_wire``): the train
state's heavy pieces — the params and the bucket-shaped error-feedback
memory — are serialized through the packed sparse codec
(``repro.core.encoding.snapshot_encode``) instead of dense f32 dumps:

* params buckets: diff-encoded against a base checkpoint when one is
  given (exact, tiny under sparse training), dense-fallback otherwise
  (exact, one header of overhead).
* memory buckets: the per-worker memory is ``W x`` the model size but
  heavy-tailed, so a per-row top-k cap (``memory_ratio``) keeps the
  dominant mass at ``~ratio`` of the dense bytes; error feedback
  self-corrects the truncated residual within a few steps of a resume.

Every record's exact encoded size is accounted in the sidecar
(``meta["wire"]``), and the restore path rebuilds bitwise-identical
params (plus memory exact on the kept support).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional, Sequence

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _set_in(tree: dict, key: str, value):
    parts = key.split("/")
    node = tree
    for p in parts[:-1]:
        node = node.setdefault(p, {})
    node[parts[-1]] = value


class Checkpointer:
    def __init__(self, directory: str, max_to_keep: int = 3):
        self.dir = directory
        self.max_to_keep = max_to_keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}.npz")

    def save(self, step: int, tree, metadata: Optional[dict] = None) -> str:
        flat = _flatten(tree)
        path = self._path(step)
        np.savez(path, **flat)
        meta = dict(metadata or {}, step=step)
        with open(path + ".json", "w") as f:
            json.dump(meta, f)
        self._gc()
        return path

    def steps(self) -> list:
        out = []
        for f in os.listdir(self.dir):
            m = re.match(r"step_(\d+)\.npz$", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: Optional[int] = None,
                like: Any = None) -> tuple:
        """Returns (tree, metadata). If ``like`` is given, the restored
        arrays are reshaped into the same treedef (strict match)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self._path(step)
        data = np.load(path)
        meta = {}
        if os.path.exists(path + ".json"):
            with open(path + ".json") as f:
                meta = json.load(f)
        if like is not None:
            flat_like = _flatten(like)
            missing = set(flat_like) - set(data.files)
            extra = set(data.files) - set(flat_like)
            if missing or extra:
                raise ValueError(
                    f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                    f"extra={sorted(extra)[:5]}"
                )
            leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
            keys = [
                "/".join(
                    str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                    for p in path_
                )
                for path_, _ in leaves_with_path[0]
            ]
            leaves = [data[k] for k in keys]
            return jax.tree_util.tree_unflatten(leaves_with_path[1], leaves), meta
        tree: dict = {}
        for k in data.files:
            _set_in(tree, k, data[k])
        return tree, meta

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.max_to_keep]:
            for suffix in (".npz", ".npz.json"):
                p = os.path.join(self.dir, f"step_{s:08d}{suffix}")
                if os.path.exists(p):
                    os.remove(p)
        for s in self.wire_steps()[: -self.max_to_keep]:
            for suffix in (".wire.npz", ".wire.npz.json"):
                p = os.path.join(self.dir, f"step_{s:08d}{suffix}")
                if os.path.exists(p):
                    os.remove(p)

    # -- wire-compressed checkpoints (packed sparse codec) ------------------

    def _wire_path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}.wire.npz")

    def wire_steps(self) -> list:
        out = []
        for f in os.listdir(self.dir):
            m = re.match(r"step_(\d+)\.wire\.npz$", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_wire_step(self) -> Optional[int]:
        s = self.wire_steps()
        return s[-1] if s else None

    @staticmethod
    def _record_meta(rec) -> dict:
        s = rec.spec
        return {
            "rows": s.rows, "cols": s.cols, "k": s.k,
            "value_dtype": s.value_dtype, "kind": s.kind,
            "vs_base": rec.vs_base, "exact": rec.exact,
            "nbytes": rec.nbytes, "dense_nbytes": rec.dense_nbytes,
            "dropped_frac": rec.dropped_frac,
        }

    def save_wire(
        self,
        step: int,
        params,
        memory: Optional[Sequence],
        plan,
        *,
        base_params=None,
        memory_ratio: Optional[float] = 0.05,
        metadata: Optional[dict] = None,
    ) -> str:
        """Checkpoint (params, bucket memory) through the packed codec.

        ``plan`` is the training ``BucketPlan``; ``memory`` is the tuple
        of bucket-space buffers (any leading worker dims) or None;
        ``base_params`` enables exact diff-vs-base params records (pass
        the same tree to ``restore_wire``). Returns the .wire.npz path;
        the sidecar carries exact per-record size accounting.
        """
        from repro.core import buckets as bk
        from repro.core import encoding as enc

        arrays: dict = {}
        recs_meta = []
        pbufs = bk.pack(plan, params)
        bbufs = (
            bk.pack(plan, base_params) if base_params is not None else None
        )
        for i, cur in enumerate(pbufs):
            rec = enc.snapshot_encode(
                cur, base=None if bbufs is None else bbufs[i]
            )
            arrays[f"params/{i}"] = np.asarray(rec.buf)
            recs_meta.append(dict(self._record_meta(rec), section="params",
                                  index=i))
        for i, m in enumerate(memory or ()):
            m = jax.numpy.asarray(m)
            cols = m.shape[-1]
            k = None
            if memory_ratio is not None:
                k = max(1, round(memory_ratio * cols))
            rec = enc.snapshot_encode(m.reshape(-1, cols), k=k)
            arrays[f"memory/{i}"] = np.asarray(rec.buf)
            recs_meta.append(dict(self._record_meta(rec), section="memory",
                                  index=i, orig_shape=list(m.shape)))
        path = self._wire_path(step)
        with open(path, "wb") as f:  # file object: savez adds no suffix
            np.savez(f, **arrays)
        nbytes = sum(r["nbytes"] for r in recs_meta)
        dense = sum(r["dense_nbytes"] for r in recs_meta)
        meta = dict(
            metadata or {}, step=step,
            wire={
                "records": recs_meta, "nbytes": nbytes,
                "dense_nbytes": dense,
                "ratio_vs_dense": dense / max(1, nbytes),
                "has_base": bbufs is not None,
                "memory_ratio": memory_ratio,
            },
        )
        with open(path + ".json", "w") as f:
            json.dump(meta, f)
        self._gc()
        return path

    def restore_wire(
        self, step: Optional[int] = None, *, plan, base_params=None
    ) -> tuple:
        """Inverse of ``save_wire``: returns (params, memory_bufs, meta).
        ``base_params`` must be the same tree passed at save time for
        diff-encoded records (checked)."""
        from repro.core import buckets as bk
        from repro.core import encoding as enc

        if step is None:
            step = self.latest_wire_step()
        if step is None:
            raise FileNotFoundError(f"no wire checkpoints in {self.dir}")
        path = self._wire_path(step)
        data = np.load(path)
        with open(path + ".json") as f:
            meta = json.load(f)
        bbufs = (
            bk.pack(plan, base_params) if base_params is not None else None
        )
        pbufs: dict = {}
        mem: dict = {}
        for r in meta["wire"]["records"]:
            spec = enc.WireSpec(r["rows"], r["cols"], r["k"],
                                r["value_dtype"], kind=r["kind"])
            rec = enc.SnapshotRecord(
                spec=spec, buf=jax.numpy.asarray(data[f"{r['section']}/{r['index']}"]),
                vs_base=r["vs_base"], exact=r["exact"],
                dense_nbytes=r["dense_nbytes"],
                dropped_frac=r["dropped_frac"],
            )
            if rec.vs_base and bbufs is None:
                raise ValueError(
                    "checkpoint is diff-encoded: pass the base_params tree "
                    "it was saved against"
                )
            if r["section"] == "params":
                base = bbufs[r["index"]] if rec.vs_base else None
                pbufs[r["index"]] = enc.snapshot_decode(rec, base=base)
            else:
                mem[r["index"]] = enc.snapshot_decode(rec).reshape(
                    r["orig_shape"]
                )
        params = bk.unpack(
            plan, [pbufs[i] for i in sorted(pbufs)], cast=True
        )
        memory = tuple(mem[i] for i in sorted(mem))
        return params, memory, meta
