"""Pytree checkpointing to .npz (offline-friendly; no orbax dependency).

Layout: one ``step_<N>.npz`` per checkpoint with '/'-joined tree paths as
array keys, plus a tiny JSON sidecar for metadata. Keeps the last
``max_to_keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _set_in(tree: dict, key: str, value):
    parts = key.split("/")
    node = tree
    for p in parts[:-1]:
        node = node.setdefault(p, {})
    node[parts[-1]] = value


class Checkpointer:
    def __init__(self, directory: str, max_to_keep: int = 3):
        self.dir = directory
        self.max_to_keep = max_to_keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}.npz")

    def save(self, step: int, tree, metadata: Optional[dict] = None) -> str:
        flat = _flatten(tree)
        path = self._path(step)
        np.savez(path, **flat)
        meta = dict(metadata or {}, step=step)
        with open(path + ".json", "w") as f:
            json.dump(meta, f)
        self._gc()
        return path

    def steps(self) -> list:
        out = []
        for f in os.listdir(self.dir):
            m = re.match(r"step_(\d+)\.npz$", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: Optional[int] = None,
                like: Any = None) -> tuple:
        """Returns (tree, metadata). If ``like`` is given, the restored
        arrays are reshaped into the same treedef (strict match)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self._path(step)
        data = np.load(path)
        meta = {}
        if os.path.exists(path + ".json"):
            with open(path + ".json") as f:
                meta = json.load(f)
        if like is not None:
            flat_like = _flatten(like)
            missing = set(flat_like) - set(data.files)
            extra = set(data.files) - set(flat_like)
            if missing or extra:
                raise ValueError(
                    f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                    f"extra={sorted(extra)[:5]}"
                )
            leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
            keys = [
                "/".join(
                    str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                    for p in path_
                )
                for path_, _ in leaves_with_path[0]
            ]
            leaves = [data[k] for k in keys]
            return jax.tree_util.tree_unflatten(leaves_with_path[1], leaves), meta
        tree: dict = {}
        for k in data.files:
            _set_in(tree, k, data[k])
        return tree, meta

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.max_to_keep]:
            for suffix in (".npz", ".npz.json"):
                p = os.path.join(self.dir, f"step_{s:08d}{suffix}")
                if os.path.exists(p):
                    os.remove(p)
