"""Roofline analysis from compiled dry-run artifacts.

Terms (per step, per chip — XLA's SPMD module is the per-device program,
so cost_analysis FLOPs/bytes and HLO shapes are already per-device):

    compute term    = HLO_FLOPs / peak_FLOPs_per_chip
    memory term     = HLO_bytes / HBM_bw
    collective term = collective_bytes / link_bw

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (in+out aggregated per the assignment's constants).

``collective_bytes`` is parsed from ``compiled.as_text()``: we sum the
RESULT shape bytes of every all-gather / all-to-all / collective-permute
op and 2x the size for all-reduce (reduce-scatter + all-gather phases);
reduce-scatter counts its (larger) operand. This is the standard
bytes-on-the-wire approximation for ring algorithms up to the (W-1)/W
factor, which we fold in as 1.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

from repro.utils.shapes import parse_hlo_shape_bytes

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_COLL_RE = re.compile(
    r"=\s*([a-z0-9\[\],{}\s()]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    bbytes: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        nbytes = parse_hlo_shape_bytes(shape_str)
        if kind == "all-reduce":
            nbytes *= 2  # RS + AG phases of a ring all-reduce
        counts[kind] = counts.get(kind, 0) + 1
        bbytes[kind] = bbytes.get(kind, 0.0) + nbytes
    return CollectiveStats(counts=counts, bytes_by_kind=bbytes)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float  # per-device
    hbm_bytes: float  # per-device
    collective_bytes: float  # per-device
    peak_memory_bytes: Optional[float]  # per-device (memory_analysis)
    model_flops: float  # 6*N*D useful flops, per-device share
    collectives: Dict[str, float]
    collective_counts: Dict[str, int]
    comm_message_bytes: Optional[float] = None  # Mem-SGD accounting

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            dominant=self.dominant,
            useful_ratio=self.useful_ratio,
        )
        return d


def model_flops_per_step(n_params_active: int, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D for a train step (fwd+bwd), 2*N*D for inference."""
    c = 6.0 if kind == "train" else 2.0
    return c * n_params_active * tokens


def analyze(
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: dict,
    hlo_text: str,
    peak_memory: Optional[float],
    model_flops_global: float,
    comm_message_bytes: Optional[float] = None,
) -> Roofline:
    coll = parse_collectives(hlo_text)
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops=flops,
        hbm_bytes=nbytes,
        collective_bytes=coll.total_bytes,
        peak_memory_bytes=peak_memory,
        model_flops=model_flops_global / chips,
        collectives=coll.bytes_by_kind,
        collective_counts=coll.counts,
        comm_message_bytes=comm_message_bytes,
    )


def format_table(rows: List[Roofline]) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'mesh':8s} "
        f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
        f"{'dominant':>10s} {'useful':>7s} {'peakGiB':>8s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        peak = (r.peak_memory_bytes or 0) / 2**30
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.mesh:8s} "
            f"{r.compute_s:10.4g} {r.memory_s:10.4g} {r.collective_s:10.4g} "
            f"{r.dominant:>10s} {r.useful_ratio:7.3f} {peak:8.2f}"
        )
    return "\n".join(lines)
