"""Serving path: prefill + batched single-token decode on the mesh.

``serve_step`` consumes ONE new token per sequence against a KV/state
cache of ``seq_len`` (the assigned decode shapes) and returns greedy next
tokens. No shard_map needed: decode is pure model-parallel + batch-parallel
GSPMD (Mem-SGD is a training-time technique; see DESIGN.md).

Replica parameter refresh: ``apply_delta`` consumes the trainer's packed
per-step delta messages (``repro.launch.delta_stream``) so replicas track
training without dense parameter broadcasts — see DESIGN.md for the wire
format.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import sharding as shd

Array = jax.Array


def replica_copy(tree):
    """Deep copy of a pytree into fresh, unaliased device buffers.

    ``make_train_step`` donates (params, memory, opt) — stepping the
    trainer invalidates every alias of those buffers, including a
    serving replica that was created by reference. Any replica held
    across trainer steps (serve, fan-out hub, snapshot base) MUST go
    through this helper; plain ``jax.tree.map(lambda x: x, tree)`` or
    ``jax.device_put`` may alias and die with the donation."""
    return jax.tree.map(lambda x: jnp.array(np.asarray(x)), tree)


def serve_shardings(model, mesh, batch: int, max_len: int,
                    cache_dtype=jnp.bfloat16):
    """NamedSharding pytrees for (params, cache, tokens)."""
    pshapes = model.param_shapes()
    pspecs = shd.drop_undivisible(shd.param_specs(pshapes), pshapes, mesh)
    cshapes = model.cache_shapes(batch, max_len, cache_dtype)
    cspecs = shd.cache_specs(model.cfg, cshapes)
    cspecs = shd.drop_undivisible(cspecs, cshapes, mesh)
    tok_spec = P("data") if batch % mesh.shape["data"] == 0 else P()
    ns = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P)
    )
    return ns(pspecs), ns(cspecs), NamedSharding(mesh, tok_spec)


def make_serve_step(model, mesh, batch: int, max_len: int,
                    cache_dtype=jnp.bfloat16, moe_ep: bool = False):
    """(params, cache, tokens (B,)) -> (next_tokens (B,), new cache)."""
    pshard, cshard, tshard = serve_shardings(model, mesh, batch, max_len,
                                             cache_dtype)

    def step(params, cache, tokens):
        tok = None
        if moe_ep and model.cfg.moe is not None:
            tok = shd.set_moe_sharding(
                NamedSharding(mesh, P(None, "model", None, None)),
                NamedSharding(mesh, P(None, None, None, None)),
                pre=None,  # token-pinning measured WORSE (§Perf C2)
            )
        try:
            logits, new_cache = model.decode_step(params, cache, tokens)
        finally:
            if tok is not None:
                shd.reset_moe_sharding(tok)
        V = model.cfg.vocab_size
        logits = jnp.where(jnp.arange(logits.shape[-1]) < V, logits, -jnp.inf)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_cache

    return (
        jax.jit(step, in_shardings=(pshard, cshard, tshard),
                out_shardings=(tshard, cshard), donate_argnums=(1,)),
        (pshard, cshard, tshard),
    )


def make_prefill_step(model, mesh, shape_cfg, moe_ep: bool = False):
    """(params, batch) -> last-position logits (B, V_padded)."""
    pshapes = model.param_shapes()
    pspecs = shd.drop_undivisible(shd.param_specs(pshapes), pshapes, mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    B = shape_cfg.global_batch
    data_ok = B % mesh.shape["data"] == 0
    bspec = P("data") if data_ok else P()
    bshard = NamedSharding(mesh, bspec)

    def batch_shardings(batch_tree):
        return jax.tree.map(lambda _: bshard, batch_tree)

    def step(params, batch):
        tok = None
        if moe_ep and model.cfg.moe is not None:
            n_ax = "data" if data_ok else None
            tok = shd.set_moe_sharding(
                NamedSharding(mesh, P(n_ax, "model", None, None)),
                NamedSharding(mesh, P(n_ax, None, None, None)),
                pre=None,  # token-pinning measured WORSE (§Perf C2)
            )
        try:
            return model.prefill_logits(params, batch)
        finally:
            if tok is not None:
                shd.reset_moe_sharding(tok)

    return jax.jit(step), pshard, batch_shardings


def apply_delta(params, dspec, msgs):
    """Refresh serving params from one trainer delta message (packed
    sparse wire buffers; see ``repro.launch.delta_stream``). Bitwise
    reproduces the trainer's own parameter update for f32 streams.
    jit-compatible; safe to fold into the serving loop between decode
    steps."""
    from repro.launch.delta_stream import apply_delta as _apply

    return _apply(params, dspec, msgs)


def decode_loop(model, mesh, params, prompts: Array, n_tokens: int,
                max_len: int, cache_dtype=jnp.bfloat16):
    """Greedy generation driver: consumes prompts token-by-token (teacher
    forcing into the cache) then generates ``n_tokens`` greedily."""
    B, PL = prompts.shape
    step, (pshard, cshard, tshard) = make_serve_step(
        model, mesh, B, max_len, cache_dtype
    )
    cache = jax.device_put(model.init_cache(B, max_len, cache_dtype), cshard)
    params = jax.device_put(params, pshard)
    tok = prompts[:, 0]
    out = []
    for t in range(PL - 1):
        nxt, cache = step(params, cache, tok)
        tok = prompts[:, t + 1]  # teacher-force the prompt
    for _ in range(n_tokens):
        tok, cache = step(params, cache, tok)
        out.append(tok)
    return jnp.stack(out, axis=1)
