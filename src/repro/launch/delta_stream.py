"""Trainer -> serving-replica parameter deltas over the packed wire.

A serving replica only needs the per-step change of the parameters, and
under Mem-SGD that change IS the sparse bucket message: the applied
update is the densified mean of the workers' top-k selections, so its
support per (rows, cols) bucket row is at most ``W * k_row`` entries
(``n_pods * k_pod`` for hierarchical sync). Re-selecting top-k' of the
update buffer with k' = that support bound therefore captures EVERY
nonzero, and streaming it through ``repro.core.encoding`` costs
``k' * (value_bits + ceil(log2 cols))`` bits per row instead of a full
dense parameter broadcast — the same d/k reduction the training sync
enjoys, now on the trainer->replica refresh path.

Exactness: the replica re-applies ``p - u.astype(p.dtype)`` with the
bit-identical ``u`` the trainer subtracted (f32 wire values), so replica
parameters track trainer parameters bitwise step by step. Dense buckets
(norm scales, biases) stream uncompressed through a ``kind="dense"``
wire message. With ``value_dtype="bfloat16"`` the stream is lossy
(rounded values) but half the size — a knob for bandwidth-starved
replica fleets.

All specs are static; ``encode_delta``/``decode_delta``/``apply_delta``
are jit-compatible and run inside the train step / serve step.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import buckets as bk
from repro.core import encoding as enc
from repro.core.distributed import (
    SyncConfig,
    _row_scatter,
    _row_topk,
    validate_pod_ratios,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DeltaSpec:
    """Static wire layout of one trainer->replica delta message: one
    ``WireSpec`` per bucket of the training ``BucketPlan``."""

    plan: bk.BucketPlan
    wires: Tuple[enc.WireSpec, ...]

    @property
    def nbytes(self) -> int:
        """Exact bytes per streamed step."""
        return sum(w.nbytes for w in self.wires)

    @property
    def dense_nbytes(self) -> int:
        """Bytes a dense f32 parameter broadcast would cost."""
        return sum(s.rows * s.cols * 4 for s in self.plan.buckets)

    def with_value_dtype(self, value_dtype: str) -> "DeltaSpec":
        """Same per-bucket layout with another wire value dtype (the
        static spec of a ``transcode_delta``'d message set)."""
        return DeltaSpec(
            plan=self.plan,
            wires=tuple(w.with_value_dtype(value_dtype) for w in self.wires),
        )


def make_delta_spec(
    plan: bk.BucketPlan,
    cfg: SyncConfig,
    workers: int,
    n_pods: int = 1,
    value_dtype: str = "float32",
) -> DeltaSpec:
    """Derive the per-bucket wire layout from the training sync config.

    ``workers``/``n_pods`` bound the update support per row (see module
    docstring); ``value_dtype="float32"`` keeps the stream bitwise-exact.

    With ``cfg.pod_dynamic`` the hierarchical support bound follows the
    bucket's static ``pod_k_max_for_bucket`` — NOT the step-0 live k —
    so a mid-run pod-ratio refresh that RAISES k can never exceed the
    encoded support (the spec is fixed for the stream's lifetime; sizing
    it from the current k would silently drop update entries after the
    first upward refresh).
    """
    validate_pod_ratios(cfg, plan)
    wires: List[enc.WireSpec] = []
    for b, spec in enumerate(plan.buckets):
        if cfg.strategy == "dense" or spec.kind == "dense":
            wires.append(
                enc.WireSpec(spec.rows, spec.cols, spec.cols, value_dtype,
                             kind="dense")
            )
            continue
        if cfg.strategy == "hierarchical" and cfg.pod_axis is not None:
            if cfg.pod_dynamic:
                n_data = max(1, workers // max(n_pods, 1))
                support = n_pods * cfg.pod_k_max_for_bucket(
                    b, spec.cols, n_data
                )
            else:
                support = n_pods * cfg.pod_k_for_bucket(b, spec.cols)
        else:
            support = workers * cfg.k_for(spec.cols)
        wires.append(
            enc.WireSpec(spec.rows, spec.cols, min(spec.cols, support),
                         value_dtype)
        )
    return DeltaSpec(plan=plan, wires=tuple(wires))


def encode_delta_bufs(dspec: DeltaSpec, bufs: Sequence[Array]) -> List[Array]:
    """Bucket-space update buffers (e.g. from
    ``bucketed_sync_gradients(..., return_bufs=True)``) -> one uint32
    wire buffer per bucket. Sparse buckets re-select top-k' per row;
    since k' bounds the update's support this captures every nonzero
    entry exactly (extra slots carry zeros, which scatter as no-ops)."""
    out = []
    for wspec, buf in zip(dspec.wires, bufs):
        buf = buf.astype(jnp.float32)
        if wspec.kind == "dense":
            out.append(enc.encode(wspec, buf))
        else:
            vals, idx = _row_topk(buf, wspec.k)
            out.append(enc.encode(wspec, vals, idx))
    return out


def encode_delta(dspec: DeltaSpec, update_tree) -> List[Array]:
    """Update pytree (the tree the trainer subtracts from params) -> wire
    buffers. Packs the tree into bucket space first; prefer
    ``encode_delta_bufs`` when the bucket buffers already exist."""
    return encode_delta_bufs(
        dspec, bk.pack(dspec.plan, update_tree, dtype=jnp.float32)
    )


def decode_delta(dspec: DeltaSpec, msgs: Sequence[Array]):
    """Wire buffers -> dense f32 update pytree (exact on the support)."""
    bufs = []
    for wspec, msg in zip(dspec.wires, msgs):
        vals, idx = enc.decode(wspec, msg)
        if wspec.kind == "dense":
            bufs.append(vals.astype(jnp.float32))
        else:
            bufs.append(
                _row_scatter(
                    (wspec.rows, wspec.cols), vals.astype(jnp.float32),
                    idx, jnp.float32,
                )
            )
    return bk.unpack(dspec.plan, bufs)


def transcode_delta(
    dspec: DeltaSpec, msgs: Sequence[Array], value_dtype: str = "bfloat16"
) -> List[Array]:
    """Re-encode one step's wire buffers in another value dtype (see
    ``repro.core.encoding.transcode``). f32 -> bf16 halves the value
    sections at the cost of rounded (non-bitwise) replica tracking; the
    result decodes against ``dspec.with_value_dtype(value_dtype)``."""
    return [
        enc.transcode(w, m, value_dtype) for w, m in zip(dspec.wires, msgs)
    ]


def apply_delta(params, dspec: DeltaSpec, msgs: Sequence[Array]):
    """One replica refresh step: ``params - decode(msgs)`` leaf-wise —
    the identical subtraction the trainer performed, so an f32 stream
    keeps replica params bitwise equal to trainer params."""
    update = decode_delta(dspec, msgs)
    return jax.tree.map(
        lambda p, u: (p - u.astype(p.dtype)), params, update
    )
