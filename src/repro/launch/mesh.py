"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices; smoke tests and benchmarks see the
single real CPU device.
"""
from __future__ import annotations

from repro.utils.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 per pod (256 chips); 2x16x16 across two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh for multi-device CPU tests (requires forced host devices)."""
    return make_mesh((n_data, n_model), ("data", "model"))


def make_pod_debug_mesh(n_pods: int = 2, n_data: int = 4, n_model: int = 1):
    """Multi-pod mesh for CPU tests of the two-level pod sync (requires
    ``XLA_FLAGS=--xla_force_host_platform_device_count>=n_pods*n_data``)."""
    return make_mesh((n_pods, n_data, n_model), ("pod", "data", "model"))


def mesh_from_config(mc):
    """Materialize a ``repro.configs.MeshConfig`` (named mesh layout)."""
    return make_mesh(mc.shape, mc.axis_names)


def data_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a == "data")


def pod_axis_of(mesh):
    return "pod" if "pod" in mesh.axis_names else None


def n_chips(mesh) -> int:
    return int(mesh.devices.size)
