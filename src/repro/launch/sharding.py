"""Sharding rules: parameter PartitionSpecs, sync row axes, activation
constraints.

Conventions (per pod: mesh ("data", "model"); multi-pod adds leading
"pod"):

* batch axis          -> ("pod", "data")  [or ("data",)]
* tensor parallel     -> "model": attention heads / FFN hidden / experts /
                         vocab, per the rules below
* per-worker Mem-SGD memory -> leading worker axis over ("pod","data"),
  remaining axes like the parameter
* activations         -> batch over data (implicit inside shard_map);
  optional sequence sharding over "model" for the stacked-layer scan carry
  (sequence parallelism; enabled by the train driver for long sequences).
"""
from __future__ import annotations

import contextvars

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

Array = jax.Array

# rules: leaf-name -> (partition spec dims AFTER the optional stacked-L
# axis, col_axis for the sparse sync, counted over the SAME trailing dims).
# spec entries: None or "model". col_axis: index into the trailing dims of
# a NON-"model" axis whose extent is a sensible row length.
_RULES = {
    # embeddings / head: vocab-parallel embed (selection along d_model per
    # vocab row). Measured better than D-sharded embed by 7.5s/step of
    # collective time on yi-9b train_4k (§Perf iteration A2a, refuted).
    "embed": (("model", None), 1),
    "lm_head": ((None, "model"), 0),
    # attention
    "wq": ((None, "model"), 0),
    "wk": ((None, "model"), 0),
    "wv": ((None, "model"), 0),
    "wo": (("model", None), 1),
    "bq": (("model",), 0),
    "bk": (("model",), 0),
    "bv": (("model",), 0),
    "q_norm": ((None,), 0),
    "k_norm": ((None,), 0),
    # dense mlp
    "w_gate": ((None, "model"), 0),
    "w_up": ((None, "model"), 0),
    "w_down": (("model", None), 1),
    # moe (experts stacked on leading E axis of the trailing dims)
    "router": ((None, None), 1),
    "moe/w_gate": (("model", None, None), 2),
    "moe/w_up": (("model", None, None), 2),
    "moe/w_down": (("model", None, None), 2),
    # rwkv time/channel mix
    "wr": ((None, "model"), 0),
    "wg": ((None, "model"), 0),
    "mix_w1": ((None, None), 1),
    "mix_w2": ((None, None, "model"), 1),
    "decay_w1": ((None, None), 0),
    "decay_w2": ((None, "model"), 0),
    "w0": ((None,), 0),
    "mu": ((None, None), 1),
    "mu_base": ((None,), 0),
    "mu_k": ((None,), 0),
    "mu_r": ((None,), 0),
    "bonus": ((None, None), 1),
    "gn": ((None, None), 1),
    # griffin recurrent block
    "w_in": ((None, "model"), 0),
    "w_gate_in": ((None, "model"), 0),
    "conv_w": ((None, "model"), 0),
    "conv_b": (("model",), 0),
    "w_a": ((None, "model"), 0),
    "b_a": (("model",), 0),
    "w_x": ((None, "model"), 0),
    "b_x": (("model",), 0),
    "lam": (("model",), 0),
    "w_out": (("model", None), 1),
    # griffin mlp
    "w1": ((None, "model"), 0),
    "w2": ((None, "model"), 0),
    "w3": (("model", None), 1),
    # norms
    "ln1": ((None,), 0),
    "ln2": ((None,), 0),
    "ln_f": ((None,), 0),
}


def _leaf_name(path) -> str:
    keys = [str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path]
    name = keys[-1]
    if "moe" in keys and name in ("w_gate", "w_up", "w_down"):
        return f"moe/{name}"
    return name


def _rule_for(path, leaf) -> tuple:
    name = _leaf_name(path)
    if name not in _RULES:
        # default: replicate, col = last axis
        return (None,) * leaf.ndim, max(0, leaf.ndim - 1)
    dims, col = _RULES[name]
    nd = leaf.ndim
    if nd == len(dims):
        return dims, col
    if nd == len(dims) + 1:  # stacked layer axis in front
        return (None,) + dims, col + 1
    if nd > len(dims):  # e.g. extra stacking; left-pad with None
        pad = nd - len(dims)
        return (None,) * pad + dims, col + pad
    # fewer dims than the rule (shouldn't happen): replicate
    return (None,) * nd, max(0, nd - 1)


def param_specs(params_shapes) -> object:
    """Pytree of PartitionSpec matching a parameter pytree (by leaf name)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = [P(*_rule_for(path, leaf)[0]) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def sync_col_axes(params_shapes) -> object:
    """Pytree of ints: row-block column axis per leaf for the sparse sync."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    cols = [_rule_for(path, leaf)[1] for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, cols)


def memory_specs(params_shapes, data_axes) -> object:
    """Per-worker memory: leading worker axis over the data axes, then the
    parameter's own spec."""
    ps = param_specs(params_shapes)
    ax = tuple(data_axes)
    worker = ax if len(ax) > 1 else ax[0]
    return jax.tree.map(lambda s: P(worker, *s), ps)


def cache_specs(cfg, cache_shapes, mesh_axes=("data", "model")) -> object:
    """KV/state cache sharding for decode.

    Rules: batch axis over "data" when divisible; kv-head axis over
    "model" when divisible, else head_dim; recurrent widths over "model".
    """
    data = "data"

    def spec_for(path, leaf) -> P:
        name = _leaf_name(path)
        shape = leaf.shape
        nd = len(shape)
        if name == "index":
            return P()
        # locate batch axis: transformer caches are (L, B, C, KV, hd) or
        # (B, C, KV, hd); rwkv states (L, B, ...) / (B, ...); griffin
        # per-layer states (B, ...).
        dims = [None] * nd
        # batch: first axis whose size matches no other rule; heuristics by
        # name:
        if name in ("k", "v"):
            b_ax = nd - 4  # (..., B, C, KV, hd)
            kv_ax, hd_ax = nd - 2, nd - 1
            dims[b_ax] = data
            if shape[kv_ax] % 16 == 0:
                dims[kv_ax] = "model"
            elif shape[hd_ax] % 16 == 0:
                dims[hd_ax] = "model"
        elif name in ("time_shift", "chan_shift"):
            dims[nd - 2] = data  # (L, B, D) or (B, D)
            dims[nd - 1] = "model"
        elif name == "wkv":
            dims[nd - 4] = data  # (..., B, H, n, n)
            if shape[nd - 3] % 16 == 0:
                dims[nd - 3] = "model"
        elif name == "h":
            dims[nd - 2] = data  # (B, R)
            dims[nd - 1] = "model"
        elif name == "conv":
            dims[nd - 3] = data  # (B, W-1, R)
            dims[nd - 1] = "model"
        # drop the data axis if batch not divisible (e.g. long_500k B=1)
        return P(*dims)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    specs = [spec_for(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def drop_undivisible(spec_tree, shape_tree, mesh) -> object:
    """Replace axis assignments that don't divide the dimension (GSPMD
    would pad; we prefer explicit replication)."""

    def fix(spec: P, leaf) -> P:
        dims = []
        for i, s in enumerate(spec):
            if s is None:
                dims.append(None)
                continue
            names = s if isinstance(s, tuple) else (s,)
            size = 1
            for n in names:
                size *= mesh.shape[n]
            if leaf.shape[i] % size == 0:
                dims.append(s)
            else:
                dims.append(None)
        return P(*dims)

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation sharding hook (sequence parallelism for the layer-scan carry)
# ---------------------------------------------------------------------------

_ACT_SHARDING: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None
)


def set_activation_sharding(sharding) -> contextvars.Token:
    return _ACT_SHARDING.set(sharding)


def reset_activation_sharding(token) -> None:
    _ACT_SHARDING.reset(token)


def shard_activations(x: Array) -> Array:
    s = _ACT_SHARDING.get()
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


# ---------------------------------------------------------------------------
# MoE expert-parallel constraints (§Perf: dispatch via all-to-all, not
# buffer replication). The step builders set (dispatch_sharding,
# combine_sharding) for the (N, E, C, D) capacity buffers: dispatch moves
# the scattered buffer to expert-sharded layout (GSPMD inserts an
# all-to-all), combine moves the expert outputs back to token layout.
# ---------------------------------------------------------------------------

_MOE_SHARDING: contextvars.ContextVar = contextvars.ContextVar(
    "moe_sharding", default=None
)


def set_moe_sharding(dispatch, combine, pre=None) -> contextvars.Token:
    """pre: token-layout sharding pinned on the capacity buffer BEFORE the
    dispatch scatter (keeps the scatter shard-local over tokens; without
    it GSPMD replicates the f32-promoted scatter operands — §Perf C2)."""
    return _MOE_SHARDING.set((dispatch, combine, pre))


def reset_moe_sharding(token) -> None:
    _MOE_SHARDING.reset(token)


def constrain_moe_dispatch(buf: Array) -> Array:
    s = _MOE_SHARDING.get()
    if s is None:
        return buf
    return jax.lax.with_sharding_constraint(buf, s[0])


def constrain_moe_combine(y: Array) -> Array:
    s = _MOE_SHARDING.get()
    if s is None:
        return y
    return jax.lax.with_sharding_constraint(y, s[1])


def constrain_moe_tokens(x: Array) -> Array:
    """Pin token-layout tensors (pre-dispatch buffer / contrib / output)."""
    s = _MOE_SHARDING.get()
    if s is None or s[2] is None:
        return x
    spec = s[2].spec
    dims = list(spec) + [None] * (x.ndim - len(spec))
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(s[2].mesh, PartitionSpec(*dims[: x.ndim]))
    )
