import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first initialization). Dry-run only: smoke tests and
# benchmarks see the single real CPU device.

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers, compiles, fits, and expose its roofline terms.

For each pair it builds the REAL jitted step (train_step for train shapes,
prefill/serve steps for inference shapes) over abstract
ShapeDtypeStruct inputs carrying NamedShardings — no device allocation —
then ``.lower().compile()`` on the production mesh and records:

  * ``compiled.memory_analysis()``  (per-device bytes — proves it fits)
  * ``compiled.cost_analysis()``    (FLOPs / bytes for the roofline)
  * collective op bytes parsed from the compiled HLO
  * the Mem-SGD message accounting (bytes the sparse sync transmits)

Results go to ``experiments/dryrun/<arch>_<shape>_<mesh>[_tag].json``.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--strategy hierarchical]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core.distributed import SyncConfig, message_bytes
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh, n_chips
from repro.launch.serve import make_serve_step, make_prefill_step
from repro.launch.train import (
    TrainConfig,
    init_train_state,
    make_train_step,
    state_shardings,
)
from repro.models import build_model
from repro.roofline import analysis as roofline

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# long_500k needs sub-quadratic attention: native for rwkv/hybrid; dense,
# moe and modal archs run their sliding-window variant (DESIGN.md).
LONG_CTX_WINDOW = 4096


def _abstract(tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings,
    )


def _abstract_repl(tree, mesh):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, l.dtype, sharding=NamedSharding(mesh, P())
        ),
        tree,
    )


def prepare_config(arch: str, shape_name: str, remat: str = "full"):
    cfg = get_config(arch)
    tag = ""
    if shape_name == "long_500k" and cfg.family in ("dense", "moe"):
        cfg = cfg.replace(sliding_window=LONG_CTX_WINDOW)
        tag = "+swa"
    if SHAPES[shape_name].kind == "train":
        cfg = cfg.replace(remat=remat)
    return cfg, tag


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               strategy: str = "sparse_allgather", optimizer: str = "memsgd",
               sync_ratio: float = 1e-3, seq_shard: bool = False,
               microbatch: int = 1, value_dtype: str = "float32",
               layout: str = "batched", moe_ep: bool = False,
               constrain: bool = False, selection: str = "argmax_onehot",
               remat: str = "full",
               n_layers_override=None, unroll_layers: bool = False):
    """Returns (lowered, aux dict). Raises on sharding/lowering bugs."""
    from repro.models import layers as Lmod

    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    cfg, tag = prepare_config(arch, shape_name, remat=remat)
    if n_layers_override is not None:
        cfg = cfg.replace(n_layers=n_layers_override)
    # Probes unroll everything for exact cost accounting; the full-scale
    # lowering keeps the compact scan form (its flops/bytes are replaced
    # by the probe-corrected values; it contributes compile-success +
    # memory_analysis). Hybrid (griffin) has no layer scan — its full
    # lowering IS the accounting, so its blocked-attention loops unroll.
    Lmod.set_unroll_layers(unroll_layers)
    Lmod.set_unroll_blocks(unroll_layers or cfg.family == "hybrid")
    model = build_model(cfg)
    aux = {"tag": tag, "mesh_shape": tuple(mesh.shape.values()),
           "chips": n_chips(mesh)}

    if shape.kind == "train":
        tc = TrainConfig(
            optimizer=optimizer, eta=0.1,
            sync=SyncConfig(ratio=sync_ratio, strategy=strategy,
                            value_dtype=value_dtype, layout=layout,
                            constrain_intermediates=constrain,
                            selection=selection),
            seq_shard_activations=seq_shard, microbatch=microbatch,
            moe_ep_constraints=moe_ep,
        )
        state = init_train_state(model, mesh, tc, abstract=True)
        pshard, mshard, oshard, cshard = state_shardings(model, mesh, tc)
        params, memory, opt, count = state
        a_params = _abstract(params, pshard)
        a_mem = _abstract(memory, mshard)
        a_opt = _abstract(opt, oshard) if oshard != () else ()
        a_count = jax.ShapeDtypeStruct((), jnp.int32, sharding=cshard)
        specs = model.input_specs(shape)
        waxes = ("pod", "data") if multi_pod else ("data",)
        bspec = P(waxes if len(waxes) > 1 else waxes[0])
        a_batch = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(mesh, bspec)),
            specs,
        )
        step = make_train_step(model, mesh, tc)
        lowered = step.lower(a_params, a_mem, a_opt, a_count, a_batch)
        pshapes = model.param_shapes()
        aux["comm_message_bytes"] = message_bytes(
            SyncConfig(ratio=sync_ratio, strategy=strategy,
                       pod_axis="pod" if multi_pod else None),
            pshapes, shd.sync_col_axes(pshapes),
        )
        tokens = shape.global_batch * shape.seq_len
        aux["model_flops"] = roofline.model_flops_per_step(
            model.n_active_params(), tokens, "train")
    elif shape.kind == "prefill":
        step, pshard, batch_shardings = make_prefill_step(
            model, mesh, shape, moe_ep=moe_ep)
        specs = model.input_specs(shape)
        a_params = _abstract(model.param_shapes(), pshard)
        a_batch = _abstract(specs, batch_shardings(specs))
        lowered = step.lower(a_params, a_batch)
        tokens = shape.global_batch * shape.seq_len
        aux["model_flops"] = roofline.model_flops_per_step(
            model.n_active_params(), tokens, "prefill")
    else:  # decode
        B = shape.global_batch
        step, (pshard, cshard, tshard) = make_serve_step(
            model, mesh, B, shape.seq_len, moe_ep=moe_ep)
        a_params = _abstract(model.param_shapes(), pshard)
        a_cache = _abstract(model.cache_shapes(B, shape.seq_len), cshard)
        a_tok = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=tshard)
        lowered = step.lower(a_params, a_cache, a_tok)
        aux["model_flops"] = roofline.model_flops_per_step(
            model.n_active_params(), B, "decode")
    return lowered, aux, mesh


def _probe_metrics(arch, shape_name, n_layers, **kw):
    """Compile a reduced-depth probe with the layer scan fully unrolled;
    returns (flops, bytes, collective_bytes) — exact, no scan-once bias."""
    lowered, _, _ = lower_pair(
        arch, shape_name, n_layers_override=n_layers, unroll_layers=True, **kw
    )
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = roofline.parse_collectives(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        coll.total_bytes,
    )


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             strategy: str = "sparse_allgather", optimizer: str = "memsgd",
             sync_ratio: float = 1e-3, out_dir: str = OUT_DIR,
             tag_extra: str = "", probe: bool = True,
             seq_shard: bool = False, microbatch: int = 1,
             value_dtype: str = "float32", layout: str = "batched",
             moe_ep: bool = False, constrain: bool = False,
             selection: str = "argmax_onehot", remat: str = "full",
             skip_full: bool = False) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    opt_kw = dict(seq_shard=seq_shard, microbatch=microbatch,
                  value_dtype=value_dtype, layout=layout, moe_ep=moe_ep,
                  constrain=constrain, selection=selection, remat=remat)
    t0 = time.time()
    if skip_full:
        # perf-iteration mode: probes carry all roofline metrics; the full
        # compile (memory proof) is reused from the baseline record.
        _, aux, mesh = lower_pair(
            arch, shape_name, multi_pod=multi_pod, strategy=strategy,
            optimizer=optimizer, sync_ratio=sync_ratio,
            n_layers_override=2, unroll_layers=True, **opt_kw,
        )
        t_lower = time.time() - t0
        t_compile = 0.0
        mem = None
        cost = {}
        hlo = ""
        # aux computed for the 2-layer probe: recompute at full depth
        cfg_tmp, _ = prepare_config(arch, shape_name)
        model_tmp = build_model(cfg_tmp)
        shape_tmp = SHAPES[shape_name]
        tokens = (shape_tmp.global_batch * shape_tmp.seq_len
                  if not shape_tmp.is_decode else shape_tmp.global_batch)
        aux["model_flops"] = roofline.model_flops_per_step(
            model_tmp.n_active_params(), tokens,
            shape_tmp.kind if shape_tmp.kind != "decode" else "decode")
    else:
        lowered, aux, mesh = lower_pair(
            arch, shape_name, multi_pod=multi_pod, strategy=strategy,
            optimizer=optimizer, sync_ratio=sync_ratio, **opt_kw,
        )
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    # XLA cost_analysis counts while-loop (scan) bodies ONCE. For families
    # whose layers run under lax.scan (dense/moe/rwkv) we recover the exact
    # affine dependence on depth from two unrolled probes:
    #   X(L) = X(2) + (X(4) - X(2))/2 * (L - 2)
    raw_cost = dict(
        flops=float(cost.get("flops", 0.0)),
        hbm_bytes=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=roofline.parse_collectives(hlo).total_bytes,
    )
    cfg_full, _ = prepare_config(arch, shape_name)
    corrected = None
    if probe and cfg_full.family in ("dense", "moe", "rwkv"):
        kw = dict(multi_pod=multi_pod, strategy=strategy,
                  optimizer=optimizer, sync_ratio=sync_ratio, **opt_kw)
        f2, b2, c2 = _probe_metrics(arch, shape_name, 2, **kw)
        f4, b4, c4 = _probe_metrics(arch, shape_name, 4, **kw)
        L = cfg_full.n_layers
        corrected = dict(
            flops=f2 + (f4 - f2) / 2 * (L - 2),
            hbm_bytes=b2 + (b4 - b2) / 2 * (L - 2),
            collective_bytes=c2 + (c4 - c2) / 2 * (L - 2),
        )
        cost = dict(cost)
        cost["flops"] = corrected["flops"]
        cost["bytes accessed"] = corrected["hbm_bytes"]
    if mem is None:
        peak = None
    else:
        peak = getattr(mem, "temp_size_in_bytes", 0) + getattr(
            mem, "argument_size_in_bytes", 0) + getattr(
            mem, "output_size_in_bytes", 0) - getattr(
            mem, "alias_size_in_bytes", 0)
    rl = roofline.analyze(
        arch=arch + aux["tag"] + tag_extra,
        shape=shape_name,
        mesh_name=mesh_name,
        chips=aux["chips"],
        cost=cost,
        hlo_text=hlo,
        peak_memory=peak,
        model_flops_global=aux["model_flops"],
        comm_message_bytes=aux.get("comm_message_bytes"),
    )
    if corrected is not None:
        rl.collective_bytes = corrected["collective_bytes"]
    rec = rl.to_dict()
    rec["raw_scan_once"] = raw_cost
    rec["probe_corrected"] = corrected is not None
    rec.update(
        t_lower_s=t_lower,
        t_compile_s=t_compile,
        strategy=strategy,
        optimizer=optimizer,
        sync_ratio=sync_ratio,
        seq_shard=seq_shard,
        microbatch=microbatch,
        value_dtype=value_dtype,
        layout=layout,
        moe_ep=moe_ep,
        generated_code_bytes=getattr(mem, "generated_code_size_in_bytes", None),
        argument_bytes=getattr(mem, "argument_size_in_bytes", None),
        temp_bytes=getattr(mem, "temp_size_in_bytes", None),
        output_bytes=getattr(mem, "output_size_in_bytes", None),
    )
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}_{shape_name}_{mesh_name}{aux['tag']}{tag_extra}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=2, default=str)
    peak_str = f"{peak/2**30:.2f}GiB" if peak is not None else "n/a"
    print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:8s} "
          f"dominant={rl.dominant:10s} compute={rl.compute_s:.4g}s "
          f"mem={rl.memory_s:.4g}s coll={rl.collective_s:.4g}s "
          f"peak={peak_str} compile={t_compile:.0f}s", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="sparse_allgather")
    ap.add_argument("--optimizer", default="memsgd")
    ap.add_argument("--sync-ratio", type=float, default=1e-3)
    ap.add_argument("--out-dir", default=OUT_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    if args.skip_existing:
        mesh_name = "2x16x16" if args.multi_pod else "16x16"

        def _done(a, s):
            import glob
            pat = os.path.join(args.out_dir,
                               f"{a}_{s}_{mesh_name}*{args.tag}.json")
            return bool(glob.glob(pat))

        pairs = [(a, s) for a, s in pairs if not _done(a, s)]
        print(f"[dryrun] {len(pairs)} pairs remaining")

    failures = []
    for a, s in pairs:
        try:
            run_pair(a, s, multi_pod=args.multi_pod, strategy=args.strategy,
                     optimizer=args.optimizer, sync_ratio=args.sync_ratio,
                     out_dir=args.out_dir, tag_extra=args.tag)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append((a, s, repr(e)))
            print(f"[dryrun] FAIL {a} {s}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"{len(failures)} failures: {failures}")
        raise SystemExit(1)
    print("dry-run: all pairs lowered and compiled.")


if __name__ == "__main__":
    main()
