"""Launch layer: meshes, sharding rules, train/serve steps, dry-run.

NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS at import — import it only in
a dedicated process (``python -m repro.launch.dryrun``).
"""
