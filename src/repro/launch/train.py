"""Distributed training step + driver (PARALLEL-MEM-SGD on a TPU mesh).

``make_train_step`` builds the jitted step:

  * OUTER: ``jax.jit`` with NamedShardings (params tensor-parallel over
    "model", batch + per-worker memory over the data axes).
  * INNER: ``jax.shard_map`` manual over the data axes, auto over "model".
    Each data shard computes its own gradient (GSPMD handles the model
    axis inside), applies error-feedback memory + row-block top-k, and the
    shards exchange only (values, indices) pairs (sparse all-gather). See
    ``repro.core.distributed``.

Optimizer modes:
  * ``memsgd``       — paper Algorithm 1/2: update = comp_k(m + eta*g),
    params -= mean_w(update). eta consumed at memory insertion.
  * ``memsgd_momentum`` — beyond-paper: heavy-ball momentum applied to the
    synced sparse update.
  * ``adam_compressed`` — beyond-paper: the sync (with eta=1) produces the
    averaged sparse gradient; Adam consumes it. Memory semantics preserved.
  * ``dense``        — vanilla data-parallel baseline (dense all-reduce),
    for communication comparisons.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import PodRefreshConfig
from repro.core import buckets as bk
from repro.core.distributed import (
    SyncConfig,
    bucketed_sync_gradients,
    sparse_sync_gradients,
)
from repro.launch import sharding as shd
from repro.utils import compat
from repro.utils.telemetry import NonFiniteLossError, Telemetry

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "memsgd"  # memsgd | memsgd_momentum | adam_compressed | dense
    eta: float = 0.1  # base stepsize (or peak LR for adam)
    eta_shift: float = 0.0  # a>0 enables eta_t = eta/(1 + t/a) style decay
    momentum: float = 0.9
    sync: SyncConfig = dataclasses.field(default_factory=SyncConfig)
    # Perf levers (see EXPERIMENTS.md §Perf):
    seq_shard_activations: bool = False  # Megatron-style sequence parallel
    microbatch: int = 1  # gradient accumulation over the local batch
    moe_ep_constraints: bool = False  # expert-parallel a2a dispatch
    # Emit the applied update as packed per-bucket delta messages for
    # serving replicas (repro.launch.delta_stream). Requires
    # sync.bucketed and optimizer="memsgd"/"dense" (the only modes whose
    # parameter delta equals the synced update). The step then returns a
    # sixth output: a tuple of uint32 wire buffers.
    emit_deltas: bool = False
    delta_value_dtype: str = "float32"  # bf16 halves the stream (lossy)
    # Two-level pod sync: autotune per-bucket pod re-compression ratios
    # (SyncConfig.pod_ratios) from the first batch's realized gradient
    # mass capture when training hierarchical+bucketed on a pod mesh and
    # no explicit ratios were given (see
    # repro.core.distributed.autotune_pod_ratios).
    pod_autotune: bool = True
    # Live pod-ratio refresh (configs.PodRefreshConfig): re-run the
    # autotune every N steps on the live memory+gradient bucket buffers
    # and feed the new per-bucket pod ks into the RUNNING jitted step —
    # the k-padded wire (SyncConfig.pod_dynamic, forced on when enabled)
    # makes the live k a plain data input, so no step ever re-jits.
    pod_refresh: Optional[PodRefreshConfig] = None
    # Base seed for the QSGD stochastic-rounding PRNG (WireConfig.quant):
    # the step folds the step count in, each quantize stage folds its
    # bucket/level/axis indices — two runs with the same seed draw the
    # same rounding noise (reproducible quantized training).
    quant_seed: int = 0


def _eta_schedule(tc: TrainConfig):
    if tc.eta_shift > 0:
        a = tc.eta_shift
        return lambda t: tc.eta * a / (a + t.astype(jnp.float32))
    return lambda t: jnp.asarray(tc.eta, jnp.float32)


def _eta_at(tc: TrainConfig, t: int) -> float:
    """Host-side mirror of ``_eta_schedule`` for the refresh path.

    The calibration branch needs eta_t on host; syncing the device step
    count with ``float(...)`` mid-loop would stall the dispatch queue
    (RL001), and the loop index is the same value already on host —
    ``count`` starts at 0 in ``train()`` and every step variant
    (step/sync/accum) increments it exactly once per dispatched step.
    """
    if tc.eta_shift > 0:
        return float(tc.eta * tc.eta_shift / (tc.eta_shift + float(t)))
    return float(tc.eta)


def _worker_count(mesh, data_axes) -> int:
    n = 1
    for a in data_axes:
        n *= mesh.shape[a]
    return n


def _bucket_plan(tc: TrainConfig, pshapes):
    """BucketPlan for the flat-buffer sync path (None when disabled)."""
    if not tc.sync.bucketed:
        return None
    return bk.make_plan(
        pshapes, cols=tc.sync.bucket_cols, dense_below=tc.sync.dense_below
    )


def init_train_state(model, mesh, tc: TrainConfig, rng=None, abstract=False):
    """Returns (params, memory, opt_state, count) — concrete or abstract.

    With ``tc.sync.bucketed`` the per-worker error-feedback memory is a
    tuple of (W, rows, cols) bucket buffers instead of a param-shaped
    pytree (see ``repro.core.buckets``).
    """
    data_axes = (("pod",) if "pod" in mesh.axis_names else ()) + ("data",)
    W = _worker_count(mesh, data_axes)
    pshapes = model.param_shapes()
    plan = _bucket_plan(tc, pshapes)

    def make():
        params = model.init(rng if rng is not None else jax.random.PRNGKey(0))
        if plan is not None:
            memory = tuple(
                jnp.zeros((W,) + spec.shape, jnp.float32)
                for spec in plan.buckets
            )
        else:
            memory = jax.tree.map(
                lambda p: jnp.zeros((W,) + p.shape, jnp.float32), params
            )
        if tc.optimizer == "memsgd_momentum":
            opt = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        elif tc.optimizer == "adam_compressed":
            opt = {
                "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            }
        else:
            opt = ()
        return params, memory, opt, jnp.zeros((), jnp.int32)

    if abstract:
        return jax.eval_shape(make)
    return make()


def state_shardings(model, mesh, tc: TrainConfig):
    """NamedSharding pytrees for (params, memory, opt, count)."""
    data_axes = (("pod",) if "pod" in mesh.axis_names else ()) + ("data",)
    pshapes = model.param_shapes()
    pspecs = shd.drop_undivisible(shd.param_specs(pshapes), pshapes, mesh)
    worker = data_axes if len(data_axes) > 1 else data_axes[0]
    plan = _bucket_plan(tc, pshapes)
    if plan is not None:
        mspecs = tuple(P(worker) for _ in plan.buckets)
    else:
        mspecs = jax.tree.map(lambda s: P(worker, *s), pspecs,
                              is_leaf=lambda x: isinstance(x, P))
    if tc.optimizer == "memsgd_momentum":
        ospecs = pspecs
    elif tc.optimizer == "adam_compressed":
        ospecs = {"mu": pspecs, "nu": pspecs}
    else:
        ospecs = ()
    to_sharding = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P)
    )
    return (
        to_sharding(pspecs),
        to_sharding(mspecs),
        to_sharding(ospecs),
        NamedSharding(mesh, P()),
    )


def make_train_step(model, mesh, tc: TrainConfig):
    """Builds the jitted train step:

        (params, memory, opt, count, batch) ->
            (params, memory, opt, count, metrics)

    With ``tc.sync.pod_dynamic`` (runtime pod k — the live-refresh
    path) the step takes a sixth argument ``pod_ks``: an (n_buckets,)
    int32 array of live per-bucket pod ks, replicated. Its SHAPE is
    fixed by the bucket plan, so feeding a new schedule is a pure data
    change — the step never re-traces (``step._cache_size()`` stays 1).
    The static padded ceilings are exposed as ``step.pod_k_max``.

    With ``tc.sync.local_steps = H > 1`` (Qsparse-local-SGD) the state
    gains a bucket-space accumulator between memory and opt:

        (params, memory, acc, opt, count, batch) -> (... same ...)

    and TWO jitted functions come back: the returned ``step`` is the
    sync step (communicates once, resets ``acc``) and ``step.accum``
    is the local step (``acc += eta_t * pack(g_t)``, zero
    communication). Call ``step.accum`` H-1 times, then ``step``.
    With H == 1 the per-step path is returned literally unchanged —
    bitwise identical to previous behavior when quantization is off.
    """
    cfg = model.cfg
    data_axes = (("pod",) if "pod" in mesh.axis_names else ()) + ("data",)
    W = _worker_count(mesh, data_axes)
    pshapes = model.param_shapes()
    pspecs = shd.drop_undivisible(shd.param_specs(pshapes), pshapes, mesh)
    col_axes = shd.sync_col_axes(pshapes)
    plan = _bucket_plan(tc, pshapes)
    eta_fn = _eta_schedule(tc)
    sync_cfg = dataclasses.replace(
        tc.sync.with_pod(axis="pod" if "pod" in mesh.axis_names else None),
        data_axes=("data",),
        strategy="dense" if tc.optimizer == "dense" else tc.sync.strategy,
    )
    worker = data_axes if len(data_axes) > 1 else data_axes[0]
    batch_spec = P(worker)
    dyn = bool(sync_cfg.pod_dynamic)
    if dyn and (plan is None or sync_cfg.strategy != "hierarchical"
                or sync_cfg.pod_axis is None):
        raise ValueError(
            "sync.pod_dynamic (runtime pod k) requires sync.bucketed, "
            "strategy='hierarchical' and a (pod, data) mesh"
        )
    H = max(1, int(sync_cfg.local_steps))
    if H > 1 and plan is None:
        raise ValueError(
            "sync.local_steps > 1 requires sync.bucketed (the local "
            "accumulator lives in bucket space)"
        )
    quant = sync_cfg.quant
    sync_cfg.validate(plan) if plan is not None else sync_cfg.validate()
    pod_k_max = None
    if dyn:
        n_data_mesh = int(mesh.shape["data"])
        pod_k_max = tuple(
            sync_cfg.pod_k_max_for_bucket(b, s.cols, n_data_mesh)
            if s.kind == "sparse" else 1
            for b, s in enumerate(plan.buckets)
        )
    dspec = None
    if tc.emit_deltas:
        if plan is None or tc.optimizer not in ("memsgd", "dense"):
            raise ValueError(
                "emit_deltas requires sync.bucketed and a plain memsgd/"
                "dense optimizer (the parameter delta must equal the "
                "synced update)"
            )
        from repro.launch import delta_stream as ds

        dspec = ds.make_delta_spec(
            plan, sync_cfg, workers=W,
            n_pods=dict(mesh.shape).get("pod", 1),
            value_dtype=tc.delta_value_dtype,
        )

    def local_loss(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def _constrain_params(params):
        # params: full (model-auto) view; memory leaves (1, *shape) local
        return jax.tree.map(
            lambda p, s: jax.lax.with_sharding_constraint(
                p, NamedSharding(mesh, s)),
            params, pspecs, is_leaf=None,
        )

    def compute_grads(params, count, batch):
        tok = None
        moe_tok = None
        if tc.seq_shard_activations:
            tok = shd.set_activation_sharding(
                NamedSharding(mesh, P(None, "model", None))
            )
        if tc.moe_ep_constraints and cfg.moe is not None:
            moe_tok = shd.set_moe_sharding(
                NamedSharding(mesh, P(None, "model", None, None)),
                NamedSharding(mesh, P(None, None, None, None)),
                pre=None,  # token-pinning measured WORSE (§Perf C2)
            )
        try:
            if tc.microbatch > 1:
                M = tc.microbatch

                def split(x):
                    return x.reshape((M, x.shape[0] // M) + x.shape[1:])

                chunks = jax.tree.map(split, batch)
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )

                def acc(carry, mb):
                    g, met = jax.grad(
                        lambda p: local_loss(p, mb), has_aux=True
                    )(params)
                    carry = jax.tree.map(
                        lambda c, gg: c + gg.astype(jnp.float32) / M, carry, g
                    )
                    return carry, met

                from repro.models.layers import layer_scan_unroll

                grads, mets = jax.lax.scan(
                    acc, zeros, chunks,
                    unroll=M if layer_scan_unroll() else 1,
                )
                metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), mets)
            else:
                grads, metrics = jax.grad(
                    lambda p: local_loss(p, batch), has_aux=True
                )(params)
        finally:
            if tok is not None:
                shd.reset_activation_sharding(tok)
            if moe_tok is not None:
                shd.reset_moe_sharding(moe_tok)
        if tc.optimizer in ("memsgd", "memsgd_momentum", "dense"):
            eta = eta_fn(count)
        else:  # adam_compressed: memory accumulates raw gradients
            eta = jnp.asarray(1.0, jnp.float32)
        return grads, eta, metrics

    def _quant_key(count):
        # per-step rounding-noise key; the sync stages fold in bucket /
        # level / axis indices on top (see distributed._fold_axes)
        if quant is None:
            return None
        return jax.random.fold_in(
            jax.random.PRNGKey(tc.quant_seed), count)

    def _mean_metrics(metrics):
        ax = data_axes if len(data_axes) > 1 else data_axes[0]
        return {
            "loss": jax.lax.pmean(metrics["xent"], ax),
            "aux": jax.lax.pmean(metrics["aux"], ax),
        }

    def apply_optimizer(params, opt, count, update):
        if tc.optimizer in ("memsgd", "dense"):
            new_params = jax.tree.map(
                lambda p, u: (p - u.astype(p.dtype)), params, update
            )
            new_opt = opt
        elif tc.optimizer == "memsgd_momentum":
            new_opt = jax.tree.map(
                lambda v, u: tc.momentum * v + u.astype(jnp.float32),
                opt, update,
            )
            new_params = jax.tree.map(
                lambda p, v: (p - v).astype(p.dtype), params, new_opt
            )
        elif tc.optimizer == "adam_compressed":
            t = count + 1
            b1, b2, eps = 0.9, 0.999, 1e-8
            mu = jax.tree.map(
                lambda m_, u: b1 * m_ + (1 - b1) * u.astype(jnp.float32),
                opt["mu"], update,
            )
            nu = jax.tree.map(
                lambda v, u: b2 * v + (1 - b2) * jnp.square(u.astype(jnp.float32)),
                opt["nu"], update,
            )
            bc1 = 1 - b1 ** t.astype(jnp.float32)
            bc2 = 1 - b2 ** t.astype(jnp.float32)
            lr = eta_fn(count)
            new_params = jax.tree.map(
                lambda p, m_, v: (
                    p - lr * (m_ / bc1) / (jnp.sqrt(v / bc2) + eps)
                ).astype(p.dtype),
                params, mu, nu,
            )
            new_opt = {"mu": mu, "nu": nu}
        else:
            raise ValueError(tc.optimizer)
        return new_params, new_opt

    def step_body(params, memory, opt, count, batch, pod_ks=None):
        params = _constrain_params(params)
        mem_local = jax.tree.map(lambda m_: m_[0], memory)
        grads, eta, metrics = compute_grads(params, count, batch)
        qkey = _quant_key(count)
        up_bufs = None
        if plan is not None and dspec is not None:
            update, new_mem, _, up_bufs = bucketed_sync_gradients(
                sync_cfg, plan, mem_local, grads, eta, return_bufs=True,
                pod_ks=pod_ks, quant_key=qkey,
            )
        elif plan is not None:
            update, new_mem, _ = bucketed_sync_gradients(
                sync_cfg, plan, mem_local, grads, eta, pod_ks=pod_ks,
                quant_key=qkey,
            )
        else:
            update, new_mem, _ = sparse_sync_gradients(
                sync_cfg, mem_local, grads, eta, col_axes,
                specs=pspecs, mesh=mesh,
            )
        new_params, new_opt = apply_optimizer(params, opt, count, update)
        new_memory = jax.tree.map(lambda m_: m_[None], new_mem)
        ret = (new_params, new_memory, new_opt, count + 1,
               _mean_metrics(metrics))
        if dspec is not None:
            # the gathered update is identical on every worker, so the
            # encoded wire buffers are replicated outputs (out_spec P())
            from repro.launch import delta_stream as ds

            ret += (tuple(ds.encode_delta_bufs(dspec, up_bufs)),)
        return ret

    def accum_body(params, memory, acc, opt, count, batch):
        # local step h < H: fold eta_t * g_t into the bucket-space
        # accumulator; no communication, params/memory/opt untouched
        params = _constrain_params(params)
        grads, eta, metrics = compute_grads(params, count, batch)
        acc_local = tuple(a[0] for a in acc)
        new_acc = tuple(
            a[None]
            for a in bk.accumulate_local(plan, acc_local, grads, eta)
        )
        return (params, memory, new_acc, opt, count + 1,
                _mean_metrics(metrics))

    def sync_body(params, memory, acc, opt, count, batch, pod_ks=None):
        # local step h == H: finish the accumulator, then one sync of
        # u = m + sum_h eta_h*g_h through top-k (-> QSGD quantize ->)
        # the packed wire; memory absorbs BOTH the sparsification
        # residual and the quantization error; accumulator resets
        params = _constrain_params(params)
        mem_local = jax.tree.map(lambda m_: m_[0], memory)
        grads, eta, metrics = compute_grads(params, count, batch)
        acc_local = tuple(a[0] for a in acc)
        u_bufs = bk.accumulate_local(plan, acc_local, grads, eta)
        qkey = _quant_key(count)
        one = jnp.asarray(1.0, jnp.float32)
        up_bufs = None
        if dspec is not None:
            update, new_mem, _, up_bufs = bucketed_sync_gradients(
                sync_cfg, plan, mem_local, grads, one, return_bufs=True,
                pod_ks=pod_ks, grad_bufs=u_bufs, quant_key=qkey,
            )
        else:
            update, new_mem, _ = bucketed_sync_gradients(
                sync_cfg, plan, mem_local, grads, one, pod_ks=pod_ks,
                grad_bufs=u_bufs, quant_key=qkey,
            )
        new_params, new_opt = apply_optimizer(params, opt, count, update)
        new_memory = jax.tree.map(lambda m_: m_[None], new_mem)
        zero_acc = tuple(jnp.zeros_like(a) for a in acc)
        ret = (new_params, new_memory, zero_acc, new_opt, count + 1,
               _mean_metrics(metrics))
        if dspec is not None:
            from repro.launch import delta_stream as ds

            ret += (tuple(ds.encode_delta_bufs(dspec, up_bufs)),)
        return ret

    pspec_P0 = jax.tree.map(lambda s: P(), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    if plan is not None:
        mem_manual = tuple(P(worker) for _ in plan.buckets)
    else:
        mem_manual = jax.tree.map(lambda s: P(worker), pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
    opt_P0 = jax.tree.map(lambda s: P(), shd.param_specs(pshapes),
                          is_leaf=lambda x: isinstance(x, P))
    if tc.optimizer == "memsgd_momentum":
        opt_in = opt_P0
    elif tc.optimizer == "adam_compressed":
        opt_in = {"mu": opt_P0, "nu": opt_P0}
    else:
        opt_in = ()

    model_specs = model.input_specs  # unused; batch spec built per leaf

    def batch_specs(batch_tree):
        return jax.tree.map(lambda _: batch_spec, batch_tree)

    out_specs = (pspec_P0, mem_manual, opt_in, P(),
                 {"loss": P(), "aux": P()})
    if dspec is not None:
        out_specs += (tuple(P() for _ in dspec.wires),)

    if H == 1:
        def step(params, memory, opt, count, batch, *pod_ks):
            # *pod_ks: exactly one (n_buckets,) int32 array on the
            # dynamic path, nothing otherwise — one closure serves both
            # so the specs can never diverge between them
            sm = compat.shard_map(
                step_body,
                mesh=mesh,
                in_specs=(pspec_P0, mem_manual, opt_in, P(),
                          batch_specs(batch)) + ((P(),) if dyn else ()),
                out_specs=out_specs,
                axis_names=set(data_axes),
                check_vma=False,
            )
            return sm(params, memory, opt, count, batch, *pod_ks)

        step = jax.jit(step, donate_argnums=(0, 1, 2))
    else:
        # Qsparse-local-SGD: two jitted steps over shared closures. The
        # accumulator rides next to the memory — same (W, rows, cols)
        # bucket layout, same per-worker sharding — so the sync step's
        # u = m + acc is plain bucket arithmetic.
        acc_manual = tuple(P(worker) for _ in plan.buckets)
        local_out = (pspec_P0, mem_manual, acc_manual, opt_in, P(),
                     {"loss": P(), "aux": P()})
        sync_out = local_out
        if dspec is not None:
            sync_out += (tuple(P() for _ in dspec.wires),)

        def sync_step(params, memory, acc, opt, count, batch, *pod_ks):
            sm = compat.shard_map(
                sync_body,
                mesh=mesh,
                in_specs=(pspec_P0, mem_manual, acc_manual, opt_in, P(),
                          batch_specs(batch)) + ((P(),) if dyn else ()),
                out_specs=sync_out,
                axis_names=set(data_axes),
                check_vma=False,
            )
            return sm(params, memory, acc, opt, count, batch, *pod_ks)

        def accum_step(params, memory, acc, opt, count, batch):
            sm = compat.shard_map(
                accum_body,
                mesh=mesh,
                in_specs=(pspec_P0, mem_manual, acc_manual, opt_in, P(),
                          batch_specs(batch)),
                out_specs=local_out,
                axis_names=set(data_axes),
                check_vma=False,
            )
            return sm(params, memory, acc, opt, count, batch)

        step = jax.jit(sync_step, donate_argnums=(0, 1, 2, 3))
        step.accum = jax.jit(accum_step, donate_argnums=(0, 1, 2, 3))
    step.local_steps = H
    if dspec is not None:
        step.delta_spec = dspec  # static wire layout for replica decoders
    if pod_k_max is not None:
        step.pod_k_max = pod_k_max  # static padded pod-k ceilings
    return step


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


class PodRatioCalibrator:
    """Host-side mass-capture calibration for the two-level pod sync.

    ONE jitted grad fn serves both the first-batch calibration (zero
    memory: u = eta*g) and every live refresh (u = m + eta*g on the
    current batch and the live per-worker memory buffers), so a mid-run
    refresh compiles nothing new — everything after step 1 is pure data
    flow. When the global batch splits evenly into ``n_data`` shards the
    per-shard buffers let ``autotune_pod_ratios`` simulate the realized
    pod mean (overlapping shard selections shrink k); otherwise the
    single global buffer's tail curve is the conservative proxy. For
    per-worker memory the first ``n_data`` workers (pod 0) stand in on
    the shard path, the worker mean on the global path.
    """

    def __init__(self, model, plan, n_data: int):
        self.plan = plan
        self.n_data = n_data
        self._gfn = jax.jit(
            jax.grad(lambda p, b: model.loss(p, b), has_aux=True)
        )

    def u_bufs(self, params, batch, eta, memory=None):
        """Concrete per-bucket u = m + eta*g buffers for
        ``autotune_pod_ratios`` — (n_data, rows, cols) per-shard stacks
        when the batch divides, (rows, cols) otherwise."""
        plan, n_data = self.plan, self.n_data
        B = jax.tree.leaves(batch)[0].shape[0]

        def u_of(bt):
            g, _ = self._gfn(params, bt)
            return bk.pack(
                plan,
                jax.tree.map(lambda x: eta * x.astype(jnp.float32), g),
                dtype=jnp.float32,
            )

        if B % n_data == 0 and n_data > 1:
            per_shard = [
                u_of(jax.tree.map(
                    lambda x: x[i * (B // n_data):(i + 1) * (B // n_data)],
                    batch))
                for i in range(n_data)
            ]
            return [
                jnp.stack([s[b] for s in per_shard])
                + (memory[b][:n_data] if memory is not None else 0.0)
                for b in range(len(plan.buckets))
            ]
        u = u_of(batch)
        if memory is not None:
            u = [ub + jnp.mean(memory[b], axis=0)
                 for b, ub in enumerate(u)]
        return u


def _calibrate_pod_ratios(sync_cfg, plan, u_bufs, n_data,
                          mass_target=None, k_caps=None, byte_budget=None):
    """One calibration entry for both pod-k sizing modes: a byte budget
    (argument override, else ``SyncConfig.byte_budget``) water-fills the
    global cross-pod allowance across buckets via
    ``core.budget.BudgetController``; otherwise the historical
    mass-target autotune sizes each bucket independently. Returns
    per-bucket pod ratios."""
    budget = (byte_budget if byte_budget is not None
              else sync_cfg.byte_budget)
    if budget is not None:
        from repro.core.budget import BudgetController

        ctl = BudgetController(sync_cfg, plan, n_data, k_caps=k_caps)
        ks = ctl.allocate(u_bufs, byte_budget=budget)
        return ctl.ratios_of(ks)
    from repro.core.distributed import autotune_pod_ratios

    return autotune_pod_ratios(sync_cfg, plan, u_bufs, n_data=n_data,
                               mass_target=mass_target, k_caps=k_caps)


def _maybe_autotune_pod_ratios(model, mesh, tc: TrainConfig, plan, params,
                               batches, calib=None):
    """Calibration pass for the two-level pod sync: when training
    hierarchical + bucketed on a pod mesh with no explicit
    ``SyncConfig.pod_ratios``, peek the first batch, measure each
    bucket's realized gradient mass capture (u = eta*g at zero memory),
    and bake per-bucket pod ratios into the static sync config before
    the jitted step is built (wire layouts need static k). Returns
    ``(tc, batches)`` with the peeked batch pushed back. Pass ``calib``
    (a ``PodRatioCalibrator``) to share its jitted grad fn with the
    live refresh loop."""
    import itertools

    if not (tc.pod_autotune and plan is not None
            and tc.sync.strategy == "hierarchical"
            and "pod" in mesh.axis_names
            and tc.sync.pod_ratios is None):
        return tc, batches
    first = next(batches, None)
    if first is None:
        return tc, batches
    n_data = int(mesh.shape["data"])
    calib = calib or PodRatioCalibrator(model, plan, n_data)
    u_bufs = calib.u_bufs(params, first, tc.eta)
    ratios = _calibrate_pod_ratios(tc.sync, plan, u_bufs, n_data)
    tc = dataclasses.replace(tc, sync=tc.sync.with_pod(ratios=ratios))
    from repro.core.distributed import bucketed_message_bytes

    lv = bucketed_message_bytes(
        tc.sync.with_pod(axis="pod"), plan, by_level=True,
        n_data=n_data,
    )
    print(
        "pod autotune: ratios="
        + ",".join(f"{r:.4g}" for r in ratios)
        + f"  intra-pod {lv['intra']}B cross-pod {lv['cross']}B /step/worker"
    )
    return tc, itertools.chain([first], batches)


def _cache_sizes(step, H: int):
    """Combined jit-cache population of the step fn(s): the sync step
    plus (at H > 1) its ``step.accum`` sibling. None when the runtime
    doesn't expose ``_cache_size``."""
    sizes = [step] + ([step.accum] if H > 1 else [])
    total = 0
    for f in sizes:
        c = getattr(f, "_cache_size", None)
        if not callable(c):
            return None
        total += int(c())
    return total


def _telemetry_bytes(tc: TrainConfig, plan, mesh, pod_ks=None):
    """Per-step wire accounting for the telemetry sink: the exact
    ``amortized_bytes_per_step`` dict (1/H under local steps), split
    ``{"intra", "cross", "total"}`` on a (pod, data) mesh. Best-effort
    — returns None for non-bucketed syncs or config combinations with
    no defined accounting, because observe-only telemetry must never
    turn an accounting edge case into a training failure."""
    if plan is None:
        return None
    from repro.core.distributed import amortized_bytes_per_step

    try:
        if "pod" in mesh.axis_names:
            acct = amortized_bytes_per_step(
                tc.sync.with_pod(axis="pod"), plan, by_level=True,
                n_data=int(mesh.shape["data"]), pod_ks=pod_ks,
            )
        else:
            acct = {"total": amortized_bytes_per_step(tc.sync, plan)}
    except (ValueError, TypeError):
        return None
    return acct


def train(model, mesh, tc: TrainConfig, batches, n_steps: int,
          checkpointer=None, ckpt_every: int = 0, log_every: int = 10,
          rng=None, delta_sink=None, ckpt_wire: bool = False,
          ckpt_memory_ratio: float = 0.05, refresh_cb=None,
          pod_k_schedule=None, diagnostics=None, telemetry=None):
    """End-to-end training loop. ``batches``: iterator of device-ready
    global batches (see repro.data.pipeline.ShardedBatcher).

    With ``tc.emit_deltas``, ``delta_sink(step_index, wire_msgs)`` is
    called with the packed per-bucket delta buffers each step (decode
    them against ``make_train_step(...).delta_spec`` — see
    ``repro.launch.delta_stream``).

    With ``ckpt_wire`` (requires ``tc.sync.bucketed``), checkpoints go
    through the packed wire codec (``Checkpointer.save_wire``): params
    diff-encoded against the boot state, the error-feedback memory
    top-k'-compressed at ``ckpt_memory_ratio`` — instead of dense f32
    dumps.

    With ``tc.pod_refresh`` enabled, the per-bucket pod ks re-calibrate
    every ``pod_refresh.every`` steps from the live memory+gradient
    buffers, riding the k-padded dynamic wire into the SAME jitted step
    (no recompile; ks clamp to the step's static ``pod_k_max``).
    ``refresh_cb(step_index, ks_tuple)`` observes each applied refresh.
    ``pod_k_schedule`` — a sequence of ``(step_index, ks_tuple)`` —
    REPLAYS a recorded schedule instead of re-calibrating (the bitwise
    reproducibility path: a fresh run fed the same schedule reproduces
    the refreshed run exactly). Pass a dict as ``diagnostics`` to
    receive ``step_cache_size`` (the jit cache population after the
    run — 1 means zero recompiles past the first trace), the applied
    ``pod_refresh_schedule`` and the ``initial_pod_ks``.

    ``telemetry`` — a ``repro.utils.telemetry.Telemetry`` sink fed
    every step (loss + rolling medians, spike/non-finite detection,
    per-step bytes, pod-k refreshes, jit-cache sizes); when omitted an
    internal sink with the default config runs, so a NaN/inf loss
    raises ``NonFiniteLossError`` instead of training to the step
    budget on garbage (pass a sink configured with
    ``stop_on_nonfinite=False`` to restore observe-only behaviour).
    The raised error carries the partial ``history`` accumulated before
    the stop, so a crash at step N does not discard N-1 steps of
    signal. A caller-provided sink stays OPEN after train() returns
    (reuse it across runs, close it yourself / via its context
    manager); only the internal default sink is closed here.
    Telemetry is observe-only: enabling it never changes the applied
    params/memory — bitwise (DESIGN.md invariant 13), and never blocks
    async dispatch — each step's device loss is drained only after the
    NEXT step is dispatched, so detection/printing lag one step while
    the host keeps running ahead. The legacy ``diagnostics`` dict is
    filled from the sink, keys unchanged.
    """
    plan = _bucket_plan(tc, model.param_shapes())
    if ckpt_wire and plan is None:
        raise ValueError("ckpt_wire requires sync.bucketed (a BucketPlan)")
    refresh = tc.pod_refresh if (
        tc.pod_refresh is not None and tc.pod_refresh.enabled) else None
    if refresh is not None or pod_k_schedule is not None:
        kw = {"dynamic": True}
        if refresh is not None and refresh.k_max_ratio is not None:
            kw["k_max_ratio"] = refresh.k_max_ratio
        tc = dataclasses.replace(tc, sync=tc.sync.with_pod(**kw))
    dyn = tc.sync.pod_dynamic
    if dyn and (plan is None or tc.sync.strategy != "hierarchical"
                or "pod" not in mesh.axis_names):
        raise ValueError(
            "pod_refresh / pod_k_schedule / sync.pod_dynamic require "
            "sync.bucketed, strategy='hierarchical' and a (pod, data) mesh"
        )
    params, memory, opt, count = init_train_state(model, mesh, tc, rng=rng)
    batches = iter(batches)
    calib = None
    if dyn:
        calib = PodRatioCalibrator(model, plan, int(mesh.shape["data"]))
    tc, batches = _maybe_autotune_pod_ratios(
        model, mesh, tc, plan, params, batches, calib=calib
    )
    base_params = None
    if ckpt_wire and checkpointer is not None:
        from repro.launch.serve import replica_copy

        base_params = replica_copy(params)  # survives the donated step
    pshard, mshard, oshard, cshard = state_shardings(model, mesh, tc)
    params = jax.device_put(params, pshard)
    memory = jax.device_put(memory, mshard)
    if oshard != ():
        opt = jax.device_put(opt, oshard)
    step = make_train_step(model, mesh, tc)
    H = int(getattr(step, "local_steps", 1))
    acc = None
    if H > 1:
        # bucket-space local accumulator: same (W, rows, cols) layout
        # and per-worker sharding as the error-feedback memory
        data_axes = (("pod",) if "pod" in mesh.axis_names else ()) + ("data",)
        W = _worker_count(mesh, data_axes)
        acc = jax.device_put(
            tuple(jnp.zeros((W,) + spec.shape, jnp.float32)
                  for spec in plan.buckets),
            mshard,
        )
    pod_ks = live_ks = k_caps = None
    sched = dict(pod_k_schedule) if pod_k_schedule is not None else None
    if dyn:
        from repro.core.distributed import bucketed_message_bytes

        n_data = int(mesh.shape["data"])
        k_caps = step.pod_k_max
        live_ks = tuple(
            tc.sync.pod_k_for_bucket(b, s.cols) if s.kind == "sparse" else 1
            for b, s in enumerate(plan.buckets)
        )
        pod_ks = jnp.asarray(live_ks, jnp.int32)
    history = []
    initial_pod_ks = live_ks
    tel_owned = telemetry is None
    tel = telemetry if telemetry is not None else Telemetry()
    tel.initial_pod_ks = initial_pod_ks
    # bytes_live is the driver's own record of the accounting currently
    # in effect (the sink's copy gets rewound to per-step snapshots by
    # the drains below, so it cannot serve as the source of truth)
    bytes_live = _telemetry_bytes(tc, plan, mesh, pod_ks=live_ks)
    tel.set_bytes_per_step(bytes_live)
    from repro.data.pipeline import take

    # one-step-late loss readback: float(loss) blocks on the device
    # value, so the driver holds each step's loss as a device array and
    # drains it only after the NEXT step has been dispatched — the host
    # keeps running ahead of the device (the async-dispatch overlap the
    # double-buffered bucket pipeline depends on) at the cost of
    # detection/printing lagging one step. The bytes accounting in
    # effect at dispatch rides along so a pod refresh between dispatch
    # and drain still attributes the step's bytes correctly.
    pending = None

    def _drain(rec):
        idx, dev_loss, cache_rec, log_rec, bytes_rec = rec
        loss = float(dev_loss)
        tel.set_bytes_per_step(bytes_rec)
        try:
            tel.step(idx, loss, cache_size=cache_rec, log=log_rec)
        except NonFiniteLossError as e:
            # a crash at step N must not discard N-1 steps of signal
            # (the garbage step itself stays out of the history)
            e.history = list(history)
            if tel_owned:
                tel.close()
            raise
        if log_rec:
            history.append((idx, loss))

    # take() consumes EXACTLY n_steps from the (typically shared,
    # typically infinite) stream — a bare `enumerate + break` would pull
    # and discard one extra batch per run
    for i, batch in enumerate(take(batches, n_steps)):
        # Qsparse-local-SGD cadence: steps i with (i+1) % H != 0 only
        # accumulate locally; step i with (i+1) % H == 0 closes sync
        # round j = i // H (H == 1: every step syncs, j == i)
        j = i // H
        is_sync = (i + 1) % H == 0
        if dyn and sched is not None and i in sched:
            # clamp to the step's static padded ceilings HOST-SIDE, so
            # the recorded/applied schedule and the effective-byte
            # accounting always describe the ks the wire realizes (the
            # jitted step clips too, but silently)
            live_ks = tuple(
                max(1, min(int(k), int(c)))
                for k, c in zip(sched[i], k_caps)
            )
            pod_ks = jnp.asarray(live_ks, jnp.int32)
            tel.pod_refresh(i, live_ks)
            bytes_live = _telemetry_bytes(tc, plan, mesh, pod_ks=live_ks)
        elif (dyn and sched is None and refresh is not None and is_sync
              and j > 0 and j % refresh.every == 0):
            # live re-calibration (an explicit pod_k_schedule REPLACES
            # it entirely — a replay must stay deterministic even past
            # the recorded entries): read-only on params/memory (fully
            # materialized host-side before the donating step call),
            # at the SAME eta the step applies — the scheduled eta_t
            # (or adam's fixed 1.0); with eta decay the base eta would
            # overweight the gradient in u = m + eta*g and mis-size k.
            # Computed host-side from the loop index (== count here):
            # float(count) would sync the dispatch queue every refresh
            eta_now = (
                _eta_at(tc, i)
                if tc.optimizer in ("memsgd", "memsgd_momentum", "dense")
                else 1.0
            )
            # at H > 1 the sync consumes u = m + acc (+ eta*g): fold the
            # live local accumulator into the calibration view of memory
            mem_live = (memory if acc is None else
                        tuple(m + a for m, a in zip(memory, acc)))
            u_bufs = calib.u_bufs(params, batch, eta_now, memory=mem_live)
            ratios = _calibrate_pod_ratios(
                tc.sync, plan, u_bufs, n_data,
                mass_target=refresh.mass_target, k_caps=k_caps,
                byte_budget=refresh.byte_budget,
            )
            live_ks = tuple(
                int(round(r * s.cols)) if s.kind == "sparse" else 1
                for r, s in zip(ratios, plan.buckets)
            )
            pod_ks = jnp.asarray(live_ks, jnp.int32)
            lv = bucketed_message_bytes(
                tc.sync.with_pod(axis="pod"), plan,
                by_level=True, n_data=n_data, pod_ks=live_ks,
            )
            print(
                f"pod refresh @ step {i}: ks="
                + ",".join(str(k) for k in live_ks)
                + f"  effective cross-pod {lv['cross']}B /step/worker"
            )
            tel.pod_refresh(i, live_ks, cross_bytes=lv["cross"])
            bytes_live = _telemetry_bytes(tc, plan, mesh, pod_ks=live_ks)
            if refresh_cb is not None:
                refresh_cb(i, live_ks)
        if H > 1:
            if is_sync:
                out = (step(params, memory, acc, opt, count, batch, pod_ks)
                       if dyn else
                       step(params, memory, acc, opt, count, batch))
            else:
                out = step.accum(params, memory, acc, opt, count, batch)
        else:
            out = (step(params, memory, opt, count, batch, pod_ks)
                   if dyn else step(params, memory, opt, count, batch))
        cache = _cache_sizes(step, H)
        if diagnostics is not None:
            diagnostics.setdefault("step_cache_sizes", []).append(cache)
        if H > 1:
            if tc.emit_deltas and is_sync:
                params, memory, acc, opt, count, metrics, delta = out
                if delta_sink is not None:
                    delta_sink(i, delta)
            else:
                params, memory, acc, opt, count, metrics = out
        elif tc.emit_deltas:
            params, memory, opt, count, metrics, delta = out
            if delta_sink is not None:
                delta_sink(i, delta)
        else:
            params, memory, opt, count, metrics = out
        # the sink sees EVERY step's loss (spike/non-finite detection
        # can't run on a log_every subsample); it owns the per-step
        # print, so a NaN/inf loss raises NonFiniteLossError from the
        # drain instead of printing garbage to the step budget. Step i
        # is already dispatched when step i-1's loss is drained, so the
        # blocking float() never stalls the dispatch queue.
        do_log = bool(log_every and (i % log_every == 0 or i == n_steps - 1))
        if pending is not None:
            _drain(pending)
        pending = (i, metrics["loss"], cache, do_log, bytes_live)
        if tel.should_stop:
            print(f"telemetry early stop @ step {i}: {tel.stop_reason}")
            break
        if checkpointer is not None and ckpt_every and (i + 1) % ckpt_every == 0:
            if ckpt_wire:
                checkpointer.save_wire(
                    i + 1, params, memory, plan,
                    base_params=base_params,
                    memory_ratio=ckpt_memory_ratio,
                )
            else:
                checkpointer.save(i + 1, {"params": params})
    if pending is not None:
        _drain(pending)  # the last dispatched step's loss
    if tel_owned:
        # caller-provided sinks stay open for reuse (they own their
        # lifetime via the context-manager protocol); only the
        # internally-created default sink is closed here
        tel.close()
    if diagnostics is not None:
        # legacy ad-hoc dict, now sourced from the telemetry sink (same
        # keys and values as before the sink absorbed the bookkeeping)
        d = tel.diagnostics(H)
        for key in ("step_cache_size", "pod_refresh_schedule",
                    "initial_pod_ks", "steady_state_recompiles"):
            diagnostics[key] = d[key]
    return params, memory, opt, count, history


def _sync_from_args(ap, args) -> SyncConfig:
    """CLI arg assembly for the sync config, routed through the grouped
    SyncConfig API. With ``--preset`` the named ``SyncConfig.preset``
    bundle is the base and only flags the user set EXPLICITLY (value
    differs from the argparse default) override it; without a preset
    every flag lands in the grouped constructors directly."""
    from repro.core.distributed import (
        PodConfig,
        TransportConfig,
        WireConfig,
    )

    bucketed = (args.bucketed or args.emit_deltas or args.ckpt_wire
                or args.pod_refresh_every > 0 or args.local_steps > 1
                or args.wire_quant is not None)
    overlap = None if args.overlap == "auto" else args.overlap == "on"
    if args.preset is not None:
        # flat override keys are the blessed warning-free preset inputs
        overrides = {}
        for arg, key in (("ratio", "ratio"), ("strategy", "strategy"),
                         ("local_steps", "local_steps"), ("wire", "wire"),
                         ("wire_quant", "quant"),
                         ("pod_ratio", "pod_ratio"),
                         ("pod_mass_target", "pod_mass_target"),
                         ("pod_k_max_ratio", "pod_k_max_ratio"),
                         ("byte_budget", "byte_budget"),
                         ("repack", "repack")):
            if getattr(args, arg) != ap.get_default(arg):
                overrides[key] = getattr(args, arg)
        if args.overlap != ap.get_default("overlap"):
            overrides["overlap"] = overlap
        if bucketed:
            overrides["bucketed"] = True
        return SyncConfig.preset(args.preset, **overrides)
    return SyncConfig(
        ratio=args.ratio,
        strategy=args.strategy,
        local_steps=args.local_steps,
        bucketed=bucketed,
        wire=WireConfig(wire=args.wire, quant=args.wire_quant),
        pod=PodConfig(ratio=args.pod_ratio,
                      mass_target=args.pod_mass_target,
                      k_max_ratio=args.pod_k_max_ratio),
        transport=TransportConfig(repack=args.repack,
                                  byte_budget=args.byte_budget,
                                  overlap=overlap),
    )


def main():
    """CLI: train an assigned architecture's SMOKE variant end-to-end.

    Full-size configs are exercised via ``repro.launch.dryrun`` (this
    container is CPU-only); this driver proves the full stack on the
    reduced variants:  python -m repro.launch.train --arch qwen3-4b
    """
    import argparse

    from repro.checkpoint import Checkpointer
    from repro.configs import ARCH_IDS, MESHES, get_smoke_config
    from repro.data import token_batches
    from repro.data.pipeline import ShardedBatcher
    from repro.models import build_model

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--optimizer", default="memsgd")
    ap.add_argument("--eta", type=float, default=0.5)
    ap.add_argument("--ratio", type=float, default=0.01)
    ap.add_argument("--strategy", default="sparse_allgather")
    ap.add_argument("--mesh", default=None, choices=sorted(MESHES),
                    help="named MeshConfig (repro.configs.MESHES); the "
                         "smoke_2pod layout exercises the two-level pod "
                         "sync on 8 forced host devices")
    ap.add_argument("--pods", type=int, default=1,
                    help="ad-hoc (pod, data) mesh: split the available "
                         "devices into this many pods (hierarchical "
                         "strategy re-compresses at the pod boundary)")
    ap.add_argument("--pod-ratio", type=float, default=None,
                    help="global pod re-compression ratio (hierarchical); "
                         "autotuned per bucket by default")
    ap.add_argument("--pod-mass-target", type=float, default=0.9,
                    help="mass-capture target for the per-bucket pod-"
                         "ratio autotune")
    ap.add_argument("--no-pod-autotune", action="store_true",
                    help="disable the per-bucket pod-ratio calibration")
    ap.add_argument("--pod-refresh-every", type=int, default=0,
                    help="re-calibrate the per-bucket pod ks every N "
                         "steps from the live memory+gradient buffers "
                         "and feed them into the RUNNING jitted step "
                         "(k-padded dynamic wire, zero recompiles; "
                         "requires --strategy hierarchical on a pod "
                         "mesh, implies --bucketed; 0 = off)")
    ap.add_argument("--pod-k-max-ratio", type=float, default=None,
                    help="cap the static padded pod k at this fraction "
                         "of bucket cols (default: the n_data*k_row "
                         "support bound) — smaller caps shrink the "
                         "padded gather but bound upward refreshes")
    ap.add_argument("--byte-budget", type=int, default=None,
                    help="global cross-pod byte budget per step per "
                         "worker: the per-bucket pod ks are sized by "
                         "water-filling this allowance across buckets "
                         "by marginal mass-per-byte "
                         "(repro.core.budget.BudgetController) instead "
                         "of the per-bucket mass-capture target; "
                         "refreshes re-spend it on the live buffers")
    ap.add_argument("--repack", action="store_true",
                    help="header-aware repack transport: grow each "
                         "bucket's pipeline an explicit repack stage at "
                         "the pod boundary so cross-pod bytes track the "
                         "live pod k instead of the padded k_max "
                         "(bitwise-identical results; see DESIGN.md "
                         "invariant 11)")
    ap.add_argument("--bucketed", action="store_true",
                    help="flat-buffer bucketed sync (repro.core.buckets)")
    ap.add_argument("--preset", default=None,
                    choices=("dense", "topk", "qsparse_local",
                             "pod_budgeted"),
                    help="start from a named SyncConfig.preset; other "
                         "sync flags given EXPLICITLY on the command "
                         "line override the preset's fields")
    ap.add_argument("--local-steps", type=int, default=1,
                    help="Qsparse-local-SGD: take H uncommunicated local "
                         "steps (accumulating eta_t*g_t in bucket space "
                         "next to the error memory), then sync ONCE "
                         "through top-k (+ optional --wire-quant) — "
                         "cross-worker bytes/step shrink ~1/H (implies "
                         "--bucketed; 1 = classic per-step sync)")
    ap.add_argument("--wire-quant", type=int, default=None,
                    help="QSGD stochastic-rounding quantization level s "
                         "for the packed sparse wire: values ship as one "
                         "f32 row norm + (1+ceil(log2(s+1)))-bit codes; "
                         "memory absorbs the quantization error (implies "
                         "--bucketed; requires --wire packed for byte "
                         "savings)")
    ap.add_argument("--quant-seed", type=int, default=0,
                    help="base PRNG seed for the QSGD rounding noise "
                         "(step count folded in per step)")
    ap.add_argument("--wire", default="unpacked",
                    choices=("unpacked", "packed"),
                    help="sync wire format (repro.core.encoding)")
    ap.add_argument("--overlap", default="auto",
                    choices=("auto", "on", "off"),
                    help="software-pipelined bucket schedule "
                         "(repro.core.pipeline): 'on' double-buffers so "
                         "bucket b's all-gather+decode overlaps bucket "
                         "b+1's select+encode, 'off' pins the strict "
                         "sequential schedule, 'auto' keeps the legacy "
                         "emission. Bitwise-identical params/memory in "
                         "all modes")
    ap.add_argument("--platform", default=None,
                    choices=("cpu", "gpu", "cuda", "tpu"),
                    help="pin the JAX platform and set its XLA perf "
                         "flags (GPU: async collectives + latency-hiding "
                         "scheduler — what makes --overlap on hide the "
                         "gathers; repro.utils.platform.setup_platform)")
    ap.add_argument("--emit-deltas", action="store_true",
                    help="stream packed parameter deltas for serving "
                         "replicas (implies --bucketed)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-wire", action="store_true",
                    help="checkpoint params+memory through the packed "
                         "wire codec instead of dense f32 dumps "
                         "(implies --bucketed)")
    ap.add_argument("--ckpt-memory-ratio", type=float, default=0.05,
                    help="per-row top-k ratio for the lossy memory "
                         "section of wire checkpoints")
    args = ap.parse_args()

    if args.platform is not None:
        # before any backend use (device_count below initializes the
        # client, which reads XLA_FLAGS once)
        from repro.utils.platform import setup_platform

        setup_platform(args.platform)

    if args.mesh:
        from repro.launch.mesh import mesh_from_config

        mesh = mesh_from_config(MESHES[args.mesh])
    elif args.pods > 1:
        n = jax.device_count()
        if n % args.pods:
            ap.error(f"--pods {args.pods} does not divide {n} devices")
        mesh = compat.make_mesh(
            (args.pods, n // args.pods, 1), ("pod", "data", "model")
        )
    else:
        mesh = compat.make_mesh((jax.device_count(), 1), ("data", "model"))
    batch_axes = (
        ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    )
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    refresh = None
    if args.pod_refresh_every > 0:
        from repro.configs import PodRefreshConfig

        refresh = PodRefreshConfig(every=args.pod_refresh_every,
                                   k_max_ratio=args.pod_k_max_ratio)
    sync = _sync_from_args(ap, args)
    tc = TrainConfig(optimizer=args.optimizer, eta=args.eta,
                     emit_deltas=args.emit_deltas,
                     pod_autotune=not args.no_pod_autotune,
                     pod_refresh=refresh,
                     quant_seed=args.quant_seed,
                     sync=sync)
    batches = ShardedBatcher(
        mesh, token_batches(cfg.vocab_size, args.batch, args.seq, seed=0),
        batch_axes=batch_axes,
    )
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    streamed = [0]
    sink = None
    if args.emit_deltas:
        sink = lambda i, msgs: streamed.__setitem__(
            0, streamed[0] + sum(m.nbytes for m in msgs))
    train(model, mesh, tc, batches, n_steps=args.steps, checkpointer=ck,
          ckpt_every=max(1, args.steps // 2), delta_sink=sink,
          ckpt_wire=args.ckpt_wire,
          ckpt_memory_ratio=args.ckpt_memory_ratio)
    if args.ckpt_wire and ck is not None:
        import json as _json

        with open(ck._wire_path(ck.latest_wire_step()) + ".json") as f:
            w = _json.load(f)["wire"]
        print(f"wire checkpoint: {w['nbytes']/1e6:.2f} MB "
              f"(dense f32 dump: {w['dense_nbytes']/1e6:.2f} MB, "
              f"x{w['ratio_vs_dense']:.1f} smaller)")
    if args.emit_deltas:
        dense = sum(
            p.size * 4 for p in jax.tree.leaves(model.param_shapes())
        ) * args.steps
        print(f"delta stream: {streamed[0]/1e6:.2f} MB over {args.steps} "
              f"steps (dense refresh would be {dense/1e6:.2f} MB)")


if __name__ == "__main__":
    main()
