"""Pub/sub fan-out of trainer delta streams to N serving replicas.

``repro.launch.delta_stream`` turned the trainer's per-step parameter
update into ONE packed wire message set; this module is where that
message pays for itself N times. A :class:`FanoutHub` sits between the
trainer's ``delta_sink`` and any number of replicas with heterogeneous
consumption patterns:

* **replay log** — the hub keeps the last ``log_bound`` steps' wire
  messages (host copies) keyed by step. A replica that missed steps
  catches up by replaying the EXACT bytes it missed, in order; on the
  f32 tier this reproduces the trainer's parameters bitwise, because
  ``apply_delta`` performs the identical subtraction per step.
* **per-replica cursors** — each replica knows only its cursor (next
  step to apply); the hub serves any cursor still inside the log. One
  encoded message serves every subscriber: publish cost is independent
  of N, unlike a dense broadcast whose bytes scale as ``N * 4d``.
* **bf16 tier** — bandwidth-starved replicas subscribe with
  ``tier="bfloat16"``: the hub transcodes each f32 message ONCE
  (``encoding.transcode``: value section re-encoded, index section
  untouched) and serves the half-size buffer to every bf16 subscriber.
  Tracking is no longer bitwise; the drift after T steps is bounded by
  ``sum_t || u_t - bf16(u_t) ||_inf`` (each step contributes at most
  its own rounding error, ~2^-9 relative), which the hub exposes via
  ``drift_bound`` and the tests pin down.
* **snapshot resync** — a replica whose cursor fell off the log restores
  from a wire-compressed snapshot instead of a dense broadcast: the
  hub's shadow params are packed into bucket buffers and diff-encoded
  against the BASE checkpoint every replica booted from
  (``encoding.snapshot_encode(cur, base=...)``). Under sparse training
  the params' drift from base has bounded support, so the snapshot costs
  a few percent of the dense dump and restores bitwise.

The hub itself is transport-agnostic: ``publish``/``sync`` move uint32
numpy buffers, exactly what a real network fabric would move.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import buckets as bk
from repro.core import encoding as enc
from repro.launch import delta_stream as ds
from repro.launch.serve import replica_copy

Array = jax.Array


@dataclasses.dataclass
class ReplicaHandle:
    """One subscriber's state. ``params`` are always fresh buffers (never
    aliased to the trainer); ``cursor`` is the next step to apply."""

    rid: int
    tier: str  # "float32" (bitwise) | "bfloat16" (lossy half-size)
    cursor: int
    params: Any
    joined_at: int = 0  # hub step at join time (for dense-equivalent cost)
    bytes_rx: int = 0
    steps_replayed: int = 0
    resyncs: int = 0


class FanoutHub:
    """Fan one trainer delta stream out to N replicas (see module doc).

    ``dspec``/``base_params`` come from the trainer side:
    ``make_train_step(...).delta_spec`` and the boot checkpoint every
    replica starts from. ``base_params`` is deep-copied (`replica_copy`)
    so trainer-side donation can never invalidate the hub's reference.
    """

    TIERS = ("float32", "bfloat16")

    def __init__(
        self,
        dspec: ds.DeltaSpec,
        base_params,
        *,
        log_bound: int = 64,
        snapshot_every: Optional[int] = None,
    ):
        if log_bound < 1:
            raise ValueError("log_bound must be >= 1")
        if snapshot_every is not None and snapshot_every > log_bound:
            raise ValueError(
                "snapshot_every > log_bound would leave un-replayable gaps"
            )
        self.dspec = dspec
        self.src_tier = dspec.wires[0].value_dtype
        self.base = replica_copy(base_params)
        self.base_bufs = bk.pack(dspec.plan, self.base)
        self.shadow = replica_copy(base_params)  # tracks the stream exactly
        self.log_bound = log_bound
        self.snapshot_every = snapshot_every
        self.step = 0  # next step index to publish
        self.published_bytes = 0
        self._log: Dict[int, Tuple[np.ndarray, ...]] = {}
        self._transcoded: Dict[str, Dict[int, Tuple[np.ndarray, ...]]] = {}
        self._snap: Optional[Tuple[int, List[enc.SnapshotRecord], int]] = None
        self._replicas: Dict[int, ReplicaHandle] = {}
        self._next_rid = 0
        self._appliers: Dict[str, Any] = {}
        self._specs: Dict[str, ds.DeltaSpec] = {self.src_tier: dspec}

    # -- trainer side -------------------------------------------------------

    @property
    def log_start(self) -> int:
        """Oldest step still replayable from the log."""
        return max(0, self.step - self.log_bound)

    def publish(self, step: int, msgs: Sequence[Array]) -> None:
        """Ingest one trainer step's wire messages (``delta_sink``
        signature). Steps must arrive consecutively from 0."""
        if step != self.step:
            raise ValueError(
                f"publish out of order: got step {step}, expected {self.step}"
            )
        if len(msgs) != len(self.dspec.wires):
            raise ValueError(
                f"{len(msgs)} buffers for {len(self.dspec.wires)} buckets"
            )
        host = tuple(np.asarray(m) for m in msgs)
        self._log[step] = host
        self.shadow = self._apply(self.src_tier)(self.shadow, host)
        self.step = step + 1
        self.published_bytes += self.dspec.nbytes
        evict = self.log_start
        for s in [s for s in self._log if s < evict]:
            del self._log[s]
            for cache in self._transcoded.values():
                cache.pop(s, None)
        if self.snapshot_every and self.step % self.snapshot_every == 0:
            self._snap = self._take_snapshot()

    # -- snapshots ----------------------------------------------------------

    def _take_snapshot(self) -> Tuple[int, List[enc.SnapshotRecord], int]:
        cur = bk.pack(self.dspec.plan, self.shadow)
        records = [
            enc.snapshot_encode(c, base=b)
            for c, b in zip(cur, self.base_bufs)
        ]
        return self.step, records, sum(r.nbytes for r in records)

    def snapshot(self) -> Tuple[int, List[enc.SnapshotRecord], int]:
        """(step, records, nbytes): the current shadow params diff-encoded
        against the boot checkpoint (exact; dense fallback per bucket)."""
        return self._take_snapshot()

    def _restore(self, records: Sequence[enc.SnapshotRecord]):
        bufs = [
            enc.snapshot_decode(r, base=b)
            for r, b in zip(records, self.base_bufs)
        ]
        return bk.unpack(self.dspec.plan, bufs, cast=True)

    # -- replica side -------------------------------------------------------

    def join(self, tier: str = "float32") -> ReplicaHandle:
        """Subscribe a new replica: it boots from the shared base
        checkpoint with its cursor at step 0 — ``sync`` brings it to the
        head via replay and/or snapshot."""
        if tier not in self.TIERS:
            raise ValueError(f"tier {tier!r} not in {self.TIERS}")
        r = ReplicaHandle(
            rid=self._next_rid, tier=tier, cursor=0,
            params=replica_copy(self.base), joined_at=self.step,
        )
        self._next_rid += 1
        self._replicas[r.rid] = r
        return r

    def sync(self, replica: ReplicaHandle) -> ReplicaHandle:
        """Advance ``replica`` to the head of the stream: replay every
        logged step it missed in order; if its cursor fell off the log,
        resync from a wire-compressed snapshot first (cached periodic
        snapshot when fresh enough, else one taken now)."""
        while replica.cursor < self.step:
            if replica.cursor < self.log_start:
                self._snapshot_resync(replica)
                continue
            msgs, spec_bytes = self._serve(replica.cursor, replica.tier)
            replica.params = self._apply(replica.tier)(replica.params, msgs)
            replica.cursor += 1
            replica.steps_replayed += 1
            replica.bytes_rx += spec_bytes
        return replica

    def _snapshot_resync(self, replica: ReplicaHandle) -> None:
        snap = self._snap
        if snap is None or snap[0] < self.log_start:
            # cache the fresh snapshot: every other lagged replica at
            # this step resyncs from the same records for free
            snap = self._snap = self._take_snapshot()
        step, records, nbytes = snap
        replica.params = self._restore(records)
        replica.cursor = step
        replica.bytes_rx += nbytes
        replica.resyncs += 1

    def _spec(self, tier: str) -> ds.DeltaSpec:
        """Static per-tier delta spec, derived once and cached."""
        if tier not in self._specs:
            self._specs[tier] = self.dspec.with_value_dtype(tier)
        return self._specs[tier]

    def _serve(self, step: int, tier: str) -> Tuple[Tuple[np.ndarray, ...], int]:
        """The wire buffers for ``step`` in ``tier``'s encoding; lossy
        tiers are transcoded once per step and cached for all
        subscribers."""
        if tier == self.src_tier:
            return self._log[step], self.dspec.nbytes
        cache = self._transcoded.setdefault(tier, {})
        if step not in cache:
            cache[step] = tuple(
                np.asarray(m)
                for m in ds.transcode_delta(self.dspec, self._log[step], tier)
            )
        return cache[step], self._spec(tier).nbytes

    def _apply(self, tier: str):
        """jit-cached ``apply_delta`` for one tier's static spec."""
        if tier not in self._appliers:
            spec = self._spec(tier)
            self._appliers[tier] = jax.jit(
                lambda params, msgs: ds.apply_delta(params, spec, msgs)
            )
        return self._appliers[tier]

    # -- accounting ---------------------------------------------------------

    def drift_bound(self, tier: str = "bfloat16") -> float:
        """Upper bound on a ``tier`` replica's parameter drift from the
        trainer over the steps still in the log: the sum of per-step
        transcode rounding errors ``||u_t - tier(u_t)||_inf`` (each step's
        update enters the replica exactly once; f32 accumulation error is
        second-order and covered by the tests' slack)."""
        bound = 0.0
        for step in sorted(self._log):
            exact = ds.decode_delta(self.dspec, self._log[step])
            lossy = ds.decode_delta(
                self._spec(tier), self._serve(step, tier)[0]
            )
            bound += max(
                float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(exact), jax.tree.leaves(lossy))
            )
        return bound

    def _dense_equiv(self, r: ReplicaHandle) -> int:
        """What a dense-broadcast world would have shipped this replica:
        one full param dump at join (if it joined mid-stream) plus one
        dense refresh per step published since."""
        boot = 1 if r.joined_at > 0 else 0
        return (boot + self.step - r.joined_at) * self.dspec.dense_nbytes

    def stats(self) -> dict:
        """Bytes accounting: what the hub shipped vs what dense
        broadcasts to the same fleet (respecting each replica's join
        step) would have cost."""
        per_replica = {
            r.rid: {
                "tier": r.tier, "cursor": r.cursor,
                "joined_at": r.joined_at, "bytes_rx": r.bytes_rx,
                "dense_equiv_bytes": self._dense_equiv(r),
                "steps_replayed": r.steps_replayed, "resyncs": r.resyncs,
            }
            for r in self._replicas.values()
        }
        served = sum(r.bytes_rx for r in self._replicas.values())
        dense = sum(self._dense_equiv(r) for r in self._replicas.values())
        if not self._replicas:
            dense = self.step * self.dspec.dense_nbytes
        return {
            "published_steps": self.step,
            "published_bytes": self.published_bytes,
            "log": (self.log_start, self.step),
            "replicas": per_replica,
            "served_bytes": served,
            "dense_broadcast_bytes": dense,
            "fanout_ratio": dense / max(1, served),
        }
