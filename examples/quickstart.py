"""Quickstart: Mem-SGD (the paper's Algorithm 1) in 30 lines.

Compresses each gradient to its top-0.1% coordinates with error feedback
and still converges — the point of the paper.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import constant_eta, leaf_compressor_from_ratio, memsgd
from repro.optim import apply_updates

# a toy regression task: params {w, b}, data y = x @ w* + b*
key = jax.random.PRNGKey(0)
w_star = jax.random.normal(key, (64, 8))
X = jax.random.normal(jax.random.fold_in(key, 1), (512, 64))
Y = X @ w_star + 0.3

params = {"w": jnp.zeros((64, 8)), "b": jnp.zeros((8,))}


def loss_fn(p, x, y):
    pred = x @ p["w"] + p["b"]
    return jnp.mean((pred - y) ** 2)


# Mem-SGD: top-k compression (k = 1% of each tensor) + error feedback.
tx = memsgd(leaf_compressor_from_ratio(0.01), constant_eta(0.05))
state = tx.init(params)

for step in range(600):
    grads = jax.grad(loss_fn)(params, X, Y)
    updates, state = tx.update(grads, state)
    params = apply_updates(params, updates)
    if step % 100 == 0:
        print(f"step {step:4d}  loss {loss_fn(params, X, Y):.5f}")

final = float(loss_fn(params, X, Y))
print(f"final loss {final:.5f}  (only 1% of coordinates communicated/step)")
assert final < 0.01
