"""End-to-end driver: train a ~100M-param qwen3-family model with
PARALLEL-MEM-SGD on a data+model mesh for a few hundred steps.

This is the (b) end-to-end deliverable: real data pipeline, real mesh,
per-worker error-feedback memory, sparse all-gather gradient exchange,
checkpointing — the full stack, sized to run on this CPU container.

Run:  PYTHONPATH=src python examples/distributed_train.py \
          [--steps 300] [--devices 4] [--optimizer memsgd] [--ratio 0.01]

(--devices N > 1 forces N host platform devices; must be set before jax
 initializes, which this script does for you.)
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--devices", type=int, default=4)
ap.add_argument("--data", type=int, default=None, help="data-axis size")
ap.add_argument("--model", type=int, default=None, help="model-axis size")
ap.add_argument("--optimizer", default="memsgd",
                choices=["memsgd", "memsgd_momentum", "adam_compressed",
                         "dense"])
ap.add_argument("--ratio", type=float, default=0.01)
ap.add_argument("--bucketed", action="store_true",
                help="flat-buffer bucketed sync (repro.core.buckets)")
ap.add_argument("--wire", default="unpacked", choices=["unpacked", "packed"],
                help="all-gather wire format (repro.core.encoding)")
ap.add_argument("--value-dtype", default="float32",
                choices=["float32", "bfloat16"], help="sync value dtype")
ap.add_argument("--d-model", type=int, default=512)
ap.add_argument("--layers", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
args = ap.parse_args()

if args.devices > 1:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices}"
    )
sys.path.insert(0, "src")

import jax  # noqa: E402  (after XLA_FLAGS)
from repro.utils.compat import make_mesh  # noqa: E402

from repro.checkpoint import Checkpointer  # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.core.distributed import SyncConfig, message_bytes  # noqa: E402
from repro.data import token_batches  # noqa: E402
from repro.data.pipeline import ShardedBatcher  # noqa: E402
from repro.launch.sharding import sync_col_axes  # noqa: E402
from repro.launch.train import TrainConfig, train  # noqa: E402
from repro.models import build_model  # noqa: E402


def main():
    # NB: on jax < 0.5 the legacy shard_map partial-auto mode cannot
    # partition a sharded model axis (XLA IsManualSubgroup crash) — use
    # --model 1 there (see tests/test_distributed.py::legacy_partial_auto).
    n_data = args.data or max(1, args.devices // 2)
    n_model = args.model or (args.devices // n_data)
    mesh = make_mesh((n_data, n_model), ("data", "model"))
    print(f"mesh: data={n_data} model={n_model}")

    # ~100M params: scale the qwen3 smoke family up
    cfg = get_smoke_config("qwen3-4b").replace(
        n_layers=args.layers, d_model=args.d_model, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=args.d_model * 4, vocab_size=8192,
        vocab_pad_multiple=256,
    )
    model = build_model(cfg)
    n_params = model.n_params()
    print(f"arch: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab_size} "
          f"-> {n_params/1e6:.1f}M params")

    tc = TrainConfig(
        optimizer=args.optimizer,
        eta=0.5 if args.optimizer.startswith("memsgd") else 3e-3,
        eta_shift=200.0,
        sync=SyncConfig(ratio=args.ratio, bucketed=args.bucketed,
                        wire=args.wire, value_dtype=args.value_dtype),
    )
    shapes = model.param_shapes()
    if args.bucketed:
        from repro.core import buckets as bk
        from repro.core.distributed import bucketed_message_bytes

        plan = bk.make_plan(shapes, cols=tc.sync.bucket_cols,
                            dense_below=tc.sync.dense_below)
        msg = bucketed_message_bytes(tc.sync, plan)
    else:
        msg = message_bytes(tc.sync, shapes, sync_col_axes(shapes))
    dense = message_bytes(SyncConfig(strategy="dense"), shapes)
    print(f"sync: {args.optimizer} ratio={args.ratio} wire={args.wire} -> "
          f"{msg/1e6:.2f} MB/worker/step (dense would be {dense/1e6:.1f} MB, "
          f"{dense/max(msg,1):.0f}x reduction)")

    batches = ShardedBatcher(
        mesh, token_batches(cfg.vocab_size, args.batch, args.seq, seed=0)
    )
    ck = Checkpointer(args.ckpt_dir, max_to_keep=2)
    params, memory, opt, count, history = train(
        model, mesh, tc, batches, n_steps=args.steps, checkpointer=ck,
        ckpt_every=max(50, args.steps // 4), log_every=10,
    )
    first, last = history[0][1], history[-1][1]
    print(f"\nloss {first:.4f} -> {last:.4f} over {args.steps} steps "
          f"({'OK' if last < first else 'NO IMPROVEMENT'})")
    print(f"checkpoints: {ck.steps()} in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
