"""Serving example: batched greedy decoding with per-family caches.

Demonstrates the serving path of the framework on three cache families:
  * dense GQA transformer  -> ring/linear KV cache
  * RWKV6                  -> O(1) state-space cache (no KV growth)
  * RecurrentGemma hybrid  -> mixed RG-LRU state + windowed KV cache

plus the trainer->replica **delta stream**: a serving replica tracks a
live Mem-SGD trainer through packed sparse parameter deltas
(repro.launch.delta_stream) instead of dense parameter broadcasts, then
serves from the refreshed weights.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from repro.utils.compat import make_mesh  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.launch.serve import apply_delta, decode_loop, make_serve_step  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.utils.tree import tree_size  # noqa: E402


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def main():
    mesh = make_mesh((1, 1), ("data", "model"))
    B, prompt_len, gen = 4, 8, 16
    max_len = 64
    for arch in ("qwen3-4b", "rwkv6-3b", "recurrentgemma-9b"):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(B, max_len)
        print(f"\n=== {arch} ({cfg.family}) ===")
        print(f"cache: {cache_bytes(cache)/1e6:.2f} MB for max_len={max_len} "
              f"(family={'O(1) state' if cfg.family == 'rwkv' else 'KV'})")
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (B, prompt_len), 0, cfg.vocab_size
        )
        toks = decode_loop(model, mesh, params, prompts, n_tokens=gen,
                           max_len=max_len)
        print(f"generated {toks.shape[1]} tokens x {toks.shape[0]} seqs; "
              f"sample: {toks[0, :8].tolist()}")
        assert int(jnp.max(toks)) < cfg.vocab_size
    delta_stream_demo()


def delta_stream_demo(arch: str = "rwkv6-3b", steps: int = 3):
    """Train `steps` Mem-SGD steps while a serving replica follows via
    the packed delta stream, then serve from the replica's weights."""
    from repro.core.distributed import SyncConfig
    from repro.data import token_batches
    from repro.data.pipeline import ShardedBatcher
    from repro.launch.train import (TrainConfig, init_train_state,
                                    make_train_step, state_shardings)

    print(f"\n=== delta stream ({arch}) ===")
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    tc = TrainConfig(optimizer="memsgd", eta=0.5, emit_deltas=True,
                     sync=SyncConfig(ratio=0.02, bucketed=True,
                                     wire="packed"))
    params, memory, opt, count = init_train_state(
        model, mesh, tc, rng=jax.random.PRNGKey(0))
    # replica bootstraps from the same checkpoint (one dense broadcast,
    # ever); every refresh after that is a sparse delta message.
    replica = jax.tree.map(lambda x: jnp.array(np.asarray(x)), params)
    pshard, mshard, _, _ = state_shardings(model, mesh, tc)
    params = jax.device_put(params, pshard)
    memory = jax.device_put(memory, mshard)
    step = make_train_step(model, mesh, tc)
    dspec = step.delta_spec
    batches = ShardedBatcher(
        mesh, token_batches(cfg.vocab_size, 8, 32, seed=1), prefetch=0)
    for i, batch in enumerate(batches):
        if i >= steps:
            break
        params, memory, opt, count, m, delta = step(
            params, memory, opt, count, batch)
        replica = apply_delta(replica, dspec, delta)
        print(f"step {i}: loss {float(m['loss']):.4f}, streamed "
              f"{dspec.nbytes/1e3:.1f} kB "
              f"(dense refresh: {dspec.dense_nbytes/1e3:.1f} kB)")
    drift = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(replica)))
    print(f"replica drift after {steps} refreshes: {drift} (exact: "
          f"{drift == 0.0})")
    assert drift == 0.0
    prompts = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0,
                                 cfg.vocab_size)
    toks = decode_loop(model, mesh, replica, prompts, n_tokens=8,
                       max_len=64)
    print(f"replica serves: {toks[0].tolist()}")


if __name__ == "__main__":
    main()
