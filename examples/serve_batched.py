"""Serving example: batched greedy decoding with per-family caches.

Demonstrates the serving path of the framework on three cache families:
  * dense GQA transformer  -> ring/linear KV cache
  * RWKV6                  -> O(1) state-space cache (no KV growth)
  * RecurrentGemma hybrid  -> mixed RG-LRU state + windowed KV cache

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from repro.utils.compat import make_mesh  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.launch.serve import decode_loop, make_serve_step  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.utils.tree import tree_size  # noqa: E402


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def main():
    mesh = make_mesh((1, 1), ("data", "model"))
    B, prompt_len, gen = 4, 8, 16
    max_len = 64
    for arch in ("qwen3-4b", "rwkv6-3b", "recurrentgemma-9b"):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(B, max_len)
        print(f"\n=== {arch} ({cfg.family}) ===")
        print(f"cache: {cache_bytes(cache)/1e6:.2f} MB for max_len={max_len} "
              f"(family={'O(1) state' if cfg.family == 'rwkv' else 'KV'})")
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (B, prompt_len), 0, cfg.vocab_size
        )
        toks = decode_loop(model, mesh, params, prompts, n_tokens=gen,
                           max_len=max_len)
        print(f"generated {toks.shape[1]} tokens x {toks.shape[0]} seqs; "
              f"sample: {toks[0, :8].tolist()}")
        assert int(jnp.max(toks)) < cfg.vocab_size


if __name__ == "__main__":
    main()
