"""Serving example: batched greedy decoding with per-family caches.

Demonstrates the serving path of the framework on three cache families:
  * dense GQA transformer  -> ring/linear KV cache
  * RWKV6                  -> O(1) state-space cache (no KV growth)
  * RecurrentGemma hybrid  -> mixed RG-LRU state + windowed KV cache

plus the trainer->replica **delta stream**: a serving replica tracks a
live Mem-SGD trainer through packed sparse parameter deltas
(repro.launch.delta_stream) instead of dense parameter broadcasts, then
serves from the refreshed weights,

plus the **fan-out hub** (repro.launch.fanout): one encoded delta
message per step serves a whole replica fleet — a steady f32 replica, a
half-bandwidth bf16 edge replica, and a late joiner that resyncs from a
wire-compressed snapshot instead of a dense broadcast.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from repro.utils.compat import make_mesh  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.launch.serve import apply_delta, decode_loop  # noqa: E402
from repro.models import build_model  # noqa: E402


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def main():
    mesh = make_mesh((1, 1), ("data", "model"))
    B, prompt_len, gen = 4, 8, 16
    max_len = 64
    for arch in ("qwen3-4b", "rwkv6-3b", "recurrentgemma-9b"):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(B, max_len)
        print(f"\n=== {arch} ({cfg.family}) ===")
        print(f"cache: {cache_bytes(cache)/1e6:.2f} MB for max_len={max_len} "
              f"(family={'O(1) state' if cfg.family == 'rwkv' else 'KV'})")
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (B, prompt_len), 0, cfg.vocab_size
        )
        toks = decode_loop(model, mesh, params, prompts, n_tokens=gen,
                           max_len=max_len)
        print(f"generated {toks.shape[1]} tokens x {toks.shape[0]} seqs; "
              f"sample: {toks[0, :8].tolist()}")
        assert int(jnp.max(toks)) < cfg.vocab_size
    delta_stream_demo()
    fanout_demo()


def delta_stream_demo(arch: str = "rwkv6-3b", steps: int = 3):
    """Train `steps` Mem-SGD steps while a serving replica follows via
    the packed delta stream, then serve from the replica's weights."""
    from repro.core.distributed import SyncConfig
    from repro.data import token_batches
    from repro.data.pipeline import ShardedBatcher
    from repro.launch.serve import replica_copy
    from repro.launch.train import (TrainConfig, init_train_state,
                                    make_train_step, state_shardings)

    print(f"\n=== delta stream ({arch}) ===")
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    tc = TrainConfig(optimizer="memsgd", eta=0.5, emit_deltas=True,
                     sync=SyncConfig(ratio=0.02, bucketed=True,
                                     wire="packed"))
    params, memory, opt, count = init_train_state(
        model, mesh, tc, rng=jax.random.PRNGKey(0))
    # replica bootstraps from the same checkpoint (one dense broadcast,
    # ever); every refresh after that is a sparse delta message. The
    # deep copy keeps it alive across the donating train step.
    replica = replica_copy(params)
    pshard, mshard, _, _ = state_shardings(model, mesh, tc)
    params = jax.device_put(params, pshard)
    memory = jax.device_put(memory, mshard)
    step = make_train_step(model, mesh, tc)
    dspec = step.delta_spec
    batches = ShardedBatcher(
        mesh, token_batches(cfg.vocab_size, 8, 32, seed=1), prefetch=0)
    for i, batch in enumerate(batches):
        if i >= steps:
            break
        params, memory, opt, count, m, delta = step(
            params, memory, opt, count, batch)
        replica = apply_delta(replica, dspec, delta)
        print(f"step {i}: loss {float(m['loss']):.4f}, streamed "
              f"{dspec.nbytes/1e3:.1f} kB "
              f"(dense refresh: {dspec.dense_nbytes/1e3:.1f} kB)")
    drift = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(replica)))
    print(f"replica drift after {steps} refreshes: {drift} (exact: "
          f"{drift == 0.0})")
    assert drift == 0.0
    prompts = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0,
                                 cfg.vocab_size)
    toks = decode_loop(model, mesh, replica, prompts, n_tokens=8,
                       max_len=64)
    print(f"replica serves: {toks[0].tolist()}")


def fanout_demo(arch: str = "rwkv6-3b", steps: int = 6):
    """One trainer, one hub, three replicas with different consumption:
    steady f32 (bitwise), bf16 edge (half bytes, bounded drift), and a
    late joiner that fell off the replay log (snapshot resync)."""
    from repro.core.distributed import SyncConfig
    from repro.data import token_batches
    from repro.data.pipeline import ShardedBatcher
    from repro.launch.fanout import FanoutHub
    from repro.launch.train import (TrainConfig, init_train_state,
                                    make_train_step, state_shardings)

    print(f"\n=== fan-out hub ({arch}) ===")
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    tc = TrainConfig(optimizer="memsgd", eta=0.5, emit_deltas=True,
                     sync=SyncConfig(ratio=0.02, bucketed=True,
                                     wire="packed"))
    params, memory, opt, count = init_train_state(
        model, mesh, tc, rng=jax.random.PRNGKey(0))
    step = make_train_step(model, mesh, tc)
    dspec = step.delta_spec
    # the hub deep-copies the boot params BEFORE the donating train step
    hub = FanoutHub(dspec, params, log_bound=3, snapshot_every=2)
    steady = hub.join()             # synced every step: pure replay
    edge = hub.join("bfloat16")     # lossy half-size tier
    pshard, mshard, _, _ = state_shardings(model, mesh, tc)
    params = jax.device_put(params, pshard)
    memory = jax.device_put(memory, mshard)
    batches = ShardedBatcher(
        mesh, token_batches(cfg.vocab_size, 8, 32, seed=1), prefetch=0)
    for i, batch in enumerate(batches):
        if i >= steps:
            break
        params, memory, opt, count, m, delta = step(
            params, memory, opt, count, batch)
        hub.publish(i, delta)
        hub.sync(steady)
        hub.sync(edge)
    late = hub.join()  # cursor 0 fell off the log -> snapshot resync
    hub.sync(late)
    drift = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(edge.params)))
    exact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(steady.params)))
    late_exact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(late.params)))
    s = hub.stats()
    print(f"steady replica bitwise: {exact}; late joiner (snapshot "
          f"resync x{late.resyncs}) bitwise: {late_exact}; "
          f"bf16 edge drift: {drift:.2e}")
    for rid, r in s["replicas"].items():
        print(f"  replica {rid} [{r['tier']}]: {r['bytes_rx']/1e6:.2f} MB rx "
              f"(dense broadcast would be "
              f"{r['dense_equiv_bytes']/1e6:.2f} MB)")
    print(f"fleet total: {s['served_bytes']/1e6:.2f} MB served vs "
          f"{s['dense_broadcast_bytes']/1e6:.2f} MB dense broadcast "
          f"(x{s['fanout_ratio']:.1f})")
    assert exact and late_exact


if __name__ == "__main__":
    main()
