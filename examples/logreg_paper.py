"""Paper Section 4 reproduction: logistic regression with Mem-SGD.

Reproduces the experimental protocol of Fig. 2 (theoretical stepsizes
eta_t = gamma/(lambda(t+a)), weighted average w_t = (t+a)^2) on an
epsilon-like dense dataset and an RCV1-like sparse dataset, comparing:

  * vanilla SGD (dense communication)
  * Mem-SGD top-k / rand-k (k sparse coordinates per step)
  * the 'without delay' ablation (a=1) that the paper shows hurts

Run:  PYTHONPATH=src python examples/logreg_paper.py [--full]
"""
import argparse
import sys

sys.path.insert(0, "src")  # allow running from repo root without install

from benchmarks.logreg_runners import (
    reference_optimum,
    run_memsgd,
    run_sgd,
)
from repro.core import encoding
from repro.data import make_epsilon_like, make_rcv1_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args()

    if args.full:
        datasets = [
            ("epsilon", make_epsilon_like(n=400_000, d=2_000), (1, 2, 3), 1.0),
            ("rcv1", make_rcv1_like(n=20_000, d=47_236), (10, 20, 30), 10.0),
        ]
        T_mult = 2
    else:
        datasets = [
            ("epsilon-small", make_epsilon_like(n=4_000, d=200), (1, 2), 1.0),
            ("rcv1-small", make_rcv1_like(n=2_000, d=2_000, density=0.01),
             (10, 20), 10.0),
        ]
        T_mult = 2

    for name, data, ks, shift_factor in datasets:
        T = T_mult * data.n
        fstar = reference_optimum(data)
        print(f"\n=== {name}: n={data.n} d={data.d} lam=1/n  f*={fstar:.5f} ===")
        r = run_sgd(data, T)
        print(f"  {'sgd (dense)':26s} subopt={r.final_loss - fstar:.3e}  "
              f"bits/step={r.bits_per_step:,.0f}")
        for k in ks:
            a = shift_factor * data.d / k  # paper Table 2
            for comp in ("top", "rand"):
                r = run_memsgd(data, T, k=k, comp=comp, a=a)
                red = encoding.reduction_factor(data.d, k)
                print(f"  {f'memsgd {comp}-{k} (a={a:.0f})':26s} "
                      f"subopt={r.final_loss - fstar:.3e}  "
                      f"bits/step={r.bits_per_step:,.0f}  ({red:.0f}x less)")
        # delay ablation
        k = ks[0]
        r = run_memsgd(data, T, k=k, comp="top", a=1.0)
        print(f"  {f'memsgd top-{k} WITHOUT delay':26s} "
              f"subopt={r.final_loss - fstar:.3e}   <- a=1 hurts (Fig. 2)")


if __name__ == "__main__":
    main()
