"""Benchmark harness — one function per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows (derived = the
figure-specific headline number). Artifacts (full loss curves) are written
to experiments/bench/*.json.

  fig2_convergence   paper Fig. 2 — Mem-SGD top-k/rand-k vs SGD, delay
                     ablation, theoretical stepsizes + weighted averaging
  fig3_qsgd          paper Fig. 3 — Mem-SGD vs QSGD, convergence + bits
  fig4_multicore     paper Fig. 4 — PARALLEL-MEM-SGD scaling (simulated)
  table_comm         communication-volume table for the 10 assigned archs
  kernel_topk        Pallas kernel wall-time (interpret mode) vs oracle
  wire_codec         packed wire codec throughput + bytes-on-wire vs the
                     unpacked (f32 value, int32 index) baseline
  fanout             delta fan-out hub: bytes/replica vs dense broadcast
                     at N=1/4/16, bf16 tier, snapshot-resync bytes
  hierarchy          two-level pod-aware bucketed sync: intra- vs
                     cross-pod bytes vs the flat bucketed baseline on a
                     2-pod mesh, packed==unpacked bit-identity, exact
                     mass conservation
  refresh            live pod-ratio refresh on the k-padded dynamic
                     wire: drifting-mass capture refresh-on vs -off,
                     2-pod smoke run with zero recompiles + bitwise
                     schedule replay
  overlap            double-buffered bucket pipeline: host-pipelined
                     encode/all-gather/decode over an emulated wire vs
                     sequential (strictly faster, bitwise-equal), plus
                     2-pod smoke bitwise identity overlap on == off for
                     flat/hierarchical/pod-dynamic
  budget             header-aware repack transport + global byte-budget
                     controller: realized cross-pod bytes == live-k
                     accounting on the drift synthetic (vs the ~7.6x
                     padded gather), water-filled budget vs frozen
                     static-k capture-per-byte, 2-pod smoke bitwise
                     identity + budget-driven refreshes

Fast mode (default) uses reduced n/T; ``--full`` approaches paper scale.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

# the ``--list`` fast path is CI's shard-matrix source of truth and runs
# on a bare hosted runner with NO deps installed — it must import
# cleanly without numpy; only the bench bodies need it (main() refuses
# to run benches when it is absent)
try:
    import numpy as np
except ModuleNotFoundError:
    np = None

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def _emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")


def _save(name: str, payload: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)


# ---------------------------------------------------------------------------


def fig2_convergence(full: bool = False):
    from benchmarks.logreg_runners import (
        reference_optimum,
        run_memsgd,
        run_sgd,
    )
    from repro.data import make_epsilon_like

    n, d = (400_000, 2_000) if full else (4_000, 200)
    T = 4 * n if full else 3 * n
    data = make_epsilon_like(n=n, d=d, seed=0)
    fstar = reference_optimum(data)
    rows = {}
    runs = [
        ("sgd", lambda: run_sgd(data, T, gamma=2.0, a=1.0)),
        ("top1", lambda: run_memsgd(data, T, k=max(1, d // 2000), comp="top")),
        ("top_k2", lambda: run_memsgd(data, T, k=max(2, 2 * d // 2000),
                                      comp="top")),
        ("rand1", lambda: run_memsgd(data, T, k=max(1, d // 2000),
                                     comp="rand")),
        ("top1_no_delay", lambda: run_memsgd(data, T, k=max(1, d // 2000),
                                             comp="top", a=1.0)),
    ]
    for label, fn in runs:
        r = fn()
        subopt = r.final_loss - fstar
        rows[label] = {
            "losses": r.losses, "subopt": subopt,
            "bits_per_step": r.bits_per_step, "fstar": fstar,
        }
        _emit(f"fig2_{label}", r.wall_s / max(1, T) * 1e6,
              f"subopt={subopt:.3e}")
    _save("fig2_convergence", rows)
    # paper claims to validate (EXPERIMENTS.md):
    # (1) top-k with memory converges comparably to SGD
    ok1 = rows["top1"]["subopt"] < 5 * max(rows["sgd"]["subopt"], 1e-4)
    # (2) 'without delay' (a=1) is clearly worse than a = d/k
    ok2 = rows["top1_no_delay"]["subopt"] > rows["top1"]["subopt"]
    _emit("fig2_claims", 0.0, f"memory_matches_sgd={ok1};delay_matters={ok2}")
    return rows


def fig3_qsgd(full: bool = False):
    from benchmarks.logreg_runners import (
        reference_optimum,
        run_memsgd_bottou,
        run_qsgd,
    )
    from repro.data import make_epsilon_like

    n, d = (400_000, 2_000) if full else (4_000, 200)
    T = 2 * n
    data = make_epsilon_like(n=n, d=d, seed=1)
    fstar = reference_optimum(data)
    rows = {}
    k1 = max(1, d // 2000)
    runs = [
        ("mem_top1", lambda: run_memsgd_bottou(data, T, k=k1, gamma0=0.5)),
        ("qsgd_2bit", lambda: run_qsgd(data, T, bits=2, gamma0=0.5)),
        ("qsgd_4bit", lambda: run_qsgd(data, T, bits=4, gamma0=0.5)),
        ("qsgd_8bit", lambda: run_qsgd(data, T, bits=8, gamma0=0.5)),
    ]
    for label, fn in runs:
        r = fn()
        subopt = r.final_loss - fstar
        total_mb = r.bits_per_step * T / 8 / 1e6
        rows[label] = {
            "losses": r.losses, "subopt": subopt,
            "bits_per_step": r.bits_per_step, "total_MB": total_mb,
        }
        _emit(f"fig3_{label}", r.wall_s / max(1, T) * 1e6,
              f"subopt={subopt:.3e};totalMB={total_mb:.2f}")
    # paper claim: Mem-SGD transmits ~2 orders of magnitude fewer bits than
    # QSGD while converging to comparable accuracy (vs 4/8-bit)
    ratio = rows["qsgd_4bit"]["bits_per_step"] / rows["mem_top1"]["bits_per_step"]
    _emit("fig3_claims", 0.0, f"bits_ratio_vs_4bit={ratio:.1f}")
    _save("fig3_qsgd", rows)
    return rows


def fig4_multicore(full: bool = False):
    from benchmarks.logreg_runners import run_parallel_memsgd_sim
    from repro.data import make_epsilon_like

    n, d = (40_000, 500) if full else (4_000, 200)
    data = make_epsilon_like(n=n, d=d, seed=2)
    target_T = 2 * n if full else n
    rows = {}
    for W in (1, 2, 4, 8):
        r = run_parallel_memsgd_sim(
            data, T_per_worker=target_T // W, k=max(1, d // 100),
            n_workers=W, eta=0.05,
        )
        rows[f"W{W}"] = {"losses": r.losses, "final": r.final_loss}
        _emit(f"fig4_W{W}", r.wall_s / max(1, target_T) * 1e6,
              f"final={r.final_loss:.5f}")
    # claim: with the SAME total gradient budget split over W workers
    # (stale reads included), convergence barely degrades
    degr = rows["W8"]["final"] - rows["W1"]["final"]
    _emit("fig4_claims", 0.0, f"degradation_W8_vs_W1={degr:.2e}")
    _save("fig4_multicore", rows)
    return rows


def table_comm(full: bool = False):
    """Per-step per-worker communication for every assigned architecture:
    Mem-SGD sparse message vs dense all-reduce (the paper's headline d/k)."""
    from repro.configs import ARCH_IDS, get_config
    from repro.core.distributed import SyncConfig, message_bytes
    from repro.launch.sharding import sync_col_axes
    from repro.models import build_model

    rows = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        t0 = time.time()
        shapes = model.param_shapes()
        cols = sync_col_axes(shapes)
        sparse = message_bytes(SyncConfig(ratio=1e-3), shapes, cols)
        dense = message_bytes(SyncConfig(strategy="dense"), shapes, cols)
        hier = message_bytes(
            SyncConfig(ratio=1e-3, strategy="hierarchical", pod_axis="pod",
                       pod_ratio=1e-3), shapes, cols)
        rows[arch] = {
            "dense_MB": dense / 1e6,
            "memsgd_MB": sparse / 1e6,
            "hier_MB": hier / 1e6,
            "reduction": dense / sparse,
        }
        _emit(f"comm_{arch}", (time.time() - t0) * 1e6,
              f"dense={dense/1e6:.1f}MB;memsgd={sparse/1e6:.3f}MB;"
              f"x{dense/sparse:.0f}")
    _save("table_comm", rows)
    return rows


def kernel_topk(full: bool = False):
    """Wall-time of the Pallas kernels (interpret mode on CPU — not a TPU
    perf number; correctness-path throughput + derived contraction), plus
    the loop-vs-single-pass comparison tracked in BENCH_topk.json at the
    repo root."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import densify_rows_ref, fused_memsgd_update, row_topk
    from repro.kernels.ref import row_topk_ref

    R, C, k = (256, 8192, 64) if full else (64, 4096, 64)
    x = jax.random.normal(jax.random.PRNGKey(0), (R, C))
    m = jax.random.normal(jax.random.PRNGKey(1), (R, C))

    def bench(fn, n=10):
        jax.block_until_ready(fn())  # warmup/compile
        t0 = time.time()
        for _ in range(n):
            out = fn()
        jax.block_until_ready(out)
        return (time.time() - t0) / n * 1e6

    us_loop = bench(lambda: row_topk(x, k, method="loop"))
    us_single = bench(lambda: row_topk(x, k, method="threshold"))
    v_l, i_l = row_topk(x, k, method="loop")
    v_s, i_s = row_topk(x, k, method="threshold")
    v_r, i_r = row_topk_ref(x, k)
    bitwise = (
        np.array_equal(np.asarray(v_l), np.asarray(v_r))
        and np.array_equal(np.asarray(i_l), np.asarray(i_r))
        and np.array_equal(np.asarray(v_s), np.asarray(v_r))
        and np.array_equal(np.asarray(i_s), np.asarray(i_r))
    )
    speedup = us_loop / us_single
    us_fused_loop = bench(
        lambda: fused_memsgd_update(m, x, 0.1, k, method="loop"))
    us_fused_single = bench(
        lambda: fused_memsgd_update(m, x, 0.1, k, method="threshold"))
    dense = densify_rows_ref(x, v_s, i_s)
    resid = float(jnp.sum((x - dense) ** 2) / jnp.sum(x**2))
    _emit("kernel_row_topk_loop", us_loop, f"k={k};C={C}")
    _emit("kernel_row_topk_singlepass", us_single,
          f"speedup_vs_loop={speedup:.2f};bitwise_equal={bitwise};"
          f"residual_frac={resid:.4f}")
    _emit("kernel_fused_loop", us_fused_loop, f"k/C={k/C:.4f}")
    _emit("kernel_fused_singlepass", us_fused_single,
          f"speedup_vs_loop={us_fused_loop/us_fused_single:.2f}")

    # bucketed engine: dispatches per step for a many-leaf architecture
    from repro.configs import get_smoke_config
    from repro.core import buckets as bk
    from repro.models import build_model

    shapes = build_model(get_smoke_config("rwkv6-3b")).param_shapes()
    plan = bk.make_plan(shapes)
    n_leaves = len(jax.tree.leaves(shapes))
    _emit("bucketed_dispatch", 0.0,
          f"leaves={n_leaves};buckets={plan.n_dispatch}")

    # loop-vs-threshold CUTOVER sweep: the backend table
    # (repro.utils.platform.TOPK_LOOP_CUTOVER) must route
    # method="auto" to the faster side wherever the gap is decisive.
    # Near the crossover both methods are within noise of each other —
    # interpret-mode timings swing ~40% run to run — so the gate only
    # checks ks where the winner leads by >= MARGIN.
    from repro.utils.platform import backend, topk_loop_cutover

    cut = topk_loop_cutover()
    MARGIN = 1.5
    sweep = []
    auto_ok = True
    for ks in (1, 2, 4, 8, 16, 32, 64):
        lu = bench(lambda: row_topk(x, ks, method="loop"))
        tu = bench(lambda: row_topk(x, ks, method="threshold"))
        auto = "threshold" if ks > cut else "loop"
        faster = "loop" if lu < tu else "threshold"
        decisive = max(lu, tu) / min(lu, tu) >= MARGIN
        ok = (not decisive) or auto == faster
        auto_ok = auto_ok and ok
        sweep.append({"k": ks, "loop_us": lu, "threshold_us": tu,
                      "auto": auto, "faster": faster,
                      "decisive": bool(decisive), "auto_ok": bool(ok)})
        _emit(f"kernel_topk_cutover_k{ks}", min(lu, tu),
              f"auto={auto};faster={faster};loop/thr={lu / tu:.2f}")
    _emit("kernel_topk_cutover", 0.0,
          f"backend={backend()};cutover_k={cut};"
          f"auto_matches_faster={auto_ok}")

    payload = {
        "shape": [R, C], "k": k,
        "loop_us": us_loop, "singlepass_us": us_single,
        "speedup": speedup, "bitwise_equal": bool(bitwise),
        "fused_loop_us": us_fused_loop,
        "fused_singlepass_us": us_fused_single,
        "bucketed": {"leaves": n_leaves, "buckets": plan.n_dispatch},
        "cutover": {
            "backend": backend(), "cutover_k": cut, "margin": MARGIN,
            "sweep": sweep, "auto_matches_faster": bool(auto_ok),
        },
    }
    _save("kernel_topk", payload)
    with open(os.path.join(_ROOT, "BENCH_topk.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)
    assert bitwise, "single-pass kernel diverged from the oracle"
    assert auto_ok, f"auto cutover routed a decisive k wrong: {sweep}"
    return payload


def wire_codec(full: bool = False):
    """Packed sparse wire codec (repro.core.encoding): encode/decode
    throughput and realized bytes-on-wire at the acceptance point (k=64,
    cols=1024) vs dense and vs the unpacked (f32 value, int32 index)
    baseline, plus the rwkv6-3b smoke-plan sync/delta byte trajectory.
    Writes BENCH_wire.json at the repo root."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.core import buckets as bk
    from repro.core import encoding as enc
    from repro.core.distributed import SyncConfig, bucketed_message_bytes
    from repro.kernels.ref import row_topk_ref
    from repro.models import build_model

    R, C, k = (1024, 1024, 64) if full else (256, 1024, 64)
    u = jax.random.normal(jax.random.PRNGKey(0), (R, C))
    vals, idx = row_topk_ref(u, k)
    vals, idx = jax.block_until_ready(vals), jax.block_until_ready(idx)

    def bench(fn, n=20):
        jax.block_until_ready(fn())  # warmup/compile
        t0 = time.time()
        for _ in range(n):
            out = fn()
        jax.block_until_ready(out)
        return (time.time() - t0) / n * 1e6

    unpacked_bytes = R * k * (4 + 4)
    dense_bytes = R * C * 4
    payload = {"shape": [R, C], "k": k, "unpacked_bytes": unpacked_bytes,
               "dense_bytes": dense_bytes}
    for vd in ("float32", "bfloat16"):
        spec = enc.WireSpec(R, C, k, vd)
        encode = jax.jit(lambda v, i: enc.encode(spec, v, i))
        buf = jax.block_until_ready(encode(vals, idx))
        decode = jax.jit(lambda b: enc.decode(spec, b))
        us_enc = bench(lambda: encode(vals, idx))
        us_dec = bench(lambda: decode(buf))
        v2, i2 = decode(buf)
        exact = bool(
            np.array_equal(np.asarray(i2), np.asarray(idx))
            and np.array_equal(
                np.asarray(v2, np.float32),
                np.asarray(vals.astype(jnp.dtype(vd)), np.float32),
            )
        )
        assert spec.nbytes == buf.size * 4
        ratio = unpacked_bytes / spec.nbytes
        # payload MB/s through encode (values+indices actually shipped)
        enc_mbps = spec.nbytes / (us_enc / 1e6) / 1e6
        dec_mbps = spec.nbytes / (us_dec / 1e6) / 1e6
        _emit(f"wire_encode_{vd}", us_enc,
              f"bytes={spec.nbytes};x_vs_unpacked={ratio:.2f};"
              f"x_vs_dense={dense_bytes/spec.nbytes:.1f};"
              f"MBps={enc_mbps:.1f}")
        _emit(f"wire_decode_{vd}", us_dec,
              f"roundtrip_exact={exact};MBps={dec_mbps:.1f}")
        payload[vd] = {
            "packed_bytes": spec.nbytes, "encode_us": us_enc,
            "decode_us": us_dec, "roundtrip_exact": exact,
            "ratio_vs_unpacked": ratio,
            "ratio_vs_dense": dense_bytes / spec.nbytes,
        }
        assert exact, f"wire codec round-trip diverged ({vd})"

    # rwkv6-3b smoke plan: realized sync + delta-stream bytes per step
    shapes = build_model(get_smoke_config("rwkv6-3b")).param_shapes()
    plan = bk.make_plan(shapes)
    base = SyncConfig(ratio=0.02, bucketed=True)
    sync_bytes = {
        "unpacked_f32": bucketed_message_bytes(base, plan),
        "packed_f32": bucketed_message_bytes(
            dataclasses.replace(base, wire="packed"), plan),
        "packed_bf16": bucketed_message_bytes(
            dataclasses.replace(base, wire="packed",
                                value_dtype="bfloat16"), plan),
        "dense": bucketed_message_bytes(
            dataclasses.replace(base, strategy="dense"), plan),
    }
    from repro.launch.delta_stream import make_delta_spec

    dspec = make_delta_spec(plan, base, workers=4)
    payload["rwkv6_3b_smoke"] = {
        "sync_bytes_per_step": sync_bytes,
        "delta_bytes_per_step": dspec.nbytes,
        "delta_dense_bytes_per_step": dspec.dense_nbytes,
    }
    _emit("wire_rwkv6_sync", 0.0,
          ";".join(f"{n}={b}" for n, b in sync_bytes.items()))
    _emit("wire_rwkv6_delta", 0.0,
          f"delta={dspec.nbytes};dense={dspec.dense_nbytes};"
          f"x{dspec.dense_nbytes/dspec.nbytes:.1f}")
    _save("wire_codec", payload)
    with open(os.path.join(_ROOT, "BENCH_wire.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)
    assert payload["bfloat16"]["ratio_vs_unpacked"] >= 1.8, payload
    return payload


def fanout(full: bool = False):
    """Fan-out hub (repro.launch.fanout): bytes per replica per step vs a
    dense parameter broadcast at N=1/4/16 replicas, the bf16 tier's
    savings, and the wire-compressed snapshot-resync bytes vs the dense
    f32 params dump — on the rwkv6-3b smoke plan with a synthetic
    support-bounded update stream. Writes BENCH_fanout.json."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.core import buckets as bk
    from repro.core.distributed import SyncConfig, _row_scatter, _row_topk
    from repro.launch import delta_stream as ds
    from repro.launch.fanout import FanoutHub
    from repro.models import build_model

    model = build_model(get_smoke_config("rwkv6-3b"))
    shapes = model.param_shapes()
    plan = bk.make_plan(shapes)
    dspec = ds.make_delta_spec(
        plan, SyncConfig(ratio=0.02, bucketed=True), workers=4
    )
    params = jax.tree.map(
        lambda s: jax.random.normal(
            jax.random.PRNGKey(hash(s.shape) % 2**31), s.shape
        ).astype(s.dtype),
        shapes,
    )
    T = 12 if full else 6

    def step_msgs(t):
        bufs = []
        for i, (spec, w) in enumerate(zip(plan.buckets, dspec.wires)):
            g = jax.random.normal(
                jax.random.PRNGKey(t * 17 + i), spec.shape
            )
            if spec.kind == "dense":
                bufs.append(g * 0.01)
            else:
                vals, idx = _row_topk(g, w.k)
                bufs.append(_row_scatter(spec.shape, vals, idx, jnp.float32))
        return ds.encode_delta_bufs(dspec, bufs)

    msgs = [jax.block_until_ready(step_msgs(t)) for t in range(T)]
    bf16_nbytes = dspec.with_value_dtype("bfloat16").nbytes
    payload = {
        "plan": "rwkv6-3b-smoke", "steps": T,
        "delta_nbytes": dspec.nbytes,
        "delta_bf16_nbytes": bf16_nbytes,
        "dense_nbytes": dspec.dense_nbytes,
        "per_N": {},
    }
    for N in (1, 4, 16):
        hub = FanoutHub(dspec, params, log_bound=T)
        # one bf16 edge replica once there is a fleet, the rest exact
        replicas = [
            hub.join("bfloat16" if N > 1 and r == N - 1 else "float32")
            for r in range(N)
        ]
        t0 = time.time()
        for t in range(T):
            hub.publish(t, msgs[t])
            for r in replicas:
                hub.sync(r)
        us_step = (time.time() - t0) / T * 1e6
        s = hub.stats()
        # replica egress: every subscriber gets the packed (or bf16)
        # message instead of a dense param dump
        ratio = s["dense_broadcast_bytes"] / s["served_bytes"]
        # trainer ingress: ONE packed message per step feeds the hub no
        # matter how many replicas subscribe — this is the fan-out win
        pub_ratio = s["dense_broadcast_bytes"] / s["published_bytes"]
        payload["per_N"][str(N)] = {
            "served_bytes": s["served_bytes"],
            "published_bytes": s["published_bytes"],
            "dense_broadcast_bytes": s["dense_broadcast_bytes"],
            "ratio_vs_dense": ratio,
            "publisher_ratio_vs_dense": pub_ratio,
            "bytes_per_replica_step": s["served_bytes"] / (N * T),
            "publish_sync_us_per_step": us_step,
        }
        _emit(f"fanout_N{N}", us_step,
              f"bytes/replica/step={s['served_bytes'] / (N * T):.0f};"
              f"x_vs_dense_broadcast={ratio:.1f};"
              f"publisher_x={pub_ratio:.1f}")
    # snapshot resync cost after T steps vs the dense f32 params dump
    snap_step, recs, snap_bytes = hub.snapshot()
    snap_dense = sum(r.dense_nbytes for r in recs)
    payload["snapshot"] = {
        "nbytes": snap_bytes, "dense_nbytes": snap_dense,
        "ratio_vs_dense": snap_dense / snap_bytes,
        "exact": all(r.exact for r in recs),
    }
    _emit("fanout_snapshot", 0.0,
          f"bytes={snap_bytes};dense={snap_dense};"
          f"x{snap_dense / snap_bytes:.1f};step={snap_step}")
    _save("fanout", payload)
    with open(os.path.join(_ROOT, "BENCH_fanout.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)
    # the falsifiable fan-out property: publish cost is independent of N
    # (the hub never re-encodes per subscriber), and every exact-tier
    # subscriber costs exactly one packed message per step
    pub = {n: p["published_bytes"] for n, p in payload["per_N"].items()}
    assert len(set(pub.values())) == 1, f"publish cost grew with N: {pub}"
    assert pub["1"] == T * dspec.nbytes, (pub, dspec.nbytes)
    for n, p in payload["per_N"].items():
        assert p["bytes_per_replica_step"] <= dspec.nbytes + 1e-9, (n, p)
    return payload


def hierarchy(full: bool = False):
    """Two-level pod-aware bucketed sync (strategy="hierarchical" on a
    (pod, data) mesh): intra- vs cross-pod bytes per step vs the flat
    bucketed baseline on the rwkv6-3b smoke plan with the smoke_2pod
    mesh config, for both wire formats. A subprocess run on the real
    8-device 2-pod mesh autotunes the per-bucket pod ratios from the
    first batch, trains a few steps under the packed AND unpacked
    wires (must be bit-identical), and checks the two-level mass-
    conservation invariant mean_w(u) == update + mean_w(new_memory).
    Writes BENCH_hierarchy.json at the repo root."""
    import dataclasses
    import subprocess
    import textwrap

    from repro.core import buckets as bk
    from repro.core.distributed import SyncConfig, bucketed_message_bytes
    from repro.configs import MESHES, get_smoke_config
    from repro.models import build_model

    mc = MESHES["smoke_2pod"]
    steps = 6 if full else 3
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import MESHES, get_smoke_config
        from repro.core import buckets as bk
        from repro.core.distributed import SyncConfig
        from repro.core.selfcheck import two_level_selfcheck
        from repro.data import token_batches
        from repro.data.pipeline import ShardedBatcher
        from repro.launch.mesh import mesh_from_config
        from repro.launch.train import (TrainConfig, make_train_step,
                                        init_train_state, state_shardings,
                                        _maybe_autotune_pod_ratios)
        from repro.models import build_model

        STEPS = {steps}
        mesh = mesh_from_config(MESHES["smoke_2pod"])
        cfg = get_smoke_config("rwkv6-3b")
        model = build_model(cfg)
        plan = bk.make_plan(model.param_shapes())
        import itertools
        batch_list = list(itertools.islice(iter(ShardedBatcher(
            mesh, token_batches(cfg.vocab_size, 8, 32, seed=3),
            batch_axes=("pod", "data"), prefetch=0)), STEPS + 1))

        def run(wire):
            tc = TrainConfig(optimizer="memsgd", eta=0.3,
                             sync=SyncConfig(ratio=0.02,
                                             strategy="hierarchical",
                                             bucketed=True, wire=wire))
            params, memory, opt, count = init_train_state(
                model, mesh, tc, rng=jax.random.PRNGKey(0))
            tc, it = _maybe_autotune_pod_ratios(
                model, mesh, tc, plan, params, iter(batch_list))
            pshard, mshard, _, _ = state_shardings(model, mesh, tc)
            params = jax.device_put(params, pshard)
            memory = jax.device_put(memory, mshard)
            step = make_train_step(model, mesh, tc)
            losses = []
            pending = None
            for i, batch in enumerate(it):
                if i >= STEPS: break
                params, memory, opt, count, m = step(
                    params, memory, opt, count, batch)
                # one-step-late drain: step i+1 is already dispatched
                # when step i's loss crosses to host, so the float()
                # never stalls the dispatch queue (RL001)
                if pending is not None:
                    losses.append(float(pending))
                pending = m["loss"]
            if pending is not None:
                losses.append(float(pending))
            return params, tc.sync.pod_ratios, losses

        p_pk, ratios_pk, loss_pk = run("packed")
        p_un, ratios_un, loss_un = run("unpacked")
        bit_identical = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(p_pk), jax.tree.leaves(p_un)))

        # two-level invariants on the shared synthetic probe
        # (repro.core.selfcheck -- the same harness the slow property
        # test runs, so the invariant definitions live in one place)
        chk = two_level_selfcheck(mesh)
        print(json.dumps({{
            "pod_ratios": list(ratios_pk),
            "ratios_match": list(ratios_pk) == list(ratios_un),
            "bit_identical": bool(bit_identical),
            "conservation_max_err": chk["conservation_max_err"],
            "probe_bit_identical": chk["bit_identical"],
            "accounting_exact": chk["accounting_exact"],
            "losses_packed": loss_pk, "losses_unpacked": loss_un}}))
        """
    ).format(src=os.path.join(_ROOT, "src"), steps=steps)
    t0 = time.time()
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=1800,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    wall_us = (time.time() - t0) * 1e6

    # exact per-level byte accounting with the realized autotuned ratios
    plan = bk.make_plan(build_model(get_smoke_config("rwkv6-3b")).param_shapes())
    base = SyncConfig(ratio=0.02, bucketed=True, pod_axis="pod",
                      pod_ratios=tuple(rec["pod_ratios"]))
    payload = {
        "plan": "rwkv6-3b-smoke",
        "mesh": {"name": mc.name, "n_pods": mc.n_pods, "n_data": mc.n_data},
        "steps": steps,
        "pod_ratios": rec["pod_ratios"],
        "bit_identical": (rec["bit_identical"] and rec["ratios_match"]
                          and rec["probe_bit_identical"]),
        "conservation_max_err": rec["conservation_max_err"],
        "conservation_ok": rec["conservation_max_err"] < 1e-5,
        "accounting_exact": rec["accounting_exact"],
        "losses_packed": rec["losses_packed"],
        "losses_unpacked": rec["losses_unpacked"],
    }
    for wire in ("packed", "unpacked"):
        two = bucketed_message_bytes(
            dataclasses.replace(base, strategy="hierarchical", wire=wire),
            plan, by_level=True)
        flat = bucketed_message_bytes(
            dataclasses.replace(base, strategy="sparse_allgather",
                                wire=wire),
            plan, by_level=True, n_data=mc.n_data)
        payload[wire] = {
            "two_level_intra": two["intra"], "two_level_cross": two["cross"],
            "flat_intra": flat["intra"], "flat_cross": flat["cross"],
            "cross_reduction": flat["cross"] / two["cross"],
        }
        _emit(f"hierarchy_{wire}", wall_us / max(1, 2 * steps),
              f"cross={two['cross']};flat_cross={flat['cross']};"
              f"x{flat['cross'] / two['cross']:.2f}")
    _emit("hierarchy_claims", 0.0,
          f"bit_identical={payload['bit_identical']};"
          f"conservation_max_err={rec['conservation_max_err']:.2e}")
    _save("hierarchy", payload)
    with open(os.path.join(_ROOT, "BENCH_hierarchy.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)
    # the acceptance claims: strictly fewer cross-pod bytes than the
    # flat bucketed baseline, bit-identical wires, exact conservation
    for wire in ("packed", "unpacked"):
        assert payload[wire]["two_level_cross"] < payload[wire]["flat_cross"], payload
    assert payload["bit_identical"], rec
    assert payload["conservation_ok"], rec
    assert payload["accounting_exact"], rec
    return payload


def refresh(full: bool = False):
    """Live pod-ratio refresh on the k-padded dynamic wire
    (--pod-refresh-every): (a) a host-side DRIFTING-MASS synthetic run —
    a bucket whose per-row mass concentration decays over training —
    comparing realized cross-pod mass capture and effective cross-pod
    bytes with the refresh ON vs OFF (off keeps the step-0 autotuned k
    and drifts out of the target band); (b) a 2-pod rwkv6-3b smoke run
    in a subprocess asserting >= 2 live refreshes with ZERO recompiles
    after step 1 (the jit cache gains no entry past the one-time step-1
    sharding settle — in particular none at a refresh), bitwise identity
    against a fresh run replaying the recorded k schedule, and the
    dynamic==static / conservation / accounting probe
    (repro.core.selfcheck.dynamic_k_selfcheck). Writes
    BENCH_refresh.json at the repo root."""
    import subprocess
    import textwrap

    import jax
    import jax.numpy as jnp

    from repro.core import buckets as bk
    from repro.core import encoding as enc
    from repro.core.distributed import SyncConfig, autotune_pod_ratios

    # -- (a) drifting-mass synthetic --------------------------------------
    T = 16 if full else 10
    every = 2
    n_data = 4
    rows, cols = 32, 512
    target = 0.9
    cfg = SyncConfig(ratio=0.02, strategy="hierarchical", pod_axis="pod",
                     bucketed=True, bucket_cols=cols, wire="packed",
                     pod_mass_target=target, pod_dynamic=True)
    plan = bk.make_plan(
        {"w": jax.ShapeDtypeStruct((rows * cols,), jnp.float32)},
        cols=cols, dense_below=cols,
    )
    k_row = cfg.k_for(cols)
    support = min(cols, n_data * k_row)
    k_max = cfg.pod_k_max_for_bucket(0, cols, n_data)
    rng = np.random.default_rng(7)
    perm = np.stack([rng.permutation(cols) for _ in range(rows)])
    signs = np.where(rng.random((rows, cols)) < 0.5, -1.0, 1.0)

    def u_shards(t):
        """Per-shard buffers whose per-row mass concentration DECAYS
        over training: power-law exponent 1.6 -> 0.15 (heavy tail at
        step 0, nearly flat at the end)."""
        alpha = 1.6 - (1.6 - 0.15) * t / max(1, T - 1)
        mag = (np.arange(1, cols + 1) ** (-alpha))[perm] * signs
        shards = np.stack([
            mag * (1.0 + 0.08 * rng.standard_normal((rows, cols)))
            for _ in range(n_data)
        ])
        return [jnp.asarray(shards, jnp.float32)]

    def capture_at(bufs, k):
        pm = bk.simulate_pod_mean(bufs[0], k_row)
        rel = bk.support_relative_capture(pm, support)
        return float(rel[min(k, support) - 1])

    def tuned_k(bufs):
        r = autotune_pod_ratios(cfg, plan, bufs, n_data=n_data,
                                k_caps=[k_max])[0]
        return int(round(r * cols))

    bufs0 = u_shards(0)
    k_off = k_on = tuned_k(bufs0)
    cap_on, cap_off, k_on_hist, eff_bytes = [], [], [], []
    for t in range(T):
        bufs = bufs0 if t == 0 else u_shards(t)
        if t > 0 and t % every == 0:
            k_on = tuned_k(bufs)  # the live refresh
        cap_on.append(capture_at(bufs, k_on))
        cap_off.append(capture_at(bufs, k_off))
        k_on_hist.append(k_on)
        eff_bytes.append(
            enc.message_nbytes(rows, cols, k_on, "float32", "packed"))
    padded = enc.message_nbytes(rows, cols, k_max, "float32", "packed")
    mean_eff = sum(eff_bytes) / len(eff_bytes)
    drift = {
        "steps": T, "refresh_every": every, "mass_target": target,
        "k_row": k_row, "support": support, "k_max": k_max,
        "k_on": k_on_hist, "k_off": k_off,
        "capture_on": cap_on, "capture_off": cap_off,
        "refresh_on": {
            "min_capture": min(cap_on),
            "mean_capture": sum(cap_on) / T,
            "mean_effective_cross_bytes": mean_eff,
        },
        "refresh_off": {
            "min_capture": min(cap_off),
            "mean_capture": sum(cap_off) / T,
            "mean_effective_cross_bytes": enc.message_nbytes(
                rows, cols, k_off, "float32", "packed"),
        },
        "capture_advantage": min(cap_on) - min(cap_off),
        "padded_cross_bytes": padded,
        "byte_ratio_padded_vs_effective": padded / mean_eff,
    }
    _emit("refresh_drift", 0.0,
          f"min_capture_on={min(cap_on):.3f};"
          f"min_capture_off={min(cap_off):.3f};"
          f"k_on={k_on_hist[0]}->{k_on_hist[-1]};"
          f"eff_bytes={eff_bytes[0]}->{eff_bytes[-1]};padded={padded}")

    # -- (b) 2-pod rwkv6-3b smoke: zero recompiles + bitwise replay --------
    steps = 6 if full else 5
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json, itertools
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import MESHES, PodRefreshConfig, get_smoke_config
        from repro.core.distributed import SyncConfig
        from repro.core.selfcheck import bitwise_equal, dynamic_k_selfcheck
        from repro.data import token_batches
        from repro.data.pipeline import ShardedBatcher, take
        from repro.launch.mesh import mesh_from_config
        from repro.launch.train import TrainConfig, train
        from repro.models import build_model

        STEPS, EVERY = {steps}, 2
        mesh = mesh_from_config(MESHES["smoke_2pod"])
        cfg = get_smoke_config("rwkv6-3b")
        model = build_model(cfg)
        batch_list = list(take(iter(ShardedBatcher(
            mesh, token_batches(cfg.vocab_size, 8, 32, seed=5),
            batch_axes=("pod", "data"), prefetch=0)), STEPS))
        sync = SyncConfig(ratio=0.02, strategy="hierarchical",
                          bucketed=True, wire="packed")

        # run A: live refresh every EVERY steps
        sched, diag_a = [], {{}}
        tc_a = TrainConfig(optimizer="memsgd", eta=0.3, sync=sync,
                           pod_refresh=PodRefreshConfig(every=EVERY))
        pa, ma, _, _, _ = train(
            model, mesh, tc_a, iter(batch_list), n_steps=STEPS,
            log_every=0, rng=jax.random.PRNGKey(0),
            refresh_cb=lambda i, ks: sched.append((i, list(ks))),
            diagnostics=diag_a)

        # run B: FRESH run replaying the recorded k schedule
        diag_b = {{}}
        tc_b = TrainConfig(optimizer="memsgd", eta=0.3, sync=sync)
        pb, mb, _, _, _ = train(
            model, mesh, tc_b, iter(batch_list), n_steps=STEPS,
            log_every=0, rng=jax.random.PRNGKey(0),
            pod_k_schedule=[(i, tuple(ks)) for i, ks in sched],
            diagnostics=diag_b)

        probe = dynamic_k_selfcheck(mesh)
        # the jit cache may gain ONE entry at step 1 as donated/committed
        # shardings settle (any run does that, refresh or not); entries
        # added after that are real recompiles and must be zero — in
        # particular at the refresh boundaries (steps 2 and 4)
        print(json.dumps({{
            "refreshes": len(sched),
            "k_schedule": sched,
            "initial_pod_ks": list(diag_a["initial_pod_ks"]),
            "step_cache_sizes": [diag_a["step_cache_sizes"],
                                 diag_b["step_cache_sizes"]],
            "zero_recompiles": (diag_a["steady_state_recompiles"] == 0
                                and diag_b["steady_state_recompiles"] == 0),
            "replay_bitwise": bool(bitwise_equal(pa, pb)
                                   and bitwise_equal(ma, mb)),
            "dynamic_matches_static": probe["dynamic_matches_static"],
            "conservation_max_err": probe["conservation_max_err"],
            "accounting_exact": probe["accounting_exact"]}}))
        """
    ).format(src=os.path.join(_ROOT, "src"), steps=steps)
    t0 = time.time()
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=1800,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    wall_us = (time.time() - t0) * 1e6
    smoke = {
        "plan": "rwkv6-3b-smoke", "mesh": "smoke_2pod", "steps": steps,
        "refresh_every": 2,
        "refreshes": rec["refreshes"],
        "k_schedule": rec["k_schedule"],
        "initial_pod_ks": rec["initial_pod_ks"],
        "step_cache_sizes": rec["step_cache_sizes"],
        "zero_recompiles": rec["zero_recompiles"],
        "replay_bitwise": rec["replay_bitwise"],
        "dynamic_matches_static": rec["dynamic_matches_static"],
        "conservation_max_err": rec["conservation_max_err"],
        "accounting_exact": rec["accounting_exact"],
    }
    _emit("refresh_smoke", wall_us / max(1, 2 * steps),
          f"refreshes={rec['refreshes']};"
          f"zero_recompiles={rec['zero_recompiles']};"
          f"replay_bitwise={rec['replay_bitwise']};"
          f"dynamic_matches_static={rec['dynamic_matches_static']}")

    payload = {"drift": drift, "smoke": smoke}
    _save("refresh", payload)
    with open(os.path.join(_ROOT, "BENCH_refresh.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)
    # acceptance claims: >= 2 refreshes, zero recompiles after step 1,
    # bitwise replay, dynamic == static; refresh-on holds the capture
    # band the frozen step-0 k drifts out of, at fewer effective bytes
    # than the padded gather
    assert smoke["refreshes"] >= 2, smoke
    assert smoke["zero_recompiles"], smoke
    assert smoke["replay_bitwise"], smoke
    assert smoke["dynamic_matches_static"], smoke
    assert smoke["conservation_max_err"] < 1e-5, smoke
    assert smoke["accounting_exact"], smoke
    assert drift["refresh_on"]["min_capture"] >= target - 0.1, drift
    assert drift["refresh_off"]["min_capture"] < target - 0.15, drift
    assert drift["capture_advantage"] > 0.1, drift
    assert drift["byte_ratio_padded_vs_effective"] > 1.0, drift
    return payload


def budget(full: bool = False):
    """Header-aware cross-pod repack transport + global byte-budget
    controller (repro.core.budget).

    (a) repack on the refresh drift synthetic (same generator/seed as
    ``refresh``): the k_max-padded pod-summary gather costs ~7.6x the
    live-k accounting; shipping each message through
    ``distributed.repack_transport`` must realize EXACTLY the live-k
    bytes (ratio 1.0, acceptance bound 1.2) at a bitwise-identical
    repadded buffer. (b) a two-bucket drift with mass migrating between
    buckets: the water-filling ``BudgetController`` re-spending a fixed
    global byte budget every refresh must capture more mass per
    cross-pod byte than the step-0 allocation frozen for the run, at
    never more than the budget. (c) a 2-pod rwkv6-3b smoke subprocess:
    ``repro.core.selfcheck.repack_selfcheck`` (R stage bitwise inert
    across overlap modes and a live-k switch, host transport round-trip
    + exact accounting) plus a short budget-driven train run — every
    refresh's allocation stays within ``SyncConfig.byte_budget`` with
    zero steady-state recompiles. Writes BENCH_budget.json."""
    import subprocess
    import textwrap

    import jax
    import jax.numpy as jnp

    from repro.core import buckets as bk
    from repro.core import encoding as enc
    from repro.core.budget import BudgetController
    from repro.core.distributed import (
        SyncConfig,
        autotune_pod_ratios,
        repack_transport,
    )
    from repro.kernels.topk_select import mask_live_k

    # -- (a) repack transport on the refresh drift synthetic ---------------
    T = 16 if full else 10
    every = 2
    n_data = 4
    rows, cols = 32, 512
    target = 0.9
    cfg = SyncConfig(ratio=0.02, strategy="hierarchical", pod_axis="pod",
                     bucketed=True, bucket_cols=cols, wire="packed",
                     pod_mass_target=target, pod_dynamic=True)
    plan = bk.make_plan(
        {"w": jax.ShapeDtypeStruct((rows * cols,), jnp.float32)},
        cols=cols, dense_below=cols,
    )
    k_row = cfg.k_for(cols)
    k_max = cfg.pod_k_max_for_bucket(0, cols, n_data)
    wspec = enc.WireSpec(rows, cols, k_max, "float32")
    rng = np.random.default_rng(7)
    perm = np.stack([rng.permutation(cols) for _ in range(rows)])
    signs = np.where(rng.random((rows, cols)) < 0.5, -1.0, 1.0)

    def u_shards(t, alpha0=1.6, alpha1=0.15):
        alpha = alpha0 - (alpha0 - alpha1) * t / max(1, T - 1)
        mag = (np.arange(1, cols + 1) ** (-alpha))[perm] * signs
        shards = np.stack([
            mag * (1.0 + 0.08 * rng.standard_normal((rows, cols)))
            for _ in range(n_data)
        ])
        return jnp.asarray(shards, jnp.float32)

    def realized_transport_bytes(bufs, k_live):
        """Ship the pod summary the way the boundary stage does: top-k
        at the static padded k_max, tail masked to the live k, packed
        with the live count in the header — then repack for the hop."""
        pm = bk.simulate_pod_mean(bufs, k_row)
        _, idx = jax.lax.top_k(jnp.abs(pm), k_max)
        vals = jnp.take_along_axis(pm, idx, axis=-1)
        vals, idx = mask_live_k(vals, idx.astype(jnp.int32), k_live)
        buf = enc.encode(wspec, vals, idx, live_n=k_live)
        repadded, nbytes = repack_transport(wspec, buf)
        roundtrip = np.array_equal(np.asarray(repadded), np.asarray(buf))
        return int(nbytes), bool(roundtrip)

    def tuned_k(bufs):
        r = autotune_pod_ratios(cfg, plan, [bufs], n_data=n_data,
                                k_caps=[k_max])[0]
        return int(round(r * cols))

    k_live = tuned_k(u_shards(0))
    realized, accounted, roundtrips = [], [], []
    for t in range(T):
        bufs = u_shards(t)
        if t > 0 and t % every == 0:
            k_live = tuned_k(bufs)
        nb, ok = realized_transport_bytes(bufs, k_live)
        realized.append(nb)
        accounted.append(
            enc.message_nbytes(rows, cols, k_live, "float32", "packed"))
        roundtrips.append(ok)
    padded = wspec.nbytes
    mean_realized = sum(realized) / len(realized)
    mean_accounted = sum(accounted) / len(accounted)
    byte_ratio = mean_realized / mean_accounted
    transport = {
        "steps": T, "refresh_every": every, "k_max": k_max,
        "padded_bytes": padded,
        "realized_bytes": realized, "accounted_bytes": accounted,
        "mean_realized_bytes": mean_realized,
        "mean_accounted_bytes": mean_accounted,
        "byte_ratio_realized_vs_accounted": byte_ratio,
        "padded_vs_realized": padded / mean_realized,
        "roundtrip_bitwise": all(roundtrips),
    }
    _emit("budget_transport", 0.0,
          f"realized/accounted={byte_ratio:.4f};"
          f"padded_vs_realized={padded / mean_realized:.2f};"
          f"roundtrip_bitwise={all(roundtrips)}")

    # -- (b) global budget vs frozen static-k at equal bytes ----------------
    # two buckets with OPPOSING drift: mass concentration migrates from
    # bucket 0 to bucket 1 over the run, so a fixed split goes stale
    plan2 = bk.make_plan(
        {"a": jax.ShapeDtypeStruct((rows * cols,), jnp.float32),
         "z": jax.ShapeDtypeStruct((rows * cols,), jnp.bfloat16)},
        cols=cols, dense_below=cols,
    )
    assert len(plan2.buckets) == 2, plan2
    k_caps = [cfg.pod_k_max_for_bucket(b, cols, n_data) for b in (0, 1)]
    ctl = BudgetController(cfg, plan2, n_data, k_caps=k_caps)

    def u2(t):
        return [u_shards(t, 1.6, 0.15), u_shards(t, 0.15, 1.6)]

    curves0 = ctl.measure(u2(0))
    floor = ctl.cross_bytes_of((1, 1))
    span = ctl.cross_bytes_of(tuple(c.k_cap for c in curves0)) - floor
    byte_budget = floor + span // 3
    ks_static = ctl.allocate_bytes(curves0, byte_budget)
    ks_ctl = ks_static
    cap_ctl, cap_static, ks_hist = [], [], []
    for t in range(T):
        curves = curves0 if t == 0 else ctl.measure(u2(t))
        if t > 0 and t % every == 0:
            ks_ctl = ctl.allocate_bytes(curves, byte_budget)

        def captured(ks):
            return sum(float(c.abs_capture[k - 1])
                       for c, k in zip(curves, ks))

        cap_ctl.append(captured(ks_ctl) / ctl.cross_bytes_of(ks_ctl))
        cap_static.append(captured(ks_static)
                          / ctl.cross_bytes_of(ks_static))
        ks_hist.append(list(ks_ctl))
    mean_adv = (sum(cap_ctl) / T) / (sum(cap_static) / T)
    final_adv = cap_ctl[-1] / cap_static[-1]
    alloc = {
        "byte_budget": byte_budget, "floor_bytes": floor,
        "k_caps": k_caps, "ks_static": list(ks_static),
        "ks_controller": ks_hist,
        "controller_bytes": ctl.cross_bytes_of(ks_hist[-1]),
        "capture_per_byte_controller": cap_ctl,
        "capture_per_byte_static": cap_static,
        "mean_advantage": mean_adv, "final_advantage": final_adv,
        "within_budget": all(
            ctl.cross_bytes_of(ks) <= byte_budget for ks in ks_hist),
    }
    _emit("budget_waterfill", 0.0,
          f"budget={byte_budget};mean_advantage={mean_adv:.3f};"
          f"final_advantage={final_adv:.3f};"
          f"ks={ks_hist[0]}->{ks_hist[-1]}")

    # -- (c) 2-pod rwkv6-3b smoke: bitwise + budget-driven refreshes -------
    steps = 5
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import MESHES, PodRefreshConfig, get_smoke_config
        from repro.core import buckets as bk
        from repro.core.budget import BudgetController
        from repro.core.distributed import SyncConfig
        from repro.core.selfcheck import repack_selfcheck
        from repro.data import token_batches
        from repro.data.pipeline import ShardedBatcher, take
        from repro.launch.mesh import mesh_from_config
        from repro.launch.train import TrainConfig, train
        from repro.models import build_model

        STEPS = {steps}
        mesh = mesh_from_config(MESHES["smoke_2pod"])
        rec = repack_selfcheck(mesh)

        cfg = get_smoke_config("rwkv6-3b")
        model = build_model(cfg)
        plan = bk.make_plan(model.param_shapes())
        base = SyncConfig(ratio=0.02, strategy="hierarchical",
                          bucketed=True, wire="packed")
        ctl = BudgetController(base, plan, n_data=4)
        floor = ctl.cross_bytes_of(tuple(1 for _ in plan.buckets))
        budget = int(floor * 1.2)
        sync = SyncConfig(ratio=0.02, strategy="hierarchical",
                          bucketed=True, wire="packed",
                          byte_budget=budget)
        sched, diag = [], {{}}
        tc = TrainConfig(optimizer="memsgd", eta=0.3, sync=sync,
                         pod_refresh=PodRefreshConfig(every=2))
        batch_list = list(take(iter(ShardedBatcher(
            mesh, token_batches(cfg.vocab_size, 8, 32, seed=9),
            batch_axes=("pod", "data"), prefetch=0)), STEPS))
        train(model, mesh, tc, iter(batch_list), n_steps=STEPS,
              log_every=0, rng=jax.random.PRNGKey(0),
              refresh_cb=lambda i, ks: sched.append((i, list(ks))),
              diagnostics=diag)
        within = all(ctl.cross_bytes_of(ks) <= budget for _, ks in sched)
        rec.update({{
            "floor_bytes": floor, "byte_budget": budget,
            "refreshes": len(sched), "k_schedule": sched,
            "refresh_within_budget": bool(within),
            "zero_recompiles": diag["steady_state_recompiles"] == 0}})
        print(json.dumps(rec))
        """
    ).format(src=os.path.join(_ROOT, "src"), steps=steps)
    t0 = time.time()
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=3600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    wall_us = (time.time() - t0) * 1e6
    smoke = {
        "plan": "rwkv6-3b-smoke", "mesh": "smoke_2pod", "steps": steps,
        "repack_bitwise": rec["repack_bitwise"],
        "transport_roundtrip_bitwise": rec["transport_roundtrip_bitwise"],
        "transport_accounting_exact": rec["transport_accounting_exact"],
        "padded_vs_live_bytes": rec["padded_vs_live_bytes"],
        "floor_bytes": rec["floor_bytes"],
        "byte_budget": rec["byte_budget"],
        "refreshes": rec["refreshes"],
        "k_schedule": rec["k_schedule"],
        "refresh_within_budget": rec["refresh_within_budget"],
        "zero_recompiles": rec["zero_recompiles"],
    }
    _emit("budget_smoke", wall_us / max(1, steps),
          f"repack_bitwise={rec['repack_bitwise']};"
          f"accounting_exact={rec['transport_accounting_exact']};"
          f"refreshes={rec['refreshes']};"
          f"within_budget={rec['refresh_within_budget']};"
          f"zero_recompiles={rec['zero_recompiles']}")

    payload = {"transport": transport, "allocation": alloc, "smoke": smoke}
    _save("budget", payload)
    with open(os.path.join(_ROOT, "BENCH_budget.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)
    # acceptance: realized cross-pod bytes within 1.2x of the live-k
    # accounting (exactly 1.0 here) vs the ~7.6x padded gather; the
    # budget allocator never overspends and beats the frozen split on
    # capture-per-byte; the smoke run is bitwise with exact accounting
    assert transport["byte_ratio_realized_vs_accounted"] <= 1.2, transport
    assert transport["padded_vs_realized"] > 2.0, transport
    assert transport["roundtrip_bitwise"], transport
    assert alloc["within_budget"], alloc
    assert alloc["mean_advantage"] > 1.0, alloc
    assert smoke["repack_bitwise"], smoke
    assert smoke["transport_roundtrip_bitwise"], smoke
    assert smoke["transport_accounting_exact"], smoke
    assert smoke["refreshes"] >= 1, smoke
    assert smoke["refresh_within_budget"], smoke
    assert smoke["zero_recompiles"], smoke
    return payload


def remark23_ultra(full: bool = False):
    """Remark 2.3 ultra-sparsification: transmit on average LESS THAN ONE
    coordinate per step (k < 1) and still converge (with memory)."""
    import jax
    import jax.numpy as jnp

    from repro.core import compression as C
    from repro.core.memsgd import memsgd_flat
    from repro.core.theory import theoretical_shift, theorem_stepsize
    from repro.optim import apply_updates

    d = 64
    target = jnp.ones(d)
    rows = {}
    for k in (0.5, 1.0, 4.0):
        a = theoretical_shift(d, max(k, 0.5), alpha=5.0)
        tx = memsgd_flat(C.random_coordinate(k), theorem_stepsize(1.0, a), d,
                         seed=1)
        w = jnp.zeros(d)
        s = tx.init(w)
        T = 30_000 if full else 8_000
        t0 = time.time()
        for _ in range(T):
            u, s = tx.update(w - target, s)
            w = apply_updates(w, u)
        err = float(jnp.linalg.norm(w - target))
        rows[f"k{k}"] = err
        _emit(f"ultra_k{k}", (time.time() - t0) / T * 1e6,
              f"err={err:.4f};avg_coords_per_step={k}")
    _save("remark23_ultra", rows)
    return rows


def overlap(full: bool = False):
    """Double-buffered bucket pipeline (repro.core.pipeline).

    Headline: the planner's depth-1 (overlap off) vs depth-2 (overlap
    on) schedule driven by the HOST executor over an ``EmulatedLink``
    whose latency is calibrated to the measured per-bucket compute —
    real top-k select + packed wire encode/decode stages, and the
    depth-2 run must land strictly under depth 1 at bitwise-identical
    outputs. (This container is a 1-core CPU with no async collectives,
    so the in-jit barrier schedule cannot overlap HERE — on GPU/TPU the
    same schedule overlaps for real via the async-collective flags
    ``utils.platform.setup_platform`` sets.)

    Smoke: a 2-pod rwkv6-3b subprocess asserting ``overlap=True`` ==
    ``overlap=False`` BITWISE on params + memory for all three sync
    paths — flat, hierarchical, pod-dynamic (with a live mid-run pod-k
    refresh) — plus the synthetic ``overlap_selfcheck`` probe. Writes
    BENCH_overlap.json at the repo root."""
    import subprocess
    import textwrap

    import jax
    import jax.numpy as jnp

    from repro.core import encoding as enc
    from repro.core.distributed import _row_scatter, _row_topk
    from repro.core.pipeline import (
        COMM,
        COMPUTE,
        EmulatedLink,
        run_host_pipeline,
    )

    # -- (a) headline: host pipeline over an emulated wire -----------------
    n_buckets = 8
    R, C, k = (128, 2048, 64) if full else (64, 2048, 64)
    wspec = enc.WireSpec(rows=R, cols=C, k=k, value_dtype="float32")
    bufs = [jax.random.normal(jax.random.PRNGKey(b), (R, C), jnp.float32)
            for b in range(n_buckets)]
    jax.block_until_ready(bufs)

    @jax.jit
    def encode(u):
        vals, idx = _row_topk(u, k)
        return enc.encode(wspec, vals, idx)

    @jax.jit
    def decode_apply(buf):
        gv, gi = enc.decode(wspec, buf)
        return _row_scatter((R, C), gv, gi, jnp.float32)

    wire0 = jax.block_until_ready(encode(bufs[0]))  # compile
    jax.block_until_ready(decode_apply(wire0))

    def t_of(fn, arg, n=5):
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn(arg))
        return (time.time() - t0) / n

    t_enc = t_of(encode, bufs[0])
    t_dec = t_of(decode_apply, wire0)
    # comm ~= compute per bucket: the regime double buffering targets
    # (a faster wire hides trivially, a slower wire bounds any schedule)
    latency = t_enc + t_dec

    kinds = [(COMPUTE, COMM, COMPUTE)] * n_buckets

    def run(depth):
        link = EmulatedLink(latency_s=latency)
        stage_lists = [
            [lambda u: jax.block_until_ready(encode(u)),
             lambda w, link=link: link.transfer(w, int(wspec.nbytes)),
             lambda w: jax.block_until_ready(decode_apply(w))]
            for _ in range(n_buckets)
        ]
        t0 = time.time()
        outs = run_host_pipeline(list(bufs), stage_lists, kinds, depth)
        return outs, (time.time() - t0) * 1e3

    out_seq, _ = run(1)  # warm
    out_ovl, _ = run(2)
    bit = all(
        np.array_equal(np.asarray(a).view(np.uint8),
                       np.asarray(b).view(np.uint8))
        for a, b in zip(out_seq, out_ovl)
    )
    seq_ms = min(run(1)[1] for _ in range(3))
    overlap_ms = min(run(2)[1] for _ in range(3))
    speedup = seq_ms / overlap_ms
    _emit("overlap_pipeline", seq_ms * 1e3 / n_buckets,
          f"seq_ms={seq_ms:.1f};overlap_ms={overlap_ms:.1f};"
          f"x{speedup:.2f};bitwise={bit}")

    # -- (b) smoke: all three sync paths, overlap on == off bitwise --------
    steps = 3
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json, dataclasses
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import MESHES, PodRefreshConfig, get_smoke_config
        from repro.core.distributed import SyncConfig
        from repro.core.selfcheck import bitwise_equal, overlap_selfcheck
        from repro.data import token_batches
        from repro.data.pipeline import ShardedBatcher, take
        from repro.launch.mesh import mesh_from_config
        from repro.launch.train import TrainConfig, train
        from repro.models import build_model

        STEPS = {steps}
        mesh = mesh_from_config(MESHES["smoke_2pod"])
        cfg = get_smoke_config("rwkv6-3b")
        model = build_model(cfg)
        batch_list = list(take(iter(ShardedBatcher(
            mesh, token_batches(cfg.vocab_size, 8, 32, seed=7),
            batch_axes=("pod", "data"), prefetch=0)), STEPS))

        def run(sync, overlap, pod_refresh=None, sched_out=None,
                replay=None):
            tc = TrainConfig(
                optimizer="memsgd", eta=0.3,
                sync=dataclasses.replace(sync, overlap=overlap),
                pod_refresh=pod_refresh)
            kw = {{}}
            if sched_out is not None:
                kw["refresh_cb"] = (
                    lambda i, ks: sched_out.append((i, list(ks))))
            if replay is not None:
                kw["pod_k_schedule"] = replay
            p, m, _, _, _ = train(
                model, mesh, tc, iter(batch_list), n_steps=STEPS,
                log_every=0, rng=jax.random.PRNGKey(0), **kw)
            return p, m

        flat = SyncConfig(ratio=0.02, strategy="sparse_allgather",
                          bucketed=True, wire="packed")
        hier = SyncConfig(ratio=0.02, strategy="hierarchical",
                          bucketed=True, wire="packed")
        res = {{}}
        res["flat_bitwise"] = bool(
            bitwise_equal(run(flat, False), run(flat, True)))
        res["hierarchical_bitwise"] = bool(
            bitwise_equal(run(hier, False), run(hier, True)))
        # pod-dynamic with a LIVE mid-run refresh (every=2 -> one
        # refresh inside STEPS=3); the on-run replays the off-run's
        # recorded k schedule so both trace the identical live ks
        sched = []
        off = run(hier, False, pod_refresh=PodRefreshConfig(every=2),
                  sched_out=sched)
        on = run(hier, True,
                 replay=[(i, tuple(ks)) for i, ks in sched])
        res["pod_dynamic_bitwise"] = bool(bitwise_equal(off, on))
        res["refreshes"] = len(sched)

        probe = overlap_selfcheck(mesh)
        res["probe_bitwise"] = probe["bitwise_all"]
        print(json.dumps(res))
        """
    ).format(src=os.path.join(_ROOT, "src"), steps=steps)
    t0 = time.time()
    # six full-model jit compiles on a 1-core container: generous budget
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=3600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    wall_us = (time.time() - t0) * 1e6

    bitwise_all = bool(
        bit and rec["flat_bitwise"] and rec["hierarchical_bitwise"]
        and rec["pod_dynamic_bitwise"] and rec["probe_bitwise"]
    )
    payload = {
        "pipeline": {
            "n_buckets": n_buckets, "shape": [R, C], "k": k,
            "wire_nbytes": wspec.nbytes,
            "encode_ms": t_enc * 1e3, "decode_ms": t_dec * 1e3,
            "link_latency_ms": latency * 1e3,
            "seq_ms": seq_ms, "overlap_ms": overlap_ms,
            "speedup": speedup, "bitwise_equal": bool(bit),
        },
        "smoke": {
            "plan": "rwkv6-3b-smoke", "mesh": "smoke_2pod",
            "steps": steps,
            "flat_bitwise": rec["flat_bitwise"],
            "hierarchical_bitwise": rec["hierarchical_bitwise"],
            "pod_dynamic_bitwise": rec["pod_dynamic_bitwise"],
            "refreshes": rec["refreshes"],
            "probe_bitwise": rec["probe_bitwise"],
        },
        "bitwise_identical": bitwise_all,
    }
    _emit("overlap_smoke", wall_us / max(1, 8 * steps),
          f"flat={rec['flat_bitwise']};hier={rec['hierarchical_bitwise']};"
          f"dyn={rec['pod_dynamic_bitwise']};refreshes={rec['refreshes']};"
          f"probe={rec['probe_bitwise']}")
    _save("overlap", payload)
    with open(os.path.join(_ROOT, "BENCH_overlap.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)
    # acceptance: overlap-on strictly faster at fixed bitwise results,
    # and every smoke path bit-identical (with >= 1 live refresh seen)
    assert speedup > 1.0, payload["pipeline"]
    assert bitwise_all, payload
    assert rec["refreshes"] >= 1, rec
    return payload


def local(full: bool = False):
    """Qsparse-local-SGD: H local steps x s-level quantization x one
    shared error memory behind the grouped SyncConfig API.

    (a) accounting: the amortized cross-worker bytes/step of the
    quantized packed wire scale EXACTLY 1/H, and the quantized value
    section beats the exact f32 tier per message. (b) an 8-device
    2-pod subprocess: ``repro.core.selfcheck.local_quant_selfcheck``
    (H=1 accumulator path bitwise-identical to the per-step sync on
    all three strategies, exact quantized mass conservation, packed ==
    unpacked bitwise, realized == accounted bytes, exact 1/H
    amortization) plus an rwkv6-3b smoke H-sweep (H in {1, 2, 4, 8},
    quant=15): every run must improve on the init loss with zero
    steady-state recompiles while the accounted bytes/step drop 1/H.
    Writes BENCH_local.json."""
    import subprocess
    import textwrap

    import jax
    import jax.numpy as jnp

    from repro.core import buckets as bk
    from repro.core import theory
    from repro.core.distributed import (
        SyncConfig,
        WireConfig,
        amortized_bytes_per_step,
        bucketed_message_bytes,
    )
    from repro.core.encoding import dense_bits

    # -- (a) amortized byte accounting --------------------------------------
    cols, ratio, s = 512, 0.02, 15
    plan = bk.make_plan(
        {"w": jax.ShapeDtypeStruct((64 * cols,), jnp.float32)},
        cols=cols, dense_below=cols,
    )
    d = sum(sp.rows * sp.cols for sp in plan.buckets)
    exact = SyncConfig(ratio=ratio, bucketed=True, bucket_cols=cols,
                       wire=WireConfig(wire="packed"))
    quant = exact.with_wire(quant=s)
    exact_b = bucketed_message_bytes(exact, plan)
    quant_b = bucketed_message_bytes(quant, plan)
    hs = (1, 2, 4, 8)
    amortized = {h: amortized_bytes_per_step(
        SyncConfig.preset("qsparse_local", ratio=ratio, bucket_cols=cols,
                          local_steps=h), plan) for h in hs}
    scaling_exact = all(amortized[h] == quant_b / h for h in hs)
    k = exact.k_for(cols)
    accounting = {
        "d": d, "k_per_row": k, "quant_levels": s,
        "exact_bytes_per_sync": exact_b,
        "quant_bytes_per_sync": quant_b,
        "quant_value_compression": exact_b / quant_b,
        "dense_bytes": dense_bits(d) / 8,
        "amortized_bytes_per_step": {str(h): amortized[h] for h in hs},
        "scaling_exact_one_over_h": scaling_exact,
        "composed_contraction": theory.composed_contraction(cols, k, s),
        "residual_factors": {str(h): theory.local_steps_residual_factor(h)
                             for h in hs},
    }
    _emit("local_accounting", 0.0,
          f"quant_compression={exact_b / quant_b:.2f};"
          f"amortized_H8={amortized[8]:.0f}B;"
          f"scaling_exact={scaling_exact}")

    # -- (b) 2-pod selfcheck + rwkv6-3b H-sweep smoke -----------------------
    steps = 48 if full else 24
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        from repro.configs import MESHES, get_smoke_config
        from repro.core import buckets as bk
        from repro.core.distributed import (SyncConfig,
                                            amortized_bytes_per_step)
        from repro.core.selfcheck import local_quant_selfcheck
        from repro.data import token_batches
        from repro.data.pipeline import ShardedBatcher, take
        from repro.launch.mesh import mesh_from_config
        from repro.launch.train import TrainConfig, train
        from repro.models import build_model

        STEPS = {steps}
        mesh = mesh_from_config(MESHES["smoke_2pod"])
        rec = local_quant_selfcheck(mesh)

        cfg = get_smoke_config("rwkv6-3b")
        model = build_model(cfg)
        plan = bk.make_plan(model.param_shapes())
        batch_list = list(take(iter(ShardedBatcher(
            mesh, token_batches(cfg.vocab_size, 8, 32, seed=9),
            batch_axes=("pod", "data"), prefetch=0)), STEPS))
        runs = {{}}
        for h in (1, 2, 4, 8):
            sync = SyncConfig.preset("qsparse_local", ratio=0.02,
                                     local_steps=h)
            diag = {{}}
            tc = TrainConfig(optimizer="memsgd", eta=0.1, sync=sync)
            *_, hist = train(
                model, mesh, tc, iter(batch_list), n_steps=STEPS,
                log_every=1, rng=jax.random.PRNGKey(0),
                diagnostics=diag)
            runs[str(h)] = {{
                "init_loss": hist[0][1],
                "final_loss": hist[-1][1],
                "bytes_per_step": amortized_bytes_per_step(sync, plan),
                "steady_state_recompiles":
                    diag["steady_state_recompiles"],
            }}
        rec.update({{"runs": runs}})
        print(json.dumps(rec))
        """
    ).format(src=os.path.join(_ROOT, "src"), steps=steps)
    t0 = time.time()
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=3600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    wall_us = (time.time() - t0) * 1e6
    runs = rec["runs"]
    b1 = runs["1"]["bytes_per_step"]
    smoke = {
        "plan": "rwkv6-3b-smoke", "mesh": "smoke_2pod", "steps": steps,
        "quant_levels": s,
        "h1_accum_bitwise": rec["h1_accum_bitwise"],
        "quant_conservation_max_err": rec["quant_conservation_max_err"],
        "quant_bit_identical": rec["quant_bit_identical"],
        "quant_accounting_exact": rec["quant_accounting_exact"],
        "amortized_ratio_exact": rec["amortized_ratio_exact"],
        "runs": runs,
        "bytes_scaling_exact": all(
            runs[str(h)]["bytes_per_step"] == b1 / h for h in hs),
        "all_converge": all(
            runs[str(h)]["final_loss"] < runs[str(h)]["init_loss"]
            for h in hs),
        "zero_recompiles": all(
            runs[str(h)]["steady_state_recompiles"] == 0 for h in hs),
    }
    _emit("local_smoke", wall_us / max(1, 4 * steps),
          f"h1_bitwise={rec['h1_accum_bitwise']};"
          f"bytes/step H1={b1:.0f} H8={runs['8']['bytes_per_step']:.0f};"
          f"all_converge={smoke['all_converge']};"
          f"zero_recompiles={smoke['zero_recompiles']}")

    payload = {"accounting": accounting, "smoke": smoke}
    _save("local", payload)
    with open(os.path.join(_ROOT, "BENCH_local.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)
    # acceptance: H=1 accumulator path bitwise, quantized conservation
    # exact, amortized bytes scale exactly 1/H (accounting AND the real
    # rwkv6-3b plan), every H-sweep run converges with zero recompiles
    assert accounting["scaling_exact_one_over_h"], accounting
    assert accounting["quant_value_compression"] > 1.0, accounting
    assert smoke["h1_accum_bitwise"], smoke
    assert smoke["quant_conservation_max_err"] < 1e-5, smoke
    assert smoke["quant_bit_identical"], smoke
    assert smoke["quant_accounting_exact"], smoke
    assert smoke["amortized_ratio_exact"], smoke
    assert smoke["bytes_scaling_exact"], smoke
    assert smoke["all_converge"], smoke
    assert smoke["zero_recompiles"], smoke
    return payload


# the config-zoo scenario matrix: architecture family coverage (MoE is
# the top-k + ragged-bucket stress case) x the shipped sync presets
MATRIX_ARCHS = (
    "rwkv6-3b",
    "qwen3-moe-30b-a3b",
    "granite-moe-3b-a800m",
    "recurrentgemma-9b",
    "internvl2-26b",
    "musicgen-medium",
)
MATRIX_PRESETS = ("topk", "pod_budgeted", "qsparse_local")


def matrix(full: bool = False, archs=None):
    """Scenario convergence matrix: config-zoo smoke plans x sync
    presets, each trained for a few dozen steps on the 2-pod smoke mesh
    with a ``Telemetry`` sink watching every step. Per scenario we
    record convergence health (no loss spikes, no NaN/inf, rolling loss
    median decreasing) and the exact per-step wire bytes vs the dense
    all-reduce baseline (compression win). PR CI runs ``--archs
    rwkv6-3b`` only; the weekly schedule sweeps the full zoo.
    """
    import subprocess
    import textwrap

    arch_list = list(archs) if archs else list(MATRIX_ARCHS)
    bad = [a for a in arch_list if a not in MATRIX_ARCHS]
    assert not bad, f"unknown matrix arch(s) {bad}; options: {MATRIX_ARCHS}"
    steps = 48 if full else 24
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json, time
        sys.path.insert(0, {src!r})
        import jax
        from repro.configs import MESHES, get_smoke_config
        from repro.core import buckets as bk
        from repro.core.distributed import SyncConfig
        from repro.data import token_batches
        from repro.data.pipeline import ShardedBatcher, take
        from repro.launch.mesh import mesh_from_config
        from repro.launch.train import TrainConfig, train
        from repro.models import build_model
        from repro.utils.telemetry import NonFiniteLossError, Telemetry

        STEPS = {steps}
        ARCHS = {archs!r}
        PRESETS = {presets!r}
        mesh = mesh_from_config(MESHES["smoke_2pod"])
        scenarios = {{}}
        for arch in ARCHS:
            cfg = get_smoke_config(arch)
            model = build_model(cfg)
            plan = bk.make_plan(model.param_shapes())
            # dense data-parallel baseline: the all-reduce moves the
            # full f32 buffers every step
            dense_bytes = 4 * sum(s.rows * s.cols for s in plan.buckets)
            batch_list = list(take(iter(ShardedBatcher(
                mesh, token_batches(cfg.vocab_size, 8, 32, seed=11),
                batch_axes=("pod", "data"), prefetch=0)), STEPS))
            for preset in PRESETS:
                sync = SyncConfig.preset(preset, ratio=0.02)
                tc = TrainConfig(optimizer="memsgd", eta=0.3, sync=sync)
                tel = Telemetry()
                t0 = time.time()
                try:
                    train(model, mesh, tc, iter(batch_list),
                          n_steps=STEPS, log_every=0,
                          rng=jax.random.PRNGKey(0), telemetry=tel)
                except NonFiniteLossError:
                    pass  # recorded in the sink; healthy=False below
                s = tel.summary()
                bps = s["bytes_per_step"] or {{}}
                total = bps.get("total")
                comp = (dense_bytes / total) if total else None
                scenarios[arch + "/" + preset] = {{
                    "arch": arch, "preset": preset,
                    "healthy": (not s["nonfinite"]) and s["spikes"] == 0,
                    "median_decreased": s["median_decreased"],
                    "nonfinite": s["nonfinite"],
                    "spikes": s["spikes"],
                    "loss_first_median": s["loss_first_median"],
                    "loss_last_median": s["loss_last_median"],
                    "stop_reason": s["stop_reason"],
                    "bytes_per_step": bps,
                    "dense_bytes_per_step": dense_bytes,
                    "compression": comp,
                    "compression_win": bool(comp and comp > 1.0),
                    "wall_s": time.time() - t0,
                }}
        print(json.dumps({{"scenarios": scenarios}}))
        """
    ).format(src=os.path.join(_ROOT, "src"), steps=steps,
             archs=arch_list, presets=list(MATRIX_PRESETS))
    t0 = time.time()
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=7200,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    scenarios = json.loads(out.stdout.strip().splitlines()[-1])["scenarios"]
    wall_us = (time.time() - t0) * 1e6
    n_ok = sum(1 for s in scenarios.values()
               if s["healthy"] and s["median_decreased"])
    _emit("matrix", wall_us / max(1, len(scenarios) * steps),
          f"scenarios={len(scenarios)};healthy_converging={n_ok};"
          f"archs={len(arch_list)};presets={len(MATRIX_PRESETS)}")
    payload = {
        "plan": "config-zoo-smoke", "mesh": "smoke_2pod", "steps": steps,
        "archs": arch_list, "presets": list(MATRIX_PRESETS),
        "scenarios": scenarios,
    }
    _save("matrix", payload)
    with open(os.path.join(_ROOT, "BENCH_matrix.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)
    # acceptance: every scenario trains healthily (no spikes, no
    # NaN/inf, rolling loss median strictly decreasing) and every
    # sparse preset beats the dense wire
    unhealthy = {k: s["stop_reason"] or f"spikes={s['spikes']}"
                 for k, s in scenarios.items() if not s["healthy"]}
    assert not unhealthy, unhealthy
    stalled = [k for k, s in scenarios.items() if not s["median_decreased"]]
    assert not stalled, f"loss median not decreasing: {stalled}"
    no_win = [k for k, s in scenarios.items() if not s["compression_win"]]
    assert not no_win, f"no compression win vs dense: {no_win}"
    return payload


BENCHES = {
    "fig2_convergence": fig2_convergence,
    "fig3_qsgd": fig3_qsgd,
    "fig4_multicore": fig4_multicore,
    "table_comm": table_comm,
    "kernel_topk": kernel_topk,
    "wire_codec": wire_codec,
    "fanout": fanout,
    "hierarchy": hierarchy,
    "refresh": refresh,
    "overlap": overlap,
    "budget": budget,
    "local": local,
    "remark23_ultra": remark23_ultra,
    "matrix": matrix,
}

# benches whose BENCH_*.json payload check_regression.py gates — the CI
# shard matrix runs exactly these (``--list --tracked --json``), so a
# bench joins CI by appearing here and in check_regression.CHECKS
TRACKED = ("kernel_topk", "wire_codec", "fanout", "hierarchy", "refresh",
           "overlap", "budget", "local", "matrix")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*", choices=[[], *BENCHES],
                    help="benchmark names (default: all)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names (same as the "
                         "positional form)")
    ap.add_argument("--list", action="store_true",
                    help="print the registered benchmark names and exit")
    ap.add_argument("--tracked", action="store_true",
                    help="with --list, restrict to the benches whose "
                         "payload the regression gate tracks")
    ap.add_argument("--json", action="store_true",
                    help="with --list, emit a JSON array (the CI shard "
                         "matrix reads this — one source of truth)")
    ap.add_argument("--archs", default=None,
                    help="matrix bench only: comma-separated subset of "
                         f"the config-zoo archs {MATRIX_ARCHS}")
    args = ap.parse_args()
    if args.list:
        listed = list(TRACKED) if args.tracked else list(BENCHES)
        print(json.dumps(listed) if args.json else "\n".join(listed))
        return
    if np is None:
        ap.error("numpy is required to RUN benches (only --list works "
                 "without it) — pip install numpy / the dev requirements")
    names = list(args.names)
    if args.only:
        names += args.only.split(",")
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; options: {sorted(BENCHES)}")
    names = names or list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        kwargs = {"full": args.full}
        if name == "matrix" and args.archs:
            kwargs["archs"] = args.archs.split(",")
        BENCHES[name](**kwargs)


if __name__ == "__main__":
    main()
