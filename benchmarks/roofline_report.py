"""Regenerate the EXPERIMENTS.md roofline table from the dry-run JSONs.

Run:  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, tag: str = "", include_opt: bool = False) -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}*.json"))):
        if "_opt" in os.path.basename(path) and not include_opt:
            continue  # perf-iteration artifacts (§Perf), not baselines
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh:
            continue
        rows.append(rec)
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 99))
    return rows


def fmt_md(rows: list) -> str:
    out = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant |"
        " useful | peak GiB/dev | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        peak = (r.get("peak_memory_bytes") or 0) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {peak:.1f} | {'yes' if peak <= 16 else 'NO'} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load(args.mesh)
    print(fmt_md(rows))
    print(f"\n{len(rows)} rows")


if __name__ == "__main__":
    main()
