"""Bench regression gate: fresh BENCH_*.json vs committed baselines.

CI stashes the committed baselines, re-runs the benches (one parallel
shard per registered bench — ``benchmarks/run.py --list`` is the shard
matrix's source of truth; each shard overwrites its repo-root
``BENCH_*.json`` and uploads it as an artifact), then a downstream gate
job downloads every shard payload and runs this checker ONCE. A run
that produces only some fresh payloads (a PR bench shard) restricts the
gate with ``--only <stems>``. Alongside the
pass/fail verdict it emits a markdown comparison table (baseline vs
fresh per tracked metric) to ``$GITHUB_STEP_SUMMARY`` and to
``--summary-file`` for artifact upload. A check FAILS when:

* throughput regresses: the wire codec's raw encode/decode ``*_us``
  timings are gated at ``--max-slowdown`` (default 1.15 — a >15% drop
  fails on a like-for-like machine; CI passes a wider budget because
  runner wall-clock is not comparable to the committed baseline's
  machine and even same-machine runs swing ~30% — the raw-us gate is a
  coarse net for order-of-magnitude regressions such as losing the
  jit). The kernel benches are gated on their MACHINE-NORMALIZED
  speedups (single-pass vs the k-loop oracle measured in the same run)
  at ``--kernel-retention`` (default 0.5: fail when the speedup
  halves), sized to the ~40% run-to-run variance of interpret-mode
  Pallas timings — a real regression (the single-pass kernel losing
  its edge over the loop) blows through 0.5 immediately;
* a wire byte ratio regresses: packed-vs-unpacked, fan-out-vs-dense,
  snapshot-vs-dense, or the two-level sync's cross-pod reduction
  shrinks below the baseline (deterministic layouts: compared with
  0.1% float slack, no timing noise);
* a correctness bit recorded in the payload flipped
  (``bitwise_equal``, ``roundtrip_exact``, snapshot ``exact``);
* a tracked key present in the baseline disappears from the fresh
  payload (a renamed metric must not silently disable its gate);
* a scenario in the convergence matrix (``BENCH_matrix.json``) goes
  unhealthy — loss spike or NaN/inf, rolling loss median no longer
  decreasing, a declared arch x preset cell missing or corrupt, the
  compression win vs the dense wire lost or regressed.

Baselines that do not exist yet (a bench added in the same PR) are
skipped with a warning so the gate never blocks its own introduction.

Usage:
    python benchmarks/check_regression.py --baseline-dir /tmp/bench-baseline
        [--fresh-dir .] [--max-slowdown 1.15]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

RATIO_SLACK = 0.999  # deterministic byte ratios, float-serialization slack


def _is_num(v) -> bool:
    """True for real JSON numbers (bool is an int subclass — exclude)."""
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _missing(fresh: dict, base: dict, key: str, label: str) -> List[str]:
    """A key the baseline tracks must exist in the fresh payload —
    renaming a metric must not silently disable its gate."""
    if key in base and key not in fresh:
        return [f"{label}: tracked key {key} missing from fresh payload"]
    return []


def _slower(fresh: dict, base: dict, key: str, max_slowdown: float,
            label: str) -> List[str]:
    if key not in base:
        return []
    if key not in fresh:
        return _missing(fresh, base, key, label)
    if fresh[key] > base[key] * max_slowdown:
        return [
            f"{label}: {key} {fresh[key]:.1f}us vs baseline "
            f"{base[key]:.1f}us (> x{max_slowdown:.2f} slowdown)"
        ]
    return []


def _ratio_regressed(fresh: dict, base: dict, key: str, label: str,
                     slack: float = RATIO_SLACK) -> List[str]:
    if key not in base:
        return []
    if key not in fresh:
        return _missing(fresh, base, key, label)
    if fresh[key] < base[key] * slack:
        return [
            f"{label}: {key} {fresh[key]:.3f} regressed vs baseline "
            f"{base[key]:.3f}"
        ]
    return []


def _flag_off(fresh: dict, base: dict, key: str, label: str) -> List[str]:
    if key not in fresh:
        return _missing(fresh, base, key, label)
    if not fresh[key]:
        return [f"{label}: correctness flag {key} is no longer true"]
    return []


def _fused_speedup(payload: dict) -> dict:
    """Derive the fused kernel's loop-vs-single-pass speedup (same-run
    normalized, like the payload's own ``speedup`` field)."""
    if "fused_loop_us" in payload and "fused_singlepass_us" in payload:
        return {"fused_speedup": payload["fused_loop_us"]
                / payload["fused_singlepass_us"]}
    return {}


def check_topk(base: dict, fresh: dict, max_slowdown: float,
               kernel_retention: float = 0.5) -> List[str]:
    errs = _flag_off(fresh, base, "bitwise_equal", "kernel_topk")
    # machine-normalized throughput: the single-pass kernels must retain
    # their same-run speedup over the k-loop oracle (threshold sized to
    # the ~40% interpret-mode variance — see module docstring)
    errs += _ratio_regressed(fresh, base, "speedup", "kernel_topk",
                             slack=kernel_retention)
    errs += _ratio_regressed(
        dict(fresh, **_fused_speedup(fresh)),
        dict(base, **_fused_speedup(base)),
        "fused_speedup", "kernel_topk", slack=kernel_retention,
    )
    # the backend cutover table must keep method="auto" on the faster
    # side of its own sweep (measured in the same run — machine-local)
    errs += _flag_off(fresh.get("cutover", {}), base.get("cutover", {}),
                      "auto_matches_faster", "kernel_topk[cutover]")
    return errs


def check_wire(base: dict, fresh: dict, max_slowdown: float,
               kernel_retention: float = 0.5) -> List[str]:
    errs: List[str] = []
    for vd in ("float32", "bfloat16"):
        b, f = base.get(vd, {}), fresh.get(vd, {})
        label = f"wire_codec[{vd}]"
        errs += _flag_off(f, b, "roundtrip_exact", label)
        errs += _ratio_regressed(f, b, "ratio_vs_unpacked", label)
        errs += _ratio_regressed(f, b, "ratio_vs_dense", label)
        for key in ("encode_us", "decode_us"):
            errs += _slower(f, b, key, max_slowdown, label)
    return errs


def check_fanout(base: dict, fresh: dict, max_slowdown: float,
                 kernel_retention: float = 0.5) -> List[str]:
    errs: List[str] = []
    for n, b in base.get("per_N", {}).items():
        f = fresh.get("per_N", {}).get(n, {})
        label = f"fanout[N={n}]"
        if not f:
            errs.append(f"{label}: missing from fresh run")
            continue
        errs += _ratio_regressed(f, b, "ratio_vs_dense", label)
        errs += _ratio_regressed(f, b, "publisher_ratio_vs_dense", label)
    bs, fs = base.get("snapshot", {}), fresh.get("snapshot", {})
    errs += _ratio_regressed(fs, bs, "ratio_vs_dense", "fanout[snapshot]")
    errs += _flag_off(fs, bs, "exact", "fanout[snapshot]")
    return errs


def check_hierarchy(base: dict, fresh: dict, max_slowdown: float,
                    kernel_retention: float = 0.5) -> List[str]:
    errs = _flag_off(fresh, base, "bit_identical", "hierarchy")
    errs += _flag_off(fresh, base, "conservation_ok", "hierarchy")
    errs += _flag_off(fresh, base, "accounting_exact", "hierarchy")
    for wire in ("packed", "unpacked"):
        b, f = base.get(wire, {}), fresh.get(wire, {})
        errs += _ratio_regressed(f, b, "cross_reduction",
                                 f"hierarchy[{wire}]")
    return errs


def check_refresh(base: dict, fresh: dict, max_slowdown: float,
                  kernel_retention: float = 0.5) -> List[str]:
    """Live pod-ratio refresh (BENCH_refresh.json): the 2-pod smoke
    run's correctness flags (>= 2 refreshes with ZERO recompiles after
    step 1, replay-schedule bitwise identity, dynamic==static wire) and
    the drifting-mass synthetic's guarantees — refresh-on holds its
    realized mass-capture floor, refresh-off's shortfall stays visible
    (capture_advantage), and re-packing to the live k keeps its byte
    edge over the padded gather buffer."""
    smoke_b, smoke_f = base.get("smoke", {}), fresh.get("smoke", {})
    errs = _flag_off(smoke_f, smoke_b, "zero_recompiles", "refresh[smoke]")
    errs += _flag_off(smoke_f, smoke_b, "replay_bitwise", "refresh[smoke]")
    errs += _flag_off(smoke_f, smoke_b, "dynamic_matches_static",
                      "refresh[smoke]")
    drift_b, drift_f = base.get("drift", {}), fresh.get("drift", {})
    errs += _ratio_regressed(
        drift_f.get("refresh_on", {}), drift_b.get("refresh_on", {}),
        "min_capture", "refresh[drift:on]")
    errs += _ratio_regressed(drift_f, drift_b, "capture_advantage",
                             "refresh[drift]")
    errs += _ratio_regressed(drift_f, drift_b,
                             "byte_ratio_padded_vs_effective",
                             "refresh[drift]")
    return errs


def check_overlap(base: dict, fresh: dict, max_slowdown: float,
                  kernel_retention: float = 0.5) -> List[str]:
    """Double-buffered bucket pipeline (BENCH_overlap.json): every
    bitwise flag must hold (overlap on == off on applied params +
    memory for flat / hierarchical / pod-dynamic, and the host-pipeline
    outputs), and the MACHINE-NORMALIZED pipeline speedup (depth-1 vs
    depth-2 measured in the same run over the same emulated wire) must
    retain its edge — gated like the kernel speedups, at
    ``kernel_retention`` of the baseline and never below break-even."""
    pipe_b, pipe_f = base.get("pipeline", {}), fresh.get("pipeline", {})
    errs = _flag_off(pipe_f, pipe_b, "bitwise_equal", "overlap[pipeline]")
    errs += _ratio_regressed(pipe_f, pipe_b, "speedup", "overlap[pipeline]",
                             slack=kernel_retention)
    if "speedup" in pipe_f and pipe_f["speedup"] <= 1.0:
        errs.append(
            f"overlap[pipeline]: speedup {pipe_f['speedup']:.3f} <= 1.0 "
            "(double buffering no longer beats sequential)"
        )
    smoke_b, smoke_f = base.get("smoke", {}), fresh.get("smoke", {})
    for key in ("flat_bitwise", "hierarchical_bitwise",
                "pod_dynamic_bitwise", "probe_bitwise"):
        errs += _flag_off(smoke_f, smoke_b, key, "overlap[smoke]")
    errs += _flag_off(fresh, base, "bitwise_identical", "overlap")
    return errs


# the acceptance bound on realized-vs-accounted cross-pod bytes: the
# repack transport measures exactly 1.0; anything past 1.2 means the
# wire is shipping bytes the live-k accounting does not admit to
BUDGET_BYTE_RATIO_BOUND = 1.2


def check_budget(base: dict, fresh: dict, max_slowdown: float,
                 kernel_retention: float = 0.5) -> List[str]:
    """Repack transport + byte-budget controller (BENCH_budget.json):
    realized cross-pod bytes must track the live-k accounting (LOWER is
    better — gated both against the baseline and the absolute 1.2x
    acceptance bound), the padded-vs-realized byte edge and the
    water-filling's capture-per-byte advantage over a frozen static-k
    split must not shrink, and every correctness bit (bitwise repack
    round trips, allocations within budget, zero recompiles) must
    hold."""
    tr_b, tr_f = base.get("transport", {}), fresh.get("transport", {})
    errs = _flag_off(tr_f, tr_b, "roundtrip_bitwise", "budget[transport]")
    key = "byte_ratio_realized_vs_accounted"
    errs += _missing(tr_f, tr_b, key, "budget[transport]")
    if key in tr_f:
        if tr_f[key] > BUDGET_BYTE_RATIO_BOUND:
            errs.append(
                f"budget[transport]: {key} {tr_f[key]:.3f} exceeds the "
                f"{BUDGET_BYTE_RATIO_BOUND}x acceptance bound")
        if key in tr_b and tr_f[key] > tr_b[key] / RATIO_SLACK:
            errs.append(
                f"budget[transport]: {key} {tr_f[key]:.3f} regressed vs "
                f"baseline {tr_b[key]:.3f} (realized bytes drifting above "
                "the live-k accounting)")
    errs += _ratio_regressed(tr_f, tr_b, "padded_vs_realized",
                             "budget[transport]")
    al_b, al_f = base.get("allocation", {}), fresh.get("allocation", {})
    errs += _flag_off(al_f, al_b, "within_budget", "budget[allocation]")
    errs += _ratio_regressed(al_f, al_b, "mean_advantage",
                             "budget[allocation]")
    if "mean_advantage" in al_f and al_f["mean_advantage"] <= 1.0:
        errs.append(
            f"budget[allocation]: mean_advantage "
            f"{al_f['mean_advantage']:.3f} <= 1.0 (water-filling no "
            "longer beats the frozen static-k split)")
    smoke_b, smoke_f = base.get("smoke", {}), fresh.get("smoke", {})
    for key in ("repack_bitwise", "transport_roundtrip_bitwise",
                "transport_accounting_exact", "refresh_within_budget",
                "zero_recompiles"):
        errs += _flag_off(smoke_f, smoke_b, key, "budget[smoke]")
    return errs


LOCAL_CONSERVATION_BOUND = 1e-5  # quantized mass conservation, float slack


def check_local(base: dict, fresh: dict, max_slowdown: float,
                kernel_retention: float = 0.5) -> List[str]:
    """Qsparse-local-SGD (BENCH_local.json): the amortized cross-worker
    bytes/step must keep scaling exactly 1/H with the quantized wire's
    compression edge intact, and every correctness bit must hold — the
    H=1 accumulator path bitwise-identical to the per-step sync, packed
    == unpacked under quantization, realized == accounted bytes, every
    H-sweep smoke run converging with zero steady-state recompiles.
    Quantized mass conservation is gated at an absolute float bound."""
    ac_b, ac_f = base.get("accounting", {}), fresh.get("accounting", {})
    errs = _flag_off(ac_f, ac_b, "scaling_exact_one_over_h",
                     "local[accounting]")
    errs += _ratio_regressed(ac_f, ac_b, "quant_value_compression",
                             "local[accounting]")
    if "quant_value_compression" in ac_f and \
            ac_f["quant_value_compression"] <= 1.0:
        errs.append(
            f"local[accounting]: quant_value_compression "
            f"{ac_f['quant_value_compression']:.3f} <= 1.0 (the QSGD "
            "wire tier no longer beats the exact f32 value section)")
    smoke_b, smoke_f = base.get("smoke", {}), fresh.get("smoke", {})
    for key in ("h1_accum_bitwise", "quant_bit_identical",
                "quant_accounting_exact", "amortized_ratio_exact",
                "bytes_scaling_exact", "all_converge",
                "zero_recompiles"):
        errs += _flag_off(smoke_f, smoke_b, key, "local[smoke]")
    key = "quant_conservation_max_err"
    errs += _missing(smoke_f, smoke_b, key, "local[smoke]")
    if key in smoke_f and smoke_f[key] > LOCAL_CONSERVATION_BOUND:
        errs.append(
            f"local[smoke]: {key} {smoke_f[key]:.2e} exceeds the "
            f"{LOCAL_CONSERVATION_BOUND:.0e} bound (memory no longer "
            "absorbs the quantization error exactly)")
    return errs


MATRIX_REQUIRED = ("healthy", "median_decreased", "nonfinite", "spikes",
                   "compression", "compression_win", "bytes_per_step")


def check_matrix(base: dict, fresh: dict, max_slowdown: float,
                 kernel_retention: float = 0.5) -> List[str]:
    """Scenario convergence matrix (BENCH_matrix.json): every declared
    arch x preset cell must be present and structurally complete
    (a missing or corrupt scenario is a NAMED failure, not a silently
    skipped gate), every scenario must be healthy (no loss spikes, no
    NaN/inf) with a decreasing rolling loss median and a compression
    win over the dense wire, and for scenarios the baseline also covers
    the compression ratio must not regress. The fresh payload may
    legitimately cover a SUBSET of the baseline's zoo (PR CI runs one
    arch, the weekly schedule runs all) — the cross-product is
    validated against the fresh run's own declared archs/presets."""
    archs, presets = fresh.get("archs"), fresh.get("presets")
    scen = fresh.get("scenarios")
    if (not isinstance(archs, list) or not archs
            or not isinstance(presets, list) or not presets
            or not isinstance(scen, dict)):
        return ["matrix: corrupt payload — archs/presets/scenarios "
                "missing or empty (the declared coverage is the gate's "
                "ground truth)"]
    errs: List[str] = []
    for arch in archs:
        for preset in presets:
            sid = f"{arch}/{preset}"
            label = f"matrix[{sid}]"
            s = scen.get(sid)
            if s is None:
                errs.append(
                    f"{label}: declared scenario missing from fresh payload")
                continue
            if not isinstance(s, dict):
                errs.append(f"{label}: corrupt scenario record "
                            f"({type(s).__name__}, expected dict)")
                continue
            absent = [k for k in MATRIX_REQUIRED if k not in s]
            if absent:
                errs.append(f"{label}: corrupt scenario record — missing "
                            f"keys {absent}")
                continue
            if not s["healthy"]:
                reason = s.get("stop_reason") or (
                    f"nonfinite={s['nonfinite']} spikes={s['spikes']}")
                errs.append(f"{label}: unhealthy run ({reason})")
            if not s["median_decreased"]:
                errs.append(
                    f"{label}: rolling loss median no longer decreasing")
            if not s["compression_win"]:
                errs.append(f"{label}: no compression win vs the dense wire")
            b = (base.get("scenarios") or {}).get(sid, {})
            if isinstance(b, dict):
                # run.py emits compression: null when the byte
                # accounting lacks a truthy total — never feed that to
                # the numeric ratio check: against a numeric baseline
                # it is a NAMED failure (the metric silently vanished),
                # against a null/absent baseline there is nothing to
                # compare
                cf, cb = s.get("compression"), b.get("compression")
                if _is_num(cb) and _is_num(cf):
                    errs += _ratio_regressed(s, b, "compression", label)
                elif _is_num(cb):
                    errs.append(
                        f"{label}: compression {cf!r} is not numeric "
                        f"but the baseline tracks {cb:.3f} (byte "
                        "accounting lost its total?)")
    return errs


CHECKS = {
    "BENCH_topk.json": check_topk,
    "BENCH_wire.json": check_wire,
    "BENCH_fanout.json": check_fanout,
    "BENCH_hierarchy.json": check_hierarchy,
    "BENCH_refresh.json": check_refresh,
    "BENCH_overlap.json": check_overlap,
    "BENCH_budget.json": check_budget,
    "BENCH_local.json": check_local,
    "BENCH_matrix.json": check_matrix,
}


def select_checks(only: str):
    """Restrict the gate to a comma-separated subset of payload stems
    (``"matrix"`` or ``"topk,local"``) — for CI runs that produce only
    some fresh payloads (a PR bench shard). Unknown stems raise."""
    if not only:
        return CHECKS
    stems = {f: f[len("BENCH_"):-len(".json")] for f in CHECKS}
    want = {w.strip() for w in only.split(",") if w.strip()}
    unknown = want - set(stems.values()) - set(CHECKS)
    if unknown:
        raise SystemExit(
            f"[gate] unknown --only selection {sorted(unknown)}; "
            f"options: {sorted(stems.values())}")
    return {f: c for f, c in CHECKS.items()
            if f in want or stems[f] in want}


def _load_payload(path: str, role: str, fname: str):
    """(payload, errors): an EXISTING but unreadable/corrupt payload is
    a loud named gate failure, not a stack trace — a truncated baseline
    must not silently disable every gate in the file."""
    try:
        with open(path) as f:
            return json.load(f), []
    except (OSError, ValueError) as e:
        return None, [
            f"{fname}: unreadable {role} payload at {path} "
            f"({type(e).__name__}: {e})"
        ]


def run(baseline_dir: str, fresh_dir: str, max_slowdown: float,
        kernel_retention: float = 0.5, checks=None) -> List[str]:
    errors: List[str] = []
    for fname, checker in (checks if checks is not None else CHECKS).items():
        bpath = os.path.join(baseline_dir, fname)
        fpath = os.path.join(fresh_dir, fname)
        if not os.path.exists(bpath):
            print(f"[gate] no baseline {fname} — skipping (new bench?)")
            continue
        if not os.path.exists(fpath):
            errors.append(f"{fname}: fresh run produced no file at {fpath}")
            continue
        base, errs_b = _load_payload(bpath, "baseline", fname)
        fresh, errs_f = _load_payload(fpath, "fresh", fname)
        if errs_b or errs_f:
            errors += errs_b + errs_f
            print(f"[gate] {fname}: FAIL (unreadable)")
            continue
        errs = checker(base, fresh, max_slowdown, kernel_retention)
        status = "FAIL" if errs else "ok"
        print(f"[gate] {fname}: {status}")
        errors += errs
    return errors


def _flatten(d: dict, prefix: str = "") -> dict:
    """Nested payload -> {dotted.path: scalar} (lists are skipped)."""
    out = {}
    for k, v in d.items():
        key = f"{prefix}.{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(_flatten(v, key))
        elif isinstance(v, (int, float, bool, str)):
            out[key] = v
    return out


def _fmt(v) -> str:
    if v is None:
        return "—"
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def write_summary(baseline_dir: str, fresh_dir: str, errors: List[str],
                  fh, checks=None) -> None:
    """Markdown comparison table (baseline vs fresh, per tracked file)
    for ``$GITHUB_STEP_SUMMARY`` / the uploaded artifact — bench
    regressions should be readable without log-diving."""
    checks = checks if checks is not None else CHECKS
    fh.write("## Bench regression gate\n\n")
    if errors:
        fh.write(f"**FAIL** — {len(errors)} regression(s):\n\n")
        for e in errors:
            fh.write(f"- :x: {e}\n")
        fh.write("\n")
    else:
        fh.write("**ok** — all benchmarks within budget\n\n")
    opath = os.path.join(fresh_dir, "BENCH_overlap.json")
    if os.path.exists(opath):
        payload, errs = _load_payload(opath, "fresh", "BENCH_overlap.json")
        pipe = {} if errs else payload.get("pipeline", {})
        if "speedup" in pipe:
            bpipe: dict = {}
            bopath = os.path.join(baseline_dir, "BENCH_overlap.json")
            if os.path.exists(bopath):
                bp, berrs = _load_payload(bopath, "baseline",
                                          "BENCH_overlap.json")
                bpipe = {} if berrs else bp.get("pipeline", {})
            vs = (f" (baseline x{bpipe['speedup']:.2f})"
                  if "speedup" in bpipe else "")
            fh.write(
                f"**Overlap pipeline speedup:** x{pipe['speedup']:.2f}"
                f"{vs} — bitwise identical: "
                f"{_fmt(payload.get('bitwise_identical'))}\n\n")
    bpath = os.path.join(fresh_dir, "BENCH_budget.json")
    if os.path.exists(bpath):
        payload, errs = _load_payload(bpath, "fresh", "BENCH_budget.json")
        tr = {} if errs else payload.get("transport", {})
        al = {} if errs else payload.get("allocation", {})
        if "byte_ratio_realized_vs_accounted" in tr:
            fh.write(
                f"**Budgeted transport:** cross-pod bytes at "
                f"x{tr['byte_ratio_realized_vs_accounted']:.2f} of the "
                f"live-k accounting (bound "
                f"x{BUDGET_BYTE_RATIO_BOUND}) — padded gather would cost "
                f"x{tr.get('padded_vs_realized', 0):.2f}; water-filled "
                f"budget captures x{al.get('mean_advantage', 0):.3f} the "
                f"mass-per-byte of a frozen static split\n\n")
    lpath = os.path.join(fresh_dir, "BENCH_local.json")
    if os.path.exists(lpath):
        payload, errs = _load_payload(lpath, "fresh", "BENCH_local.json")
        ac = {} if errs else payload.get("accounting", {})
        runs = {} if errs else payload.get("smoke", {}).get("runs", {})
        amort = ac.get("amortized_bytes_per_step", {})
        if "1" in amort and "8" in amort:
            comp = ac.get("quant_value_compression", 0)
            conv = ""
            if "1" in runs and "8" in runs:
                conv = (f"; smoke losses H=1 "
                        f"{runs['1'].get('final_loss', 0):.2f} / H=8 "
                        f"{runs['8'].get('final_loss', 0):.2f} from "
                        f"{runs['1'].get('init_loss', 0):.2f}")
            fh.write(
                f"**Qsparse-local-SGD:** amortized cross-worker bytes/"
                f"step {amort['1']:.0f}B at H=1 -> {amort['8']:.0f}B at "
                f"H=8 (exact 1/H), QSGD wire x{comp:.2f} smaller than "
                f"the exact f32 tier{conv}\n\n")
    mpath = os.path.join(fresh_dir, "BENCH_matrix.json")
    if os.path.exists(mpath):
        payload, errs = _load_payload(mpath, "fresh", "BENCH_matrix.json")
        scen = {} if errs else payload.get("scenarios", {})
        cells = {k: s for k, s in scen.items() if isinstance(s, dict)}
        if cells:
            n_ok = sum(1 for s in cells.values()
                       if s.get("healthy") and s.get("median_decreased"))
            fh.write(
                f"**Scenario matrix:** {n_ok}/{len(cells)} scenarios "
                f"healthy + converging over "
                f"{len(payload.get('archs', []))} arch(s) x "
                f"{len(payload.get('presets', []))} preset(s), "
                f"{payload.get('steps', '?')} steps each\n\n")
            fh.write("| scenario | healthy | median ↓ | spikes | "
                     "compression |\n|---|---|---|---:|---:|\n")
            for sid in sorted(cells):
                s = cells[sid]
                fh.write(
                    f"| {sid} | {_fmt(s.get('healthy'))} | "
                    f"{_fmt(s.get('median_decreased'))} | "
                    f"{_fmt(s.get('spikes'))} | "
                    f"x{s.get('compression') or 0:.1f} |\n")
            fh.write("\n")
    for fname in checks:
        if fname == "BENCH_matrix.json":
            continue  # has its own per-scenario table above
        fpath = os.path.join(fresh_dir, fname)
        if not os.path.exists(fpath):
            continue
        payload, errs = _load_payload(fpath, "fresh", fname)
        if errs:  # already reported as a gate failure above
            fh.write(f"### {fname}\n\nunreadable fresh payload\n\n")
            continue
        fresh = _flatten(payload)
        bpath = os.path.join(baseline_dir, fname)
        base: dict = {}
        if os.path.exists(bpath):
            payload, errs = _load_payload(bpath, "baseline", fname)
            base = {} if errs else _flatten(payload)
        fh.write(f"### {fname}\n\n")
        fh.write("| metric | baseline | fresh | Δ |\n|---|---:|---:|---:|\n")
        for key in sorted(set(base) | set(fresh)):
            b, f = base.get(key), fresh.get(key)
            delta = ""
            if _is_num(b) and _is_num(f) and b:
                delta = f"{(f - b) / abs(b) * 100:+.1f}%"
            fh.write(f"| {key} | {_fmt(b)} | {_fmt(f)} | {delta} |\n")
        fh.write("\n")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh-dir", default=".",
                    help="directory the bench run wrote into (repo root)")
    ap.add_argument("--max-slowdown", type=float, default=1.15,
                    help="fail when a tracked timing grows beyond this "
                         "factor (1.15 == >15%% throughput drop)")
    ap.add_argument("--kernel-retention", type=float, default=0.5,
                    help="fail when a kernel's same-run speedup drops "
                         "below this fraction of the baseline's (wide "
                         "budget: interpret-mode variance is ~40%%)")
    ap.add_argument("--summary-file", default=None,
                    help="also write the markdown comparison table here "
                         "(uploaded as a CI artifact); "
                         "$GITHUB_STEP_SUMMARY is appended to "
                         "automatically when set")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of gates by payload stem "
                         "(e.g. 'matrix' or 'topk,local') for CI runs "
                         "that produce only some fresh payloads")
    args = ap.parse_args()
    checks = select_checks(args.only)
    errors = run(args.baseline_dir, args.fresh_dir, args.max_slowdown,
                 args.kernel_retention, checks=checks)
    targets = []
    if args.summary_file:
        targets.append((args.summary_file, "w"))
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        targets.append((step_summary, "a"))
    for path, mode in targets:
        with open(path, mode) as fh:
            write_summary(args.baseline_dir, args.fresh_dir, errors, fh,
                          checks=checks)
    for e in errors:
        print(f"[gate] REGRESSION: {e}", file=sys.stderr)
    if errors:
        return 1
    print("[gate] all benchmarks within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
