"""Numpy logistic-regression runners reproducing the paper's Section 4
experimental protocol (the paper's own implementation is numpy, see §4.1).

All runners share the protocol:
  * stochastic gradient of  f(x) = mean log(1+exp(-b a^T x)) + lam/2 |x|^2
  * stepsizes eta_t = gamma / (lam (t + a))           (Table 2)
  * final estimate  x_bar = sum w_t x_t / S_T,  w_t = (t + a)^2  (Thm 2.4)
  * per-step transmitted bits per the paper's accounting (Appendix B)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core import encoding
from repro.data.synthetic import LogRegData, logreg_loss_np


@dataclasses.dataclass
class RunResult:
    name: str
    losses: list  # (step, f(x_bar or x)) pairs
    bits_per_step: float
    wall_s: float

    @property
    def final_loss(self) -> float:
        return self.losses[-1][1]


def _topk(u: np.ndarray, k: int) -> np.ndarray:
    out = np.zeros_like(u)
    idx = np.argpartition(np.abs(u), -k)[-k:]
    out[idx] = u[idx]
    return out


def _randk(u: np.ndarray, k: int, rng) -> np.ndarray:
    out = np.zeros_like(u)
    idx = rng.choice(u.size, size=k, replace=False)
    out[idx] = u[idx]
    return out


def _qsgd_quantize(g: np.ndarray, s: int, rng) -> np.ndarray:
    norm = np.linalg.norm(g)
    if norm == 0:
        return g
    r = np.abs(g) / norm * s
    lo = np.floor(r)
    up = rng.random(g.shape) < (r - lo)
    return norm * np.sign(g) * (lo + up) / s


def _sgrad(data: LogRegData, x: np.ndarray, i: int) -> np.ndarray:
    ai = data.A[i]
    bi = data.b[i]
    z = -bi * float(ai @ x)
    sig = 1.0 / (1.0 + np.exp(-z))
    return -(bi * sig) * ai + data.lam * x


def run_memsgd(
    data: LogRegData,
    T: int,
    k: int,
    comp: str = "top",  # top | rand
    gamma: float = 2.0,
    a: Optional[float] = None,
    seed: int = 0,
    eval_every: Optional[int] = None,
    weighted_avg: bool = True,
) -> RunResult:
    """Paper Algorithm 1 on logistic regression."""
    rng = np.random.default_rng(seed)
    d = data.d
    if a is None:
        a = d / k  # paper Table 2 (epsilon)
    x = np.zeros(d)
    m = np.zeros(d)
    xbar = np.zeros(d)
    wsum = 0.0
    eval_every = eval_every or max(1, T // 20)
    losses = []
    t0 = time.time()
    for t in range(T):
        eta = gamma / (data.lam * (t + a))
        i = rng.integers(data.n)
        g = _sgrad(data, x, i)
        u = m + eta * g
        gt = _topk(u, k) if comp == "top" else _randk(u, k, rng)
        x = x - gt
        m = u - gt
        w = (t + a) ** 2
        xbar += w * x
        wsum += w
        if (t + 1) % eval_every == 0 or t == T - 1:
            xe = xbar / wsum if weighted_avg else x
            losses.append((t + 1, logreg_loss_np(data, xe)))
    return RunResult(
        name=f"memsgd_{comp}{k}(a={a:.0f})",
        losses=losses,
        bits_per_step=encoding.sparse_bits(d, k),
        wall_s=time.time() - t0,
    )


def run_sgd(
    data: LogRegData, T: int, gamma: float = 2.0, a: float = 1.0,
    seed: int = 0, eval_every: Optional[int] = None,
    weighted_avg: bool = True,
) -> RunResult:
    """Vanilla SGD (k = d, dense communication)."""
    rng = np.random.default_rng(seed)
    d = data.d
    x = np.zeros(d)
    xbar = np.zeros(d)
    wsum = 0.0
    eval_every = eval_every or max(1, T // 20)
    losses = []
    t0 = time.time()
    for t in range(T):
        eta = gamma / (data.lam * (t + a))
        i = rng.integers(data.n)
        x = x - eta * _sgrad(data, x, i)
        w = (t + a) ** 2
        xbar += w * x
        wsum += w
        if (t + 1) % eval_every == 0 or t == T - 1:
            xe = xbar / wsum if weighted_avg else x
            losses.append((t + 1, logreg_loss_np(data, xe)))
    return RunResult(
        name="sgd",
        losses=losses,
        bits_per_step=encoding.dense_bits(d),
        wall_s=time.time() - t0,
    )


def run_qsgd(
    data: LogRegData, T: int, bits: int, gamma0: float = 0.2,
    seed: int = 0, eval_every: Optional[int] = None,
    sparse_aware: bool = False,
) -> RunResult:
    """QSGD baseline (Alistarh et al.) with s = 2^bits levels and the
    Bottou stepsize used for the comparison in paper §4.3."""
    rng = np.random.default_rng(seed)
    d = data.d
    s = 2**bits
    x = np.zeros(d)
    eval_every = eval_every or max(1, T // 20)
    losses = []
    t0 = time.time()
    d_eff = d
    if sparse_aware:
        d_eff = max(1, int((data.A != 0).sum(axis=1).mean()))
    for t in range(T):
        eta = gamma0 / (1 + gamma0 * data.lam * t)
        i = rng.integers(data.n)
        g = _qsgd_quantize(_sgrad(data, x, i), s, rng)
        x = x - eta * g
        if (t + 1) % eval_every == 0 or t == T - 1:
            losses.append((t + 1, logreg_loss_np(data, x)))
    return RunResult(
        name=f"qsgd_{bits}bit",
        losses=losses,
        bits_per_step=encoding.qsgd_bits(d_eff, s),
        wall_s=time.time() - t0,
    )


def run_memsgd_bottou(
    data: LogRegData, T: int, k: int, gamma0: float = 0.2, seed: int = 0,
    eval_every: Optional[int] = None,
) -> RunResult:
    """Mem-SGD with the same Bottou stepsize (paper §4.3 comparison)."""
    rng = np.random.default_rng(seed)
    d = data.d
    x = np.zeros(d)
    m = np.zeros(d)
    eval_every = eval_every or max(1, T // 20)
    losses = []
    t0 = time.time()
    for t in range(T):
        eta = gamma0 / (1 + gamma0 * data.lam * t)
        i = rng.integers(data.n)
        u = m + eta * _sgrad(data, x, i)
        gt = _topk(u, k)
        x = x - gt
        m = u - gt
        if (t + 1) % eval_every == 0 or t == T - 1:
            losses.append((t + 1, logreg_loss_np(data, x)))
    return RunResult(
        name=f"memsgd_top{k}_bottou",
        losses=losses,
        bits_per_step=encoding.sparse_bits(d, k),
        wall_s=time.time() - t0,
    )


def reference_optimum(data: LogRegData, iters: int = 2000) -> float:
    """f* via full gradient descent (L-smooth => eta = 1/L works)."""
    L = 0.25 * float((data.A**2).sum(axis=1).max()) + data.lam
    x = np.zeros(data.d)
    eta = 1.0 / L
    for _ in range(iters):
        z = -data.b * (data.A @ x)
        sig = 1.0 / (1.0 + np.exp(-z))
        g = -(data.A * (data.b * sig)[:, None]).mean(axis=0) + data.lam * x
        x = x - eta * g
    return logreg_loss_np(data, x)


def run_parallel_memsgd_sim(
    data: LogRegData, T_per_worker: int, k: int, n_workers: int,
    eta: float = 0.05, seed: int = 0, staleness: bool = True,
) -> RunResult:
    """PARALLEL-MEM-SGD (Algorithm 2) simulation of the multicore
    experiment (paper §4.4).

    TPU adaptation note (DESIGN.md): the paper's lock-free shared-memory
    race has no TPU analogue, so we SIMULATE the Hogwild-style execution:
    workers take turns applying their sparse updates to the shared iterate,
    each computing its gradient on a stale snapshot (the iterate as of its
    previous turn) — the same staleness pattern a lock-free run exhibits,
    with W-step-old reads."""
    rng = np.random.default_rng(seed)
    d = data.d
    x = np.zeros(d)
    mems = np.zeros((n_workers, d))
    snapshots = np.zeros((n_workers, d))  # stale views
    losses = []
    t0 = time.time()
    eval_every = max(1, T_per_worker // 10)
    for t in range(T_per_worker):
        for w in range(n_workers):
            xw = snapshots[w] if staleness and t > 0 else x
            i = rng.integers(data.n)
            g = _sgrad(data, xw, i)
            u = mems[w] + eta * g
            gt = _topk(u, k)
            x = x - gt  # sparse write into the shared iterate
            mems[w] = u - gt
            snapshots[w] = x.copy()
        if (t + 1) % eval_every == 0 or t == T_per_worker - 1:
            losses.append((t + 1, logreg_loss_np(data, x)))
    return RunResult(
        name=f"parallel_mem_top{k}_W{n_workers}",
        losses=losses,
        bits_per_step=encoding.sparse_bits(d, k) * n_workers,
        wall_s=time.time() - t0,
    )
