"""Telemetry sink (repro.utils.telemetry): rolling-median/spike
detector properties (hypothesis-fallback), named non-finite errors,
JSONL series, diagnostics back-compat — and DESIGN.md invariant 13:
telemetry is observe-only, so enabling a fully-instrumented sink is
bitwise inert on the applied params + memory of a real train run."""
import json
import math

import pytest

from _hypothesis_compat import given, settings, st
from repro.utils.telemetry import (
    NonFiniteLossError,
    RollingMedian,
    SpikeDetector,
    Telemetry,
    TelemetryConfig,
    is_spike,
)


# -- rolling median -----------------------------------------------------------

def test_rolling_median_window():
    m = RollingMedian(3)
    assert m.value is None
    assert m.push(1.0) == 1.0
    assert m.push(9.0) == 5.0
    assert m.push(5.0) == 5.0
    # window slides: the 1.0 falls out
    assert m.push(9.0) == 9.0
    assert len(m) == 3


def test_rolling_median_rejects_bad_window():
    with pytest.raises(ValueError, match="window"):
        RollingMedian(0)


@settings(max_examples=25)
@given(x=st.floats(min_value=0.1, max_value=100.0),
       n=st.integers(min_value=1, max_value=20))
def test_median_constant_under_constant_streams(x, n):
    """Property: a constant stream keeps a constant median (monotone:
    it never drifts off the stream value), and never flags a spike."""
    det = SpikeDetector(window=8, factor=4.0, min_history=3)
    for _ in range(n):
        assert det.observe(x) is False
        assert det.median.value == x


@settings(max_examples=25)
@given(base=st.floats(min_value=0.5, max_value=10.0),
       excess=st.floats(min_value=1.1, max_value=20.0))
def test_spike_flagged_iff_excess_over_window_median(base, excess):
    """Property: after a steady window at ``base``, a new value is
    flagged iff it exceeds factor * median — values at or below the
    threshold never flag, values above always do."""
    factor = 4.0
    det = SpikeDetector(window=8, factor=factor, min_history=3)
    for _ in range(8):
        det.observe(base)
    probe = factor * base * excess
    fresh = SpikeDetector(window=8, factor=factor, min_history=3)
    for _ in range(8):
        fresh.observe(base)
    assert fresh.observe(probe) is True
    assert det.observe(factor * base * 0.99) is False


def test_spike_detection_arms_after_min_history():
    det = SpikeDetector(window=8, factor=2.0, min_history=3)
    # the first min_history observations never flag, however extreme
    assert det.observe(1.0) is False
    assert det.observe(100.0) is False
    assert det.observe(1.0) is False
    # armed now: median of {1, 100, 1} = 1 -> 50 is a spike
    assert det.observe(50.0) is True


def test_is_spike_nonfinite_inputs():
    # NaN/inf are non-finite EVENTS, not spikes — and never poison the
    # median window
    assert is_spike(float("nan"), 1.0, 4.0) is False
    assert is_spike(float("inf"), 1.0, 4.0) is False
    assert is_spike(5.0, None, 4.0) is False
    det = SpikeDetector(window=4, factor=4.0, min_history=1)
    det.observe(1.0)
    det.observe(float("nan"))
    assert det.median.value == 1.0  # NaN not pushed


# -- Telemetry sink -----------------------------------------------------------

def test_nonfinite_loss_raises_named_error():
    tel = Telemetry()
    tel.step(0, 2.0)
    with pytest.raises(NonFiniteLossError, match="step 1") as exc:
        tel.step(1, float("nan"))
    assert exc.value.step == 1
    assert tel.nonfinite_step == 1
    assert "non-finite loss at step 1" in tel.stop_reason


def test_nonfinite_raise_flushes_and_keeps_sink_open(tmp_path):
    # the raise path FLUSHES the JSONL handle (record durable on disk)
    # but does not close it: a caller-owned sink survives the error and
    # can keep receiving events / be reused across runs
    path = tmp_path / "tel.jsonl"
    tel = Telemetry(TelemetryConfig(jsonl_path=str(path)))
    tel.step(0, 1.0)
    with pytest.raises(NonFiniteLossError):
        tel.step(1, float("nan"))
    assert len(path.read_text().splitlines()) == 2  # flushed, durable
    tel.step(2, 1.1)  # still open: no ValueError on a closed file
    tel.close()
    assert len(path.read_text().splitlines()) == 3


def test_nonfinite_observe_only_mode():
    tel = Telemetry(TelemetryConfig(stop_on_nonfinite=False))
    tel.step(0, 2.0)
    tel.step(1, float("inf"))  # records, does not raise
    tel.step(2, 1.9)
    s = tel.summary()
    assert s["nonfinite"] and s["nonfinite_step"] == 1
    assert not tel.should_stop  # observe-only: driver keeps looping


def test_spike_budget_early_stop():
    prints = []
    tel = Telemetry(TelemetryConfig(window=4, spike_factor=2.0,
                                    min_history=2, max_spikes=2),
                    printer=prints.append)
    for i, x in enumerate([1.0, 1.0, 9.0, 1.0, 9.0]):
        tel.step(i, x)
    assert tel.should_stop
    assert tel.summary()["spikes"] == 2
    assert "max_spikes=2" in tel.stop_reason
    assert any("loss spike at step 2" in p for p in prints)


def test_step_print_routed_through_sink():
    prints = []
    tel = Telemetry(printer=prints.append)
    tel.step(0, 3.25, log=True)
    tel.step(1, 3.0, log=False)
    assert prints == ["step     0  loss 3.2500"]


def test_jsonl_series_and_refresh_events(tmp_path):
    path = tmp_path / "tel.jsonl"
    with Telemetry(TelemetryConfig(jsonl_path=str(path))) as tel:
        tel.set_bytes_per_step({"intra": 100, "cross": 10, "total": 110})
        tel.step(0, 5.0, cache_size=1)
        tel.pod_refresh(1, (32, 16), cross_bytes=123.0)
        tel.step(1, 4.0, cache_size=2)
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(recs) == 3
    assert recs[0]["loss"] == 5.0 and recs[0]["bytes"]["total"] == 110
    assert recs[1] == {"event": "pod_refresh", "step": 1,
                       "pod_ks": [32, 16], "cross_bytes": 123.0}
    s = tel.summary()
    assert s["bytes_total"] == {"intra": 200, "cross": 20, "total": 220}
    assert s["pod_refresh_schedule"] == [[1, [32, 16]]]


def test_summary_median_decreased():
    tel = Telemetry(TelemetryConfig(window=4))
    for i, x in enumerate([8.0, 8.1, 7.9, 8.0, 4.0, 4.1, 3.9, 4.0]):
        tel.step(i, x)
    s = tel.summary()
    assert s["loss_first_median"] == pytest.approx(8.0)
    assert s["loss_last_median"] == pytest.approx(4.0)
    assert s["median_decreased"]
    flat = Telemetry(TelemetryConfig(window=4))
    for i in range(8):
        flat.step(i, 5.0)
    assert not flat.summary()["median_decreased"]


def test_diagnostics_back_compat_keys():
    """The sink reproduces the historical ``train(diagnostics=)`` dict:
    same keys, and the steady-state recompile formula anchored at the
    end of the second sync round (index 2H - 1)."""
    tel = Telemetry()
    tel.initial_pod_ks = (8, 4)
    sizes = [1, 2, 2, 2, 3]
    for i, c in enumerate(sizes):
        tel.step(i, 5.0 - 0.1 * i, cache_size=c)
    tel.pod_refresh(3, (16, 8))
    d = tel.diagnostics(local_steps=1)
    assert set(d) == {"step_cache_sizes", "step_cache_size",
                      "pod_refresh_schedule", "initial_pod_ks",
                      "steady_state_recompiles"}
    assert d["step_cache_sizes"] == sizes
    assert d["step_cache_size"] == 3
    assert d["initial_pod_ks"] == (8, 4)
    assert d["pod_refresh_schedule"] == [(3, (16, 8))]
    # baseline index min(2*1-1, 4) = 1 -> sizes[-1] - sizes[1] = 1
    assert d["steady_state_recompiles"] == 1
    # H=2: baseline index min(3, 4) = 3 -> 3 - 2 = 1; H large clamps
    assert tel.diagnostics(local_steps=2)["steady_state_recompiles"] == 1
    assert tel.diagnostics(local_steps=9)["steady_state_recompiles"] == 0
    # unknown cache sizes -> None, not a crash
    blind = Telemetry()
    blind.step(0, 1.0)
    assert blind.diagnostics()["steady_state_recompiles"] is None


# -- invariant 13: observe-only, bitwise --------------------------------------

def test_telemetry_is_observe_only_bitwise(tmp_path):
    """Selfcheck-style probe: a fully-instrumented sink (tiny window,
    hair-trigger spike detector, JSONL series) vs the default internal
    sink on the same seeded run — applied params AND error-feedback
    memory must match BITWISE."""
    import jax

    from repro.configs import get_smoke_config
    from repro.core.distributed import SyncConfig
    from repro.core.selfcheck import bitwise_equal
    from repro.data import token_batches
    from repro.data.pipeline import ShardedBatcher, take
    from repro.launch.train import TrainConfig, train
    from repro.models import build_model
    from repro.utils.compat import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke_config("rwkv6-3b")
    model = build_model(cfg)
    tc = TrainConfig(optimizer="memsgd", eta=0.3,
                     sync=SyncConfig.preset("topk", ratio=0.02))
    batch_list = list(take(iter(ShardedBatcher(
        mesh, token_batches(cfg.vocab_size, 2, 16, seed=3), prefetch=0)), 6))

    def run(telemetry):
        p, m, _, _, _ = train(model, mesh, tc, iter(batch_list), n_steps=6,
                              log_every=0, rng=jax.random.PRNGKey(0),
                              telemetry=telemetry)
        return p, m

    baseline = run(None)  # default internal sink
    tel = Telemetry(TelemetryConfig(window=2, spike_factor=1.0001,
                                    min_history=1,
                                    jsonl_path=str(tmp_path / "t.jsonl")),
                    printer=lambda s: None)
    instrumented = run(tel)
    assert bitwise_equal(baseline, instrumented)
    assert tel.summary()["steps"] == 6
    # train() must NOT close a caller-provided sink (only the internal
    # default one it created itself) — the caller owns the lifetime
    assert tel._fh is not None
    tel.close()
    assert (tmp_path / "t.jsonl").exists()


def test_nonfinite_train_run_attaches_history(tmp_path):
    """An exploding run raises the named error mid-loop, and the error
    carries the partial (step, loss) history accumulated before the
    stop — plus the caller's sink survives for post-mortem readback."""
    import jax
    import math as _math

    from repro.configs import get_smoke_config
    from repro.core.distributed import SyncConfig
    from repro.data import token_batches
    from repro.data.pipeline import ShardedBatcher, take
    from repro.launch.train import TrainConfig, train
    from repro.models import build_model
    from repro.utils.compat import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke_config("rwkv6-3b")
    model = build_model(cfg)
    # eta=inf: step 0's loss is finite (initial params), the update
    # poisons the params, step 1's loss is NaN — a deterministic blowup
    tc = TrainConfig(optimizer="memsgd", eta=float("inf"),
                     sync=SyncConfig.preset("topk", ratio=0.02))
    batch_list = list(take(iter(ShardedBatcher(
        mesh, token_batches(cfg.vocab_size, 2, 16, seed=3), prefetch=0)), 4))
    tel = Telemetry(TelemetryConfig(jsonl_path=str(tmp_path / "t.jsonl")),
                    printer=lambda s: None)
    with pytest.raises(NonFiniteLossError) as exc:
        train(model, mesh, tc, iter(batch_list), n_steps=4, log_every=1,
              rng=jax.random.PRNGKey(0), telemetry=tel)
    e = exc.value
    assert e.step == 1 and not _math.isfinite(e.loss)
    # the partial history: step 0's finite loss is NOT discarded
    assert [i for i, _ in e.history] == [0]
    assert _math.isfinite(e.history[0][1])
    assert tel._fh is not None  # caller sink spared on the raise path
    assert tel.summary()["nonfinite_step"] == 1
    tel.close()
