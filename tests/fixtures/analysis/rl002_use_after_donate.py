# repro-lint: skip-file  (linter fixture: parsed by tests, never run)
#
# RL002 use-after-donate corpus.
import jax
import jax.numpy as jnp

from repro.launch.train import make_train_step
from repro.launch import serve


# --- true positives -------------------------------------------------------

def read_after_jit_donation(params, batch):
    step = jax.jit(update, donate_argnums=(0,))
    new_params = step(params, batch)
    norm = jnp.linalg.norm(params["w"])  # EXPECT: RL002
    return new_params, norm


def read_after_factory_donation(model, mesh, tc, batches):
    step = make_train_step(model, mesh, tc)
    params, memory, opt, count = init_state(model)
    out = step(params, memory, opt, count, next(batches))
    stale = memory  # EXPECT: RL002
    return out, stale


def loop_carried_donation(model, mesh, tc, batches):
    step = make_train_step(model, mesh, tc)
    params, memory, opt, count = init_state(model)
    for batch in batches:
        # `out` is never unpacked back into params: iteration 2 passes
        # a donated buffer back into the step
        out = step(params, memory, opt, count, batch)  # EXPECT: RL002
    return out


# --- negatives ------------------------------------------------------------

def simultaneous_rebind(model, mesh, tc, batches):
    step = make_train_step(model, mesh, tc)
    params, memory, opt, count = init_state(model)
    for batch in batches:
        params, memory, opt, count, m = step(params, memory, opt, count, batch)
    return params


def sanctioned_replica_copy(model, mesh, tc, batch):
    step = make_train_step(model, mesh, tc)
    params, memory, opt, count = init_state(model)
    snapshot = serve.replica_copy(params)
    params, memory, opt, count, m = step(params, memory, opt, count, batch)
    return snapshot, serve.replica_copy(params)


def aot_lowering_is_not_execution(model, mesh, tc, a_params, a_batch):
    step = make_train_step(model, mesh, tc)
    lowered = step.lower(a_params, a_batch)
    return lowered, a_params  # abstract shapes: nothing was donated


def correlated_branches(model, mesh, tc, batches, H):
    """The same condition guards the donating call and the rebinding
    unpack — no feasible donate-then-read path exists."""
    step = make_train_step(model, mesh, tc)
    params, memory, opt, count = init_state(model)
    acc = init_acc(model) if H > 1 else None
    for batch in batches:
        if H > 1:
            out = step(params, memory, acc, opt, count, batch)
        else:
            out = step(params, memory, opt, count, batch)
        if H > 1:
            params, memory, acc, opt, count, m = out
        else:
            params, memory, opt, count, m = out
    return params, acc


# --- suppressed -----------------------------------------------------------

def suppressed_read(params, batch):
    step = jax.jit(update, donate_argnums=(0,))
    new_params = step(params, batch)
    # repro-lint: disable=RL002  (fixture: demonstrating suppression)
    norm = jnp.linalg.norm(params["w"])
    return new_params, norm
