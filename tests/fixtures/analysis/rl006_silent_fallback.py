# repro-lint: skip-file  (linter fixture: parsed by tests, never run)
#
# RL006 silent-fallback corpus.


# --- true positives -------------------------------------------------------

def bare_except(cfg):
    try:
        return cfg["pod_k"]
    except:  # EXPECT: RL006
        return 1


def swallowed_exception(plan, bucket):
    try:
        return plan.pod_k_for_bucket(bucket)
    except Exception:  # EXPECT: RL006
        return plan.global_ratio


def bound_but_unused(path):
    try:
        return open(path).read()
    except Exception as e:  # EXPECT: RL006
        return ""


# --- negatives ------------------------------------------------------------

def narrow_catch(cfg):
    try:
        return cfg["pod_k"]
    except KeyError:
        return 1


def reraised_named(plan, bucket):
    try:
        return plan.pod_k_for_bucket(bucket)
    except Exception as e:
        raise RuntimeError(f"pod_k lookup failed for {bucket}") from e


def reported_error(path, log):
    try:
        return open(path).read()
    except Exception as e:
        log.warning("unreadable %s: %s", path, e)
        return ""


# --- suppressed -----------------------------------------------------------

def deliberate_best_effort(sock):
    try:
        sock.close()
    # repro-lint: disable=RL006  (close() on shutdown is best-effort)
    except Exception:
        pass
