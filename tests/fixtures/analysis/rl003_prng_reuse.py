# repro-lint: skip-file  (linter fixture: parsed by tests, never run)
#
# RL003 prng-key-reuse corpus.
import jax
import jax.numpy as jnp
import jax.random as jr


# --- true positives -------------------------------------------------------

def double_sample(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))  # EXPECT: RL003
    return a + b


def reuse_via_alias(seed):
    k = jr.PRNGKey(seed)
    noise = jr.normal(k, (8,))
    jitter = jr.bernoulli(k, 0.5, (8,))  # EXPECT: RL003
    return noise, jitter


def loop_without_fold(key, n):
    out = []
    for i in range(n):
        out.append(jax.random.normal(key, (2,)))  # EXPECT: RL003
    return out


def literal_seed_twice():
    u = jax.random.normal(jax.random.PRNGKey(0), (3,))
    v = jax.random.normal(jax.random.PRNGKey(0), (3,))  # EXPECT: RL003
    return u, v


# --- negatives ------------------------------------------------------------

def split_before_each_use(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (4,))
    b = jax.random.uniform(k2, (4,))
    return a + b


def fold_in_loop(key, n):
    out = []
    for i in range(n):
        out.append(jax.random.normal(jax.random.fold_in(key, i), (2,)))
    return out


def exclusive_branches(key, kind):
    k1, k2 = jax.random.split(key)
    if kind == "rec":
        block = jax.random.normal(k1, (4,))
    else:
        block = jax.random.uniform(k1, (4,))
    tail = jax.random.normal(k2, (4,))
    return block, tail


def rebound_key(key, n):
    for i in range(n):
        noise = jax.random.normal(key, (2,))
        key, _ = jax.random.split(key)
    return noise


def dict_key_is_not_prng(table, key):
    # module imports jax, but `key` here is consumed by plain helpers —
    # passing a name into an unknown call twice IS flagged when it looks
    # like a key param; renaming or splitting is the fix. This negative
    # pins the *derivation* exemption instead:
    sub = jax.random.fold_in(key, 3)
    other = jax.random.fold_in(key, 4)
    return sub, other


# --- suppressed -----------------------------------------------------------

def deliberate_same_draw(key):
    dense = jax.random.normal(key, (4,))
    # repro-lint: disable=RL003  (two encodings of the SAME draw)
    sparse = jax.random.normal(key, (4,))
    return dense, sparse
