# repro-lint: skip-file  (linter fixture: parsed by tests, never run)
#
# RL005 wire-header-literal corpus.
import jax.numpy as jnp

from repro.core import encoding


# --- true positives -------------------------------------------------------

def peek_live_n(buf):
    return buf[7]  # EXPECT: RL005


def check_magic(header):
    if header[0] != 0x53505257:  # EXPECT: RL005
        raise ValueError("bad magic")
    return header


def strip_header(wire_buf):
    head = wire_buf[:8]  # EXPECT: RL005
    return head


# --- negatives ------------------------------------------------------------

def named_constant(buf):
    return buf[encoding.LIVE_N_WORD]


def accessor_helpers(buf):
    return encoding.live_n_of(buf)


def bucket_lists(bufs):
    # plural: a LIST of bucket buffers, first bucket — not a header word
    return bufs[0]


def payload_index(buf, i):
    return buf[i]


def beyond_header(buf):
    # payload starts after the header; literal 8 is not a header word
    return buf[8:]


def unrelated_name(table):
    return table[3]


# --- suppressed -----------------------------------------------------------

def deliberate_raw_peek(buf):
    # repro-lint: disable=RL005  (debug dump: prints every raw word)
    return buf[1]
