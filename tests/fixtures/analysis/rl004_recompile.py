# repro-lint: skip-file  (linter fixture: parsed by tests, never run)
#
# RL004 recompile-hazard corpus.
import functools

import jax
import jax.numpy as jnp

from repro.utils.compat import shard_map


# --- true positives: jit/shard_map constructed inside a loop --------------

def jit_per_iteration(specs, vals):
    out = []
    for spec in specs:
        encode = jax.jit(lambda v: pack(spec, v))  # EXPECT: RL004
        out.append(encode(vals))
    return out


def shard_map_per_iteration(mesh, kernels, x):
    for kern in kernels:
        y = shard_map(kern, mesh=mesh, in_specs=None, out_specs=None)(x)  # EXPECT: RL004
    return y


# --- true positive: traced closure over a later-rebound name --------------

def stale_closure(mesh, x):
    k_live = 4

    @jax.jit
    def step(v):
        return jnp.sum(v) * k_live

    y = step(x)
    k_live = 8  # EXPECT: RL004
    return step(x), y


# --- negatives ------------------------------------------------------------

def hoisted_jit(specs, vals):
    encode = jax.jit(pack_all)
    out = []
    for spec in specs:
        out.append(encode(spec, vals))
    return out


def self_rebind_idiom(x):
    def step(v):
        return jnp.sum(v)

    step = jax.jit(step)  # f = jax.jit(f) is the idiom, not a hazard
    return step(x)


def rebind_before_definition(mesh, x):
    k_live = 4
    k_live = 8  # rebinding BEFORE the trace exists is fine

    @jax.jit
    def step(v):
        return jnp.sum(v) * k_live

    return step(x)


def traced_argument_refresh(step, pod_ks, x):
    # the sanctioned shape: runtime-varying values ride as traced args
    for ks in pod_ks:
        y = step(x, ks)
    return y


# --- suppressed -----------------------------------------------------------

def deliberate_jit_in_loop(specs, vals):
    out = []
    for spec in specs:
        # repro-lint: disable=RL004  (two fixed dtype variants, bench
        # code compiles each exactly once on purpose)
        encode = jax.jit(lambda v: pack(spec, v))
        out.append(encode(vals))
    return out
