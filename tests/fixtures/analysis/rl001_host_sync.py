# repro-lint: skip-file  (linter fixture: parsed by tests, never run)
#
# RL001 host-sync-in-hot-path corpus. `# EXPECT: RL00x` marks lines the
# rule must flag; every other line must stay silent.
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.train import make_train_step


# --- true positives: host sync inside a traced body ----------------------

@jax.jit
def traced_loss(params, batch):
    loss = jnp.mean(params["w"] * batch)
    return float(loss)  # EXPECT: RL001


def make_traced(spec):
    def inner(x):
        return np.asarray(x).sum()  # EXPECT: RL001

    return jax.jit(inner)


# --- true positives: host sync inside a step-dispatch loop ----------------

def train_loop(model, mesh, tc, batches):
    step = make_train_step(model, mesh, tc)
    params, memory, opt, count = init_state(model)
    losses = []
    for batch in batches:
        params, memory, opt, count, m = step(params, memory, opt, count, batch)
        losses.append(float(m["loss"]))  # EXPECT: RL001
        if bool(m["done"]):  # EXPECT: RL001
            break
    return losses


def eta_through_helper(tc, batches, step):
    for batch in batches:
        out = step(batch)
        # tainted name inside an unknown call still crosses to host
        eta = float(schedule(tc)(out))  # EXPECT: RL001
    return eta


# --- negatives ------------------------------------------------------------

def drain_pattern(step, batches):
    """The sanctioned one-step-late drain: sync lives in a closure that
    runs AFTER the next step is dispatched."""
    pending = None

    def _drain(p):
        return float(p)  # closure, not the loop body: silent

    out = None
    for batch in batches:
        out = step(batch)
        if pending is not None:
            _drain(pending)
        pending = out["loss"]
    return out


def host_only_loop(rows):
    # no step dispatch in sight: float() on host data is fine
    total = 0.0
    for r in rows:
        total += float(r["value"])
    return total


def bench_timing(step, batches):
    for batch in batches:
        out = step(batch)
        # block_until_ready is the sanctioned EXPLICIT sync
        jax.block_until_ready(out)
    return out


# --- suppressed -----------------------------------------------------------

def convergence_smoke(step, batches):
    losses = []
    for batch in batches:
        m = step(batch)
        # repro-lint: disable=RL001  (smoke test: simplicity beats
        # throughput here, the sync is deliberate)
        losses.append(float(m["loss"]))
    return losses
