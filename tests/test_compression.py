"""Property tests for the k-contraction operators (paper Definition 2.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import compression as C

DIM = st.integers(min_value=2, max_value=257)
SEED = st.integers(min_value=0, max_value=2**31 - 1)


def _vec(d, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), (d,))


@settings(max_examples=40, deadline=None)
@given(d=DIM, seed=SEED, kfrac=st.floats(0.01, 1.0))
def test_topk_contraction(d, seed, kfrac):
    """top_k is a k-contraction: ||x - comp(x)||^2 <= (1-k/d)||x||^2,
    deterministically (no expectation needed)."""
    k = max(1, int(kfrac * d))
    x = _vec(d, seed)
    comp = C.top_k(k)
    resid = float(jnp.sum((x - comp.dense(x, None)) ** 2))
    bound = (1 - comp.k_of(d) / d) * float(jnp.sum(x**2))
    assert resid <= bound + 1e-5 * float(jnp.sum(x**2)) + 1e-12


@settings(max_examples=20, deadline=None)
@given(d=st.integers(8, 128), seed=SEED)
def test_randk_contraction_in_expectation(d, seed):
    k = max(1, d // 4)
    x = _vec(d, seed)
    comp = C.rand_k(k)
    key = jax.random.PRNGKey(seed + 1)
    resids = []
    for i in range(300):
        r = x - comp.dense(x, jax.random.fold_in(key, i))
        resids.append(float(jnp.sum(r**2)))
    bound = (1 - k / d) * float(jnp.sum(x**2))
    # statistical: mean within 15% of the exact expectation (= bound)
    assert np.mean(resids) <= bound * 1.15 + 1e-12


@settings(max_examples=30, deadline=None)
@given(d=DIM, seed=SEED, kb=st.integers(1, 8), block=st.sampled_from([8, 16, 64]))
def test_blockwise_topk_contraction(d, seed, kb, block):
    x = _vec(d, seed)
    comp = C.blockwise_top_k(kb, block)
    resid = float(jnp.sum((x - comp.dense(x, None)) ** 2))
    k_eff = comp.k_of(d)
    bound = (1 - min(kb, block) / block) * float(jnp.sum(x**2))
    # per-block contraction with uniform factor k_b/block
    assert resid <= bound + 1e-5 * float(jnp.sum(x**2)) + 1e-12
    assert k_eff >= 1


@settings(max_examples=20, deadline=None)
@given(d=st.integers(4, 64), seed=SEED, k=st.floats(0.25, 2.0))
def test_random_coordinate_ultra_contraction(d, seed, k):
    """Remark 2.3: valid even for k < 1 (in expectation)."""
    x = _vec(d, seed)
    comp = C.random_coordinate(k)
    key = jax.random.PRNGKey(seed + 7)
    resids = []
    for i in range(400):
        r = x - comp.dense(x, jax.random.fold_in(key, i))
        resids.append(float(jnp.sum(r**2)))
    bound = (1 - min(k, d) / d) * float(jnp.sum(x**2))
    assert np.mean(resids) <= bound * 1.15 + 1e-12


@settings(max_examples=25, deadline=None)
@given(d=DIM, seed=SEED)
def test_topk_sparse_dense_consistency(d, seed):
    k = max(1, d // 3)
    x = _vec(d, seed)
    comp = C.top_k(k)
    dense = comp.dense(x, None)
    vals, idx = comp.sparse(x, None)
    rebuilt = jnp.zeros_like(x).at[idx].set(vals)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(rebuilt), atol=0)
    assert int(jnp.sum(dense != 0)) <= k


def test_topk_keeps_largest():
    x = jnp.array([0.1, -5.0, 3.0, 0.01, -0.2])
    out = C.top_k(2).dense(x, None)
    np.testing.assert_allclose(np.asarray(out), [0, -5.0, 3.0, 0, 0])


def test_identity_is_lossless():
    x = _vec(33, 0)
    assert float(jnp.sum((x - C.identity().dense(x, None)) ** 2)) == 0.0


def test_make_compressor_registry():
    assert C.make_compressor("top_k", k=3).name == "top_3"
    assert C.make_compressor("rand_k", k=3).needs_rng
    with pytest.raises(ValueError):
        C.make_compressor("nope")
