"""Optimizer substrate tests (SGD/momentum/Adam/QSGD/schedules/chain)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    adam,
    add_weight_decay,
    apply_updates,
    chain,
    clip_by_global_norm,
    qsgd,
    qsgd_quantize,
    schedules,
    sgd,
    sgd_momentum,
)


def _opt_quadratic(tx, steps=300, d=10, use_params=True):
    target = jnp.linspace(-1, 1, d)
    w = jnp.zeros(d)
    s = tx.init(w)
    for _ in range(steps):
        g = w - target
        u, s = tx.update(g, s, params=w if use_params else None)
        w = apply_updates(w, u)
    return float(jnp.linalg.norm(w - target))


def test_sgd_converges():
    assert _opt_quadratic(sgd(0.2)) < 1e-5


def test_momentum_converges():
    assert _opt_quadratic(sgd_momentum(0.05, 0.9)) < 1e-4


def test_nesterov_converges():
    assert _opt_quadratic(sgd_momentum(0.05, 0.9, nesterov=True)) < 1e-4


def test_adam_converges():
    assert _opt_quadratic(adam(0.05), steps=500) < 1e-3


def test_qsgd_converges_statistically():
    assert _opt_quadratic(qsgd(0.05, s=16), steps=800) < 0.05


def test_qsgd_quantize_unbiased():
    g = jax.random.normal(jax.random.PRNGKey(0), (500,))
    key = jax.random.PRNGKey(1)
    est = jnp.mean(
        jnp.stack([
            qsgd_quantize(g, 8, jax.random.fold_in(key, i)) for i in range(500)
        ]),
        axis=0,
    )
    rel = float(jnp.linalg.norm(est - g) / jnp.linalg.norm(g))
    assert rel < 0.15


def test_qsgd_quantize_levels():
    g = jnp.array([0.3, -0.7, 0.1])
    q = qsgd_quantize(g, 4, jax.random.PRNGKey(0))
    norm = float(jnp.linalg.norm(g))
    levels = np.abs(np.asarray(q)) / norm * 4
    np.testing.assert_allclose(levels, np.round(levels), atol=1e-5)


def test_weight_decay_adds_l2_term():
    wd = add_weight_decay(0.5)
    u, _ = wd.update({"w": jnp.ones(3)}, (), params={"w": 2 * jnp.ones(3)})
    np.testing.assert_allclose(np.asarray(u["w"]), 2.0)


def test_clip_by_global_norm():
    tx = clip_by_global_norm(1.0)
    g = {"w": jnp.array([3.0, 4.0])}  # norm 5
    u, _ = tx.update(g, ())
    np.testing.assert_allclose(float(jnp.linalg.norm(u["w"])), 1.0, rtol=1e-5)


def test_chain_composes():
    tx = chain(clip_by_global_norm(10.0), sgd(0.1))
    assert _opt_quadratic(tx) < 1e-4


def test_schedules():
    s = schedules.warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-5
    assert float(s(jnp.asarray(100))) < 1e-5
    lin = schedules.linear_decay(2.0, 10)
    assert float(lin(jnp.asarray(5))) == 1.0
    inv = schedules.inverse_time(2.0, 0.5, 4.0)
    assert float(inv(jnp.asarray(0))) == 1.0
