"""Platform setup + per-backend tables (repro.utils.platform).

Covers the ``REPRO_PALLAS_INTERPRET`` override both ways, the
backend-keyed top-k cutover table and its consumption by
``kernels.ops.row_topk(method="auto")`` / the distributed
``_pick_selection``, and the XLA flag merge (user flags never
overridden, GPU flags never leaked onto CPU runs).
"""
import pytest

from repro.kernels.ops import _resolve_method
from repro.utils import platform as pf


# --- REPRO_PALLAS_INTERPRET env override ---------------------------------

def test_interpret_env_force_on(monkeypatch):
    monkeypatch.setenv(pf.ENV_INTERPRET, "1")
    assert pf.pallas_interpret_default("tpu") is True
    assert pf.pallas_interpret_default("cpu") is True


def test_interpret_env_force_off(monkeypatch):
    monkeypatch.setenv(pf.ENV_INTERPRET, "0")
    assert pf.pallas_interpret_default("cpu") is False
    assert pf.pallas_interpret_default("gpu") is False


def test_interpret_env_invalid_raises(monkeypatch):
    monkeypatch.setenv(pf.ENV_INTERPRET, "yes")
    with pytest.raises(ValueError, match="REPRO_PALLAS_INTERPRET"):
        pf.pallas_interpret_default("cpu")


def test_interpret_backend_defaults(monkeypatch):
    monkeypatch.delenv(pf.ENV_INTERPRET, raising=False)
    # compiled lowerings exist on TPU (Mosaic) and GPU (Triton);
    # CPU falls back to interpret mode
    assert pf.pallas_interpret_default("tpu") is False
    assert pf.pallas_interpret_default("gpu") is False
    assert pf.pallas_interpret_default("cpu") is True
    # empty string == unset (a cleared CI variable)
    monkeypatch.setenv(pf.ENV_INTERPRET, "")
    assert pf.pallas_interpret_default("cpu") is True


def test_kernel_auto_interpret_consults_env(monkeypatch):
    from repro.kernels.topk_select import _auto_interpret

    monkeypatch.setenv(pf.ENV_INTERPRET, "0")
    assert _auto_interpret(None) is False
    monkeypatch.setenv(pf.ENV_INTERPRET, "1")
    assert _auto_interpret(None) is True
    # an explicit interpret= wins over the env var
    assert _auto_interpret(False) is False


# --- top-k loop/threshold cutover table ----------------------------------

def test_cutover_table_per_backend():
    assert pf.topk_loop_cutover("cpu") == pf.TOPK_LOOP_CUTOVER["cpu"]
    assert pf.topk_loop_cutover("tpu") == pf.TOPK_LOOP_CUTOVER["tpu"]
    # unknown backends get the conservative fallback, never a KeyError
    assert pf.topk_loop_cutover("rocm") == pf._CUTOVER_FALLBACK


def test_auto_method_matches_table():
    """``method="auto"`` flips from the argmax loop to the single-pass
    threshold select exactly at the active backend's cutover."""
    cut = pf.topk_loop_cutover()  # this process runs on CPU
    assert _resolve_method("auto", cut) == "loop"
    assert _resolve_method("auto", cut + 1) == "threshold"
    assert _resolve_method("loop", 64) == "loop"
    assert _resolve_method("threshold", 1) == "threshold"
    with pytest.raises(ValueError, match="method"):
        _resolve_method("bogus", 4)


def test_distributed_selection_uses_cutover():
    """threshold_onehot's tiny-k fallback keys off the same table."""
    from repro.core.distributed import (
        SyncConfig,
        _pick_selection,
        _row_topk_argmax,
        _row_topk_threshold,
    )

    cfg = SyncConfig(selection="threshold_onehot")
    cut = pf.topk_loop_cutover()
    assert _pick_selection(cfg, cut)[0] is _row_topk_argmax
    assert _pick_selection(cfg, cut + 1)[0] is _row_topk_threshold


# --- XLA flag merge / setup_platform -------------------------------------

def test_merge_xla_flags_dedup_and_preserve():
    merged = pf._merge_xla_flags(
        "--xla_gpu_enable_async_collectives=false --foo=1",
        pf.GPU_PERF_FLAGS,
    )
    parts = merged.split()
    # the user's explicit setting survives, un-duplicated
    assert parts.count("--xla_gpu_enable_async_collectives=false") == 1
    assert not any(
        p == "--xla_gpu_enable_async_collectives=true" for p in parts
    )
    # everything else appended once
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" in parts
    assert pf._merge_xla_flags("", ["--a=1"]) == "--a=1"


def test_setup_platform_env_and_config(monkeypatch):
    calls = []
    import jax

    monkeypatch.setattr(jax.config, "update",
                        lambda k, v: calls.append((k, v)))
    monkeypatch.setenv("XLA_FLAGS", "--keep=me")

    import os

    # CPU: host-device count appended, NO gpu flags leak (an XLA build
    # that does not know a flag treats XLA_FLAGS as fatal)
    pf.setup_platform("cpu", host_devices=8)
    flags = os.environ["XLA_FLAGS"].split()
    assert "--keep=me" in flags
    assert "--xla_force_host_platform_device_count=8" in flags
    assert not any("xla_gpu" in f for f in flags)
    assert calls == [("jax_platform_name", "cpu")]

    # GPU: perf flags injected; "cuda" aliases to the gpu platform name
    pf.setup_platform("cuda")
    flags = os.environ["XLA_FLAGS"].split()
    for f in pf.GPU_PERF_FLAGS:
        assert f in flags
    assert calls[-1] == ("jax_platform_name", "gpu")

    # perf_flags=False: platform pinned, flags untouched
    monkeypatch.setenv("XLA_FLAGS", "")
    pf.setup_platform("gpu", perf_flags=False)
    assert os.environ["XLA_FLAGS"] == ""
    assert calls[-1] == ("jax_platform_name", "gpu")


def test_setup_platform_none_is_flags_only(monkeypatch):
    import jax

    monkeypatch.setattr(
        jax.config, "update",
        lambda *_: pytest.fail("platform=None must not pin a platform"))
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    import os

    pf.setup_platform(None, host_devices=4)
    assert os.environ["XLA_FLAGS"] == (
        "--xla_force_host_platform_device_count=4")
    # and with nothing to do it must not create the variable at all
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    pf.setup_platform(None)
    assert "XLA_FLAGS" not in os.environ
