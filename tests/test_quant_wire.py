"""Quantized wire tier tests (repro.core.encoding quant=s +
repro.optim.qsgd.quantize_rows): round-trip, byte accounting, repack,
and the -0.0 masking identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import encoding as enc
from repro.optim.qsgd import quantize_rows


def _codes(rows, k, s, seed=0):
    """Random valid wire codes + norms for an (rows, k) selection."""
    kv, kn, kq = jax.random.split(jax.random.PRNGKey(seed), 3)
    vals = jax.random.normal(kv, (rows, k))
    norms, codes = quantize_rows(vals, s, kq)
    return norms, codes


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=7),
    # non-pow2 cols positions sit at the fallback sweep's SPREAD
    # sample indices (see test_wire_codec.py)
    cols=st.sampled_from([1024, 17, 1, 100, 3, 2, 1000, 700]),
    s=st.sampled_from([1, 3, 15, 255, 32767]),
)
def test_quant_roundtrip_property(rows, cols, s):
    """decode(encode(codes, idx, norms)) == dequantize_rows(norms,
    codes, s) BITWISE, and the accounted bytes equal the realized
    buffer size, for s in {1, 3, 15, ...} and non-power-of-two cols."""
    k = max(1, cols // 3)
    norms, codes = _codes(rows, k, s, seed=rows * cols + s)
    idx = jax.random.randint(
        jax.random.PRNGKey(1), (rows, k), 0, cols
    ).astype(jnp.int32)
    spec = enc.WireSpec(rows, cols, k, "float32", quant=s)
    # the encode is bit-exact under jit (pure integer packing); the
    # DEQUANT comparison stays eager — XLA may reassociate
    # norm*(level/s) across a jit boundary, and the in-jit bitwise
    # claim (decode == own-contribution densify inside ONE jitted
    # sync) is covered by core.selfcheck.local_quant_selfcheck
    buf_jit = jax.jit(lambda c, i, n: enc.encode(spec, c, i, norms=n))(
        codes, idx, norms)
    buf = enc.encode(spec, codes, idx, norms=norms)
    assert np.array_equal(np.asarray(buf_jit), np.asarray(buf))
    assert buf.shape == (spec.words,)
    # accounting == realized bytes
    assert buf.nbytes == enc.message_nbytes(
        rows, cols, k, "float32", wire="packed", quant=s)
    v2, i2 = enc.decode(spec, buf)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(idx))
    want = np.asarray(enc.dequantize_rows(norms, codes, s))
    got = np.asarray(v2)
    assert np.array_equal(got.view(np.uint8), want.view(np.uint8))
    # the raw reader hands back the exact code/norm stream
    n3, c3, i3 = enc.decode_quant(spec, buf)
    np.testing.assert_array_equal(np.asarray(c3), np.asarray(codes))
    np.testing.assert_array_equal(np.asarray(i3), np.asarray(idx))
    assert np.array_equal(np.asarray(n3).view(np.uint8),
                          np.asarray(norms).view(np.uint8))


def test_quant_code_bits_and_value_section():
    assert enc.quant_code_bits(1) == 2   # ternary: sign + 1 level bit
    assert enc.quant_code_bits(15) == 5
    assert enc.quant_code_bits(255) == 9
    # value section = one f32 norm word + packed codes
    spec = enc.WireSpec(4, 100, 10, "float32", quant=15)
    assert spec.value_words == 1 + -(-10 * 5 // 32)


def test_quant_negative_zero_identity():
    """A -0.0 input (the runtime-k padded tail) survives quantization:
    code 1 dequantizes to exactly -0.0, so decode+scatter-add is a
    no-op on padded slots."""
    vals = jnp.array([[1.0, -0.0, 0.0, -2.0]])
    norms, codes = quantize_rows(vals, 15, jax.random.PRNGKey(0))
    assert int(codes[0, 1]) == 1
    deq = np.asarray(enc.dequantize_rows(norms, codes, 15))
    assert deq[0, 1] == 0.0 and np.signbit(deq[0, 1])
    assert deq[0, 2] == 0.0 and not np.signbit(deq[0, 2])


def test_quantize_rows_levels_and_unbiasedness():
    """Levels stay in [0, s]; the stochastic rounding is unbiased —
    the mean dequantized value over many keys approaches the input."""
    s = 7
    vals = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    norms, codes = quantize_rows(vals, s, jax.random.PRNGKey(0))
    levels = np.asarray(codes >> 1)
    assert levels.min() >= 0 and levels.max() <= s
    acc = np.zeros(vals.shape, np.float64)
    N = 200
    for i in range(N):
        n, c = quantize_rows(vals, s, jax.random.PRNGKey(i))
        acc += np.asarray(enc.dequantize_rows(n, c, s), np.float64)
    err = np.abs(acc / N - np.asarray(vals, np.float64))
    # MC error ~ norm/(s*sqrt(N)); allow 5 sigma-ish slack
    tol = 5.0 * float(norms.max()) / (s * np.sqrt(N))
    assert err.max() < tol


def test_quant_zero_norm_row():
    norms, codes = quantize_rows(jnp.zeros((2, 8)), 15,
                                 jax.random.PRNGKey(0))
    assert float(jnp.max(jnp.abs(norms))) == 0.0
    assert int(jnp.max(codes >> 1)) == 0


def test_quant_repack_repad_bitwise():
    """Header-aware repack of a k-padded QUANTIZED message: the
    compacted buffer re-expands bitwise, and its bytes track the live
    k through the quantized accounting."""
    rows, cols, k_pad, live, s = 3, 257, 24, 5, 15
    norms, codes = _codes(rows, k_pad, s, seed=9)
    # contract-ordered: live pairs first, (-0.0, 0) identity tail
    codes = jnp.concatenate(
        [codes[:, :live], jnp.ones((rows, k_pad - live), jnp.int32)],
        axis=1)
    idx = jnp.concatenate(
        [jax.random.randint(jax.random.PRNGKey(2), (rows, live), 0, cols),
         jnp.zeros((rows, k_pad - live), jnp.int32)],
        axis=1).astype(jnp.int32)
    spec = enc.WireSpec(rows, cols, k_pad, "float32", quant=s)
    buf = enc.encode(spec, codes, idx, live_n=live, norms=norms)
    small_spec, small = enc.repack(spec, buf)
    assert small_spec.k == live and small_spec.quant == s
    assert small.nbytes == enc.message_nbytes(
        rows, cols, live, "float32", wire="packed", quant=s)
    back = enc.repad(spec, small_spec, small)
    assert np.array_equal(np.asarray(back), np.asarray(buf))


def test_quant_requires_sparse_f32():
    with pytest.raises(ValueError):
        enc.WireSpec(2, 8, 2, "bfloat16", quant=15)
    with pytest.raises(ValueError):
        enc.WireSpec(2, 8, 2, "float32", kind="dense", quant=15)
    with pytest.raises(ValueError):
        enc.WireSpec(2, 8, 2, "float32", quant=1 << 16)  # > _QUANT_MAX
