"""Double-buffered bucket pipeline tests (repro.core.pipeline).

Fast tier: planner legality properties (permutation, per-bucket order,
depth-window bound), the exact classic double-buffer order at depth 2,
degeneration to strict sequential at depth 1, value-identity of the
in-jit executor across depths, and the host pipeline actually hiding an
``EmulatedLink``'s latency behind younger buckets' compute. Slow tier:
the guarantee the feature ships on — ``SyncConfig.overlap`` in {None,
False, True} is BITWISE identical on applied params + memory across all
three sync paths on a real 8-device 2-pod mesh, including a mid-run
pod-k refresh (``repro.core.selfcheck.overlap_selfcheck``).
"""
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import (
    COMM,
    COMPUTE,
    REPACK,
    EmulatedLink,
    overlap_depth,
    plan_schedule,
    run_host_pipeline,
    run_schedule,
    validate_schedule,
)

from tests._hypothesis_compat import given, settings, st

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

E, G, R = COMPUTE, COMM, REPACK
FLAT = (E, G, E)          # select+encode / gather / decode+apply
HIER = (E, G, E, G, E)    # + pod re-select and the cross-pod gather
HIER_R = (E, G, E, R, G, E)  # + boundary repack before the cross-pod hop
DENSE = (G,)              # one all-reduce


def _sequential(kinds):
    return [(b, s) for b in range(len(kinds)) for s in range(len(kinds[b]))]


def test_depth1_is_strict_sequential():
    kinds = [FLAT, HIER, DENSE, FLAT]
    assert plan_schedule(kinds, 1) == _sequential(kinds)


def test_depth2_is_classic_double_buffer():
    """For uniform [E, G, E] buckets the depth-2 plan is the textbook
    software pipeline: bucket b+1's encode issues while bucket b's
    gather is in flight, and decodes drain one transfer behind."""
    kinds = [FLAT] * 4
    order = plan_schedule(kinds, 2)
    assert order == [
        (0, 0), (0, 1), (1, 0),   # E0, G0 issues, E1 hides behind it
        (0, 2), (1, 1), (2, 0),   # D0 drains, G1 issues, E2 hides
        (1, 2), (2, 1), (3, 0),
        (2, 2), (3, 1),
        (3, 2),                   # tail drains
    ]
    validate_schedule(order, kinds, 2)
    # every gather except the last has a younger bucket's compute
    # scheduled between its issue and its bucket's next stage
    pos = {bs: i for i, bs in enumerate(order)}
    for b in range(3):
        between = order[pos[(b, 1)] + 1: pos[(b, 2)]]
        assert any(kinds[b2][s2] == COMPUTE and b2 > b
                   for b2, s2 in between), (b, order)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=1, max_value=6),
       depth=st.integers(min_value=1, max_value=4),
       mix=st.integers(min_value=0, max_value=2))
def test_planner_always_legal(n, depth, mix):
    shapes = [FLAT, HIER, DENSE]
    kinds = [shapes[(b + mix) % 3] for b in range(n)]
    order = plan_schedule(kinds, depth)
    validate_schedule(order, kinds, depth)
    # depth >= n can never beat the full-width schedule; depth 1 is
    # exactly sequential
    assert plan_schedule(kinds, 1) == _sequential(kinds)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=1, max_value=6),
       depth=st.integers(min_value=1, max_value=4),
       mix=st.integers(min_value=0, max_value=3))
def test_planner_legal_with_repack_chains(n, depth, mix):
    """The 6-stage repack chain (E, G, E, R, G, E) mixes with every
    other bucket shape at every depth; REPACK schedules like a local
    stage (the planner only yields at COMM issues), so the plan stays
    legal and depth 1 stays exactly sequential."""
    shapes = [HIER_R, FLAT, HIER, DENSE]
    kinds = [shapes[(b + mix) % 4] for b in range(n)]
    order = plan_schedule(kinds, depth)
    validate_schedule(order, kinds, depth)
    assert plan_schedule(kinds, 1) == _sequential(kinds)
    # the repack stage is never left dangling across a bucket's own
    # cross-pod gather: within each bucket, R immediately precedes the
    # second COMM in program order (per-bucket order is monotone)
    pos = {bs: i for i, bs in enumerate(order)}
    for b, ks in enumerate(kinds):
        if ks is HIER_R:
            assert pos[(b, 3)] < pos[(b, 4)]


def test_repack_stage_transparent_to_overlap_structure():
    """Inserting the R stage must not perturb the overlap structure:
    dropping every (b, 3) from the depth-2 repack-chain schedule and
    renumbering the later stages yields EXACTLY the plain hierarchical
    schedule, and each R lands immediately before its bucket's cross-pod
    gather issue (repack runs boundary-side, just in time for the hop)."""
    for depth in (1, 2, 3):
        order = plan_schedule([HIER_R] * 3, depth)
        validate_schedule(order, [HIER_R] * 3, depth)
        squeezed = [(b, s if s < 3 else s - 1)
                    for b, s in order if s != 3]
        assert squeezed == plan_schedule([HIER] * 3, depth), depth
        pos = {bs: i for i, bs in enumerate(order)}
        for b in range(3):
            assert pos[(b, 4)] == pos[(b, 3)] + 1, (depth, order)


def test_validate_schedule_rejects_violations():
    kinds = [FLAT, FLAT, FLAT]
    good = plan_schedule(kinds, 1)
    with pytest.raises(AssertionError, match="permutation"):
        validate_schedule(good[:-1], kinds, 1)
    bad = list(good)
    bad[0], bad[1] = bad[1], bad[0]  # stage 1 before stage 0
    with pytest.raises(AssertionError):
        validate_schedule(bad, kinds, 1)
    # depth-2 plan violates the depth-1 window
    with pytest.raises(AssertionError, match="window"):
        validate_schedule(plan_schedule([FLAT] * 4, 2), [FLAT] * 4, 1)
    with pytest.raises(ValueError, match="depth"):
        plan_schedule(kinds, 0)
    with pytest.raises(ValueError, match="kind"):
        plan_schedule([("compute", "mystery")], 1)


def test_overlap_depth_mapping():
    assert overlap_depth(None) is None
    assert overlap_depth(False) == 1
    assert overlap_depth(True) == 2


def _toy_chains(n=4):
    """n independent 3-stage chains over arrays, with a fake comm stage
    (a roll — any value-preserving op) so all depths must agree."""
    inits = [jnp.arange(8.0) * (b + 1) for b in range(n)]
    stage_lists = [
        [lambda x: jnp.sin(x) + 1.0,
         lambda x: jnp.roll(x, 1),
         lambda x: (x * 2.0, jnp.cumsum(x))]
        for _ in range(n)
    ]
    kinds = [FLAT] * n
    return inits, stage_lists, kinds


def test_run_schedule_value_identity_across_depths():
    """The in-jit executor returns bitwise-equal results at every depth
    (barriers only order, never transform) — under jit, where the
    barrier actually lowers."""
    inits, stage_lists, kinds = _toy_chains()

    def run(depth):
        return jax.jit(
            lambda xs: run_schedule(xs, stage_lists, kinds, depth)
        )(inits)

    ref = run(None)
    for depth in (1, 2, 3):
        out = run(depth)
        for (a1, a2), (b1, b2) in zip(ref, out):
            assert np.array_equal(np.asarray(a1).view(np.uint8),
                                  np.asarray(b1).view(np.uint8))
            assert np.array_equal(np.asarray(a2).view(np.uint8),
                                  np.asarray(b2).view(np.uint8))


def test_host_pipeline_matches_and_overlaps():
    """The host executor over an ``EmulatedLink``: (1) results equal the
    sequential run bit for bit, (2) depth 2 hides the transfer latency
    behind the next bucket's compute — with compute time ~= wire time
    the pipelined wall clock must land well under the serial sum."""
    n, delay = 4, 0.03
    rng = np.random.default_rng(0)
    data = [rng.standard_normal(64).astype(np.float32) for _ in range(n)]

    def make(link):
        def compute1(x):
            time.sleep(delay)
            return np.tanh(x)

        def comm(x):
            return link.transfer(x, x.nbytes)

        def compute2(x):
            return (x * 2.0).sum()

        return [[compute1, comm, compute2] for _ in range(n)]

    def run(depth):
        link = EmulatedLink(latency_s=delay)
        t0 = time.monotonic()
        out = run_host_pipeline(list(data), make(link), [FLAT] * n, depth)
        return out, time.monotonic() - t0

    out1, t1 = run(1)
    out2, t2 = run(2)
    assert [float(a) for a in out1] == [float(a) for a in out2]
    # serial: n*(compute+wire) ~ 8*delay. pipelined: ~ (n+1)*delay.
    # assert with a wide margin so scheduler jitter can't flake this.
    assert t2 < t1 - 1.5 * delay, (t1, t2)


def test_emulated_link_serializes_transfers():
    link = EmulatedLink(latency_s=0.01, bandwidth_Bps=1e6)
    f1 = link.transfer("a", 10_000)  # 10ms latency + 10ms wire
    f2 = link.transfer("b", 10_000)
    assert f1.result() == "a" and f2.result() == "b"
    (i1, d1), (i2, d2) = link.transfers
    assert d2 >= d1 + link.delay_for(10_000) - 1e-6  # no double-booking
    assert link.delay_for(10_000) == pytest.approx(0.02)


def test_run_schedule_none_depth_needs_no_kinds_order():
    """depth=None (legacy emission) must not even consult the planner —
    it is the exact bucket-after-bucket fold."""
    inits, stage_lists, kinds = _toy_chains(2)
    out = run_schedule(inits, stage_lists, kinds, None)
    st0 = inits[0]
    for f in stage_lists[0]:
        st0 = f(st0)
    assert np.array_equal(np.asarray(out[0][1]), np.asarray(st0[1]))


_SUBPROCESS_CACHE: dict = {}


@pytest.mark.slow
def test_overlap_bitwise_identity_all_paths():
    """flat / hierarchical / pod-dynamic (with a mid-run live-k switch)
    on a real 2-pod x 4-worker mesh: overlap in {None, False, True}
    applies BITWISE identical params and memory
    (``repro.core.selfcheck.overlap_selfcheck``)."""
    key = "overlap_selfcheck"
    body = """
        from repro.core.selfcheck import overlap_selfcheck
        from repro.utils.compat import make_mesh

        rec = overlap_selfcheck(make_mesh((2, 4), ("pod", "data")))
        print(json.dumps(rec))
        """
    if key not in _SUBPROCESS_CACHE:
        _SUBPROCESS_CACHE[key] = _run_subprocess(body)
    rec = _SUBPROCESS_CACHE[key]
    assert rec["flat_bitwise"], rec
    assert rec["hierarchical_bitwise"], rec
    assert rec["pod_dynamic_bitwise"], rec
    assert rec["bitwise_all"], rec


@pytest.mark.slow
@settings(max_examples=3, deadline=None)
@given(wire=st.sampled_from(["unpacked", "packed"]))
def test_overlap_bitwise_identity_per_wire(wire):
    """The identity holds for both wire formats (the packed encode/
    decode split across pipeline stages is the riskier path)."""
    body = """
        from repro.core.selfcheck import overlap_selfcheck
        from repro.utils.compat import make_mesh

        rec = overlap_selfcheck(make_mesh((2, 4), ("pod", "data")),
                                wire={wire!r})
        print(json.dumps(rec))
        """
    if wire not in _SUBPROCESS_CACHE:
        _SUBPROCESS_CACHE[wire] = _run_subprocess(body.format(wire=wire))
    assert _SUBPROCESS_CACHE[wire]["bitwise_all"], _SUBPROCESS_CACHE[wire]


@pytest.mark.slow
def test_repack_bitwise_identity_and_transport():
    """``SyncConfig.repack`` on a real 2-pod mesh: the in-jit R stage is
    bitwise inert across overlap modes and a live-k switch, the host
    ``repack_transport`` round-trips the padded buffer bitwise (inline
    and over an ``EmulatedLink``), and its realized bytes equal the
    live-k accounting exactly (``repro.core.selfcheck.repack_selfcheck``)."""
    key = "repack_selfcheck"
    body = """
        from repro.core.selfcheck import repack_selfcheck
        from repro.utils.compat import make_mesh

        rec = repack_selfcheck(make_mesh((2, 4), ("pod", "data")))
        print(json.dumps(rec))
        """
    if key not in _SUBPROCESS_CACHE:
        _SUBPROCESS_CACHE[key] = _run_subprocess(body)
    rec = _SUBPROCESS_CACHE[key]
    assert rec["repack_bitwise"], rec
    assert rec["transport_roundtrip_bitwise"], rec
    assert rec["transport_accounting_exact"], rec
    padded, live = rec["padded_vs_live_bytes"]
    assert live < padded, rec


def _run_subprocess(body: str) -> dict:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        import numpy as np
        """
    ).format(src=SRC) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])
