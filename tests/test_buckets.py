"""Bucketed flat-buffer engine tests (repro.core.buckets)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buckets as bk
from repro.core import compression as C
from repro.core.memsgd import constant_eta, memsgd_bucketed

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _tree(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    return {
        "w1": jax.random.normal(ks[0], (128, 256)),           # sparse f32
        "w2": jax.random.normal(ks[1], (300, 70)),            # sparse f32
        "h": jax.random.normal(ks[2], (200, 100)).astype(jnp.bfloat16),
        "b": jax.random.normal(ks[3], (64,)),                 # dense f32
        "s": jax.random.normal(ks[4], (8, 16)),               # dense f32
        "hb": jax.random.normal(ks[5], (33,)).astype(jnp.bfloat16),
    }


def test_plan_groups_by_dtype_and_route():
    plan = bk.make_plan(_tree(), cols=1024, dense_below=16384)
    kinds = sorted((s.dtype, s.kind) for s in plan.buckets)
    assert kinds == [
        ("bfloat16", "dense"),
        ("bfloat16", "sparse"),
        ("float32", "dense"),
        ("float32", "sparse"),
    ]
    assert plan.n_dispatch <= 4  # the whole point of the engine
    for spec in plan.buckets:
        assert spec.rows * spec.cols >= spec.size


def test_plan_works_on_abstract_shapes():
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree()
    )
    plan_a = bk.make_plan(shapes)
    plan_c = bk.make_plan(_tree())
    assert plan_a.buckets == plan_c.buckets
    assert plan_a.placements == plan_c.placements


def test_pack_unpack_roundtrip_exact():
    tree = _tree()
    plan = bk.make_plan(tree)
    out = bk.unpack(plan, bk.pack(plan, tree), cast=True)
    for k in tree:
        assert out[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(
            np.asarray(out[k], np.float32), np.asarray(tree[k], np.float32)
        )


def test_bucket_memory_step_conservation_and_contraction():
    """new_m + applied == m + eta*g per sparse bucket (error-feedback
    conservation), and the per-bucket selection equals blockwise top-k
    over the concatenated leaves (Definition 2.1 contraction)."""
    tree = _tree()
    plan = bk.make_plan(tree, cols=512, dense_below=16384)
    mem = bk.init_bucket_memory(plan)
    eta = 0.7
    k_for = lambda c: max(1, c // 64)
    applied, new_mem, n = bk.bucket_memory_step(
        plan, mem, tree, eta, k_for
    )
    assert n == plan.n_dispatch
    g_bufs = bk.pack(plan, tree, dtype=jnp.float32)
    a_bufs = bk.pack(plan, applied, dtype=jnp.float32)
    for spec, m0, g, nm, a in zip(plan.buckets, mem, g_bufs, new_mem, a_bufs):
        u = m0 + eta * g
        np.testing.assert_allclose(
            np.asarray(nm + a), np.asarray(u), atol=1e-5
        )
        if spec.kind == "dense":
            np.testing.assert_array_equal(np.asarray(nm), 0.0)
            continue
        # equivalence with the framework-level blockwise compressor
        comp = C.blockwise_top_k(k_for(spec.cols), spec.cols)
        want = comp.dense(u.reshape(-1)[: spec.size], None)
        got = np.asarray(a).reshape(-1)[: spec.size]
        np.testing.assert_allclose(got, np.asarray(want), atol=1e-6)


def test_memsgd_bucketed_transform_converges():
    """Algorithm 1 through the bucketed engine drives a quadratic to its
    optimum (error feedback must re-inject suppressed coordinates)."""
    target = {
        "a": jnp.ones((64, 300)),
        "c": jnp.full((32,), 2.0),
    }
    w = jax.tree.map(jnp.zeros_like, target)
    # eta must respect the error-feedback stability limit ~ O(k/d): the
    # selection delay is d/k = 10 steps here.
    tx = memsgd_bucketed(0.1, constant_eta(0.05), cols=256, dense_below=64)
    state = tx.init(w)
    assert len(state.memory) == 2  # sparse + dense bucket
    for _ in range(250):
        grads = jax.tree.map(lambda x, t: x - t, w, target)
        updates, state = tx.update(grads, state)
        w = jax.tree.map(lambda x, u: x + u, w, updates)
    err = max(
        float(jnp.max(jnp.abs(w[k] - target[k]))) for k in target
    )
    assert err < 1e-2, err


def test_bucketed_sync_single_worker_matches_memory_step():
    """On a 1-worker mesh the synced update equals the worker's own
    selection (mean over one worker), and the memories agree."""
    from repro.core.distributed import SyncConfig, bucketed_sync_gradients
    from repro.utils.compat import shard_map

    tree = _tree()
    plan = bk.make_plan(tree, cols=512)
    mem = bk.init_bucket_memory(plan)
    cfg = SyncConfig(ratio=0.02, bucketed=True, bucket_cols=512,
                     selection="threshold_onehot")
    mesh = jax.make_mesh((1,), ("data",))

    def body(mem, tree):
        upd, new_mem, _ = bucketed_sync_gradients(
            cfg, plan, mem, tree, jnp.float32(0.3)
        )
        return upd, new_mem

    upd, new_mem = shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: jax.sharding.PartitionSpec(), mem),
                  jax.tree.map(lambda _: jax.sharding.PartitionSpec(), tree)),
        out_specs=(jax.tree.map(lambda _: jax.sharding.PartitionSpec(), tree),
                   jax.tree.map(lambda _: jax.sharding.PartitionSpec(), mem)),
    )(mem, tree)

    k_for = lambda c: cfg.k_for(c)
    applied, want_mem, _ = bk.bucket_memory_step(
        plan, mem, tree, 0.3, k_for
    )
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(upd[k]), np.asarray(applied[k]), atol=1e-5
        )
    for got, want in zip(new_mem, want_mem):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=1e-5
        )


@pytest.mark.slow
def test_distributed_bucketed_memsgd_loss_decreases():
    """Full train step with sync.bucketed on a 4-worker mesh (model=1)."""
    import json
    import textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        """
    ).format(src=SRC) + textwrap.dedent(
        """
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.train import (TrainConfig, make_train_step,
                                        init_train_state, state_shardings)
        from repro.core.distributed import SyncConfig
        from repro.data import token_batches
        from repro.data.pipeline import ShardedBatcher

        mesh = make_debug_mesh(4, 1)
        cfg = get_smoke_config("qwen3-4b")
        model = build_model(cfg)
        tc = TrainConfig(optimizer="memsgd", eta=0.5,
                         sync=SyncConfig(ratio=0.02, bucketed=True,
                                         selection="threshold_onehot"))
        params, memory, opt, count = init_train_state(
            model, mesh, tc, rng=jax.random.PRNGKey(0))
        pshard, mshard, oshard, _ = state_shardings(model, mesh, tc)
        params = jax.device_put(params, pshard)
        memory = jax.device_put(memory, mshard)
        step = make_train_step(model, mesh, tc)
        it = ShardedBatcher(mesh, token_batches(cfg.vocab_size, 8, 64,
                            seed=1), prefetch=0)
        losses = []
        for i, batch in enumerate(it):
            if i >= 12: break
            params, memory, opt, count, m = step(params, memory, opt,
                                                 count, batch)
            losses.append(float(m["loss"]))
        print(json.dumps({"first": losses[0], "last": losses[-1],
                          "n_buckets": len(memory)}))
        """
    )
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["last"] < rec["first"]
    assert rec["n_buckets"] <= 4
