"""Fan-out hub, replay catch-up, bf16 tier, snapshot codec and wire
checkpoints (repro.launch.fanout, repro.core.encoding snapshot records,
repro.checkpoint.Checkpointer.save_wire).

Fast tests drive the hub with a SYNTHETIC sparse update stream: per-step
bucket updates with support <= the delta spec's k' bound, so the packed
encode captures them exactly — the same contract the trainer guarantees
(see repro.launch.delta_stream). The slow subprocess test replays the
real trainer end to end on 4 fake devices."""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.checkpoint import Checkpointer
from repro.core import buckets as bk
from repro.core import encoding as enc
from repro.core.distributed import SyncConfig, _row_scatter, _row_topk
from repro.launch import delta_stream as ds
from repro.launch.fanout import FanoutHub

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# -- synthetic trainer-side stream -------------------------------------------


def _plan_and_spec(workers: int = 2):
    tree = {
        "w": jax.ShapeDtypeStruct((100, 300), jnp.float32),
        "b": jax.ShapeDtypeStruct((40,), jnp.float32),
    }
    plan = bk.make_plan(tree, cols=256, dense_below=512)
    cfg = SyncConfig(ratio=0.05, bucketed=True, bucket_cols=256)
    return plan, ds.make_delta_spec(plan, cfg, workers=workers)


def _update_bufs(plan, dspec, seed):
    """Per-bucket update buffers with support <= each wire's k — the
    invariant the trainer's synced update satisfies by construction."""
    bufs = []
    for i, (spec, w) in enumerate(zip(plan.buckets, dspec.wires)):
        g = jax.random.normal(jax.random.PRNGKey(seed * 13 + i), spec.shape)
        if spec.kind == "dense":
            bufs.append(g * 0.01)
        else:
            vals, idx = _row_topk(g, w.k)
            bufs.append(_row_scatter(spec.shape, vals, idx, jnp.float32))
    return bufs


def _init_params():
    return {
        "w": jax.random.normal(jax.random.PRNGKey(99), (100, 300)),
        "b": jax.random.normal(jax.random.PRNGKey(98), (40,)),
    }


def _bitwise(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x).view(np.uint8),
                       np.asarray(y).view(np.uint8))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def _run_stream(hub, plan, dspec, trainer, steps, *, start=0, on_step=None):
    """Publish ``steps`` synthetic updates; apply them to ``trainer`` the
    way the train step does (p - u). Returns the new trainer params."""
    for t in range(start, start + steps):
        bufs = _update_bufs(plan, dspec, t)
        hub.publish(t, ds.encode_delta_bufs(dspec, bufs))
        trainer = jax.tree.map(
            lambda p, u: p - u.astype(p.dtype), trainer,
            bk.unpack(plan, bufs),
        )
        if on_step is not None:
            on_step(t)
    return trainer


# -- replay catch-up ----------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    # shim sweep runs the FIRST samples: lead with the snapshot-forcing
    # cases (join long after the log start) and the full-replay edge 0
    join_step=st.sampled_from([12, 0, 9, 4, 11]),
)
def test_replay_catchup_property(join_step):
    """A replica joining at ANY step and syncing after every subsequent
    publish ends bitwise-equal to the trainer. Joins beyond the log
    bound go through a snapshot resync first; joins inside it replay
    wire messages only."""
    T, log_bound = 12, 5
    plan, dspec = _plan_and_spec()
    trainer = _init_params()
    hub = FanoutHub(dspec, trainer, log_bound=log_bound)
    trainer = _run_stream(hub, plan, dspec, trainer, join_step)
    replica = hub.join()
    hub.sync(replica)
    expect_resync = join_step > log_bound
    assert replica.resyncs == (1 if expect_resync else 0)
    assert _bitwise(trainer, replica.params)
    trainer = _run_stream(
        hub, plan, dspec, trainer, T - join_step, start=join_step,
        on_step=lambda t: hub.sync(replica),
    )
    assert replica.cursor == T
    assert _bitwise(trainer, replica.params)
    assert _bitwise(trainer, hub.shadow)
    if join_step < T:  # everything after the join was replayed exactly
        assert replica.steps_replayed >= T - join_step


def test_lagged_replica_snapshot_resync_and_replay_tail():
    """A replica that stops syncing falls off the log; the next sync
    restores from the cached periodic snapshot (wire-compressed diff vs
    base) and replays only the tail — still bitwise-equal."""
    plan, dspec = _plan_and_spec()
    trainer = _init_params()
    hub = FanoutHub(dspec, trainer, log_bound=6, snapshot_every=4)
    replica = hub.join()
    trainer = _run_stream(hub, plan, dspec, trainer, 15)
    hub.sync(replica)
    assert replica.resyncs == 1
    assert 0 < replica.steps_replayed <= 6  # only the post-snapshot tail
    assert _bitwise(trainer, replica.params)
    # the resync moved fewer bytes than replaying the whole stream
    full_replay = 15 * dspec.nbytes
    assert replica.bytes_rx < full_replay


def test_publish_out_of_order_rejected():
    plan, dspec = _plan_and_spec()
    hub = FanoutHub(dspec, _init_params(), log_bound=4)
    bufs = _update_bufs(plan, dspec, 0)
    hub.publish(0, ds.encode_delta_bufs(dspec, bufs))
    with pytest.raises(ValueError):
        hub.publish(2, ds.encode_delta_bufs(dspec, bufs))
    with pytest.raises(ValueError):
        FanoutHub(dspec, _init_params(), log_bound=4, snapshot_every=9)


# -- bf16 tier ---------------------------------------------------------------


def test_bf16_tier_drift_bounded_over_10_steps():
    """The lossy tier's parameter drift after 10 steps stays under the
    stated bound: the sum of per-step transcode rounding errors
    ``||u_t - bf16(u_t)||_inf`` (f32 accumulation error is orders of
    magnitude below the bf16 rounding and covered by the 1% slack)."""
    T = 10
    plan, dspec = _plan_and_spec()
    trainer = _init_params()
    hub = FanoutHub(dspec, trainer, log_bound=T)
    exact = hub.join()
    lossy = hub.join("bfloat16")
    trainer = _run_stream(
        hub, plan, dspec, trainer, T,
        on_step=lambda t: (hub.sync(exact), hub.sync(lossy)),
    )
    assert _bitwise(trainer, exact.params)
    bound = hub.drift_bound("bfloat16")  # log covers all T steps here
    drift = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(trainer),
                        jax.tree.leaves(lossy.params))
    )
    assert 0 < drift <= bound * 1.01 + 1e-6, (drift, bound)
    # the lossy tier is the cheaper one, and both beat a dense broadcast
    assert lossy.bytes_rx < exact.bytes_rx
    assert exact.bytes_rx < T * dspec.dense_nbytes


def test_transcode_delta_matches_direct_bf16_encode():
    """Hub-side f32->bf16 transcode produces byte-identical messages to
    encoding the update with a bf16 delta spec directly."""
    plan, dspec = _plan_and_spec()
    bufs = _update_bufs(plan, dspec, 5)
    f32_msgs = ds.encode_delta_bufs(dspec, bufs)
    via_transcode = ds.transcode_delta(dspec, f32_msgs, "bfloat16")
    direct = ds.encode_delta_bufs(dspec.with_value_dtype("bfloat16"), bufs)
    for a, b in zip(via_transcode, direct):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# -- snapshot records --------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    # first samples matter for the shim: sparse diff, full-support
    # (dense fallback), empty diff, single column, tie to base
    support=st.sampled_from([3, 256, 0, 1, 100]),
)
def test_snapshot_diff_roundtrip_bitwise(support):
    base = jax.random.normal(jax.random.PRNGKey(1), (8, 256))
    cur = base
    if support:
        cols = jnp.arange(support)
        cur = base.at[jnp.arange(8)[:, None], cols[None, :]].add(1.0)
    rec = enc.snapshot_encode(cur, base=base)
    assert rec.exact
    out = enc.snapshot_decode(rec, base=base)
    assert np.array_equal(
        np.asarray(out).view(np.uint8), np.asarray(cur).view(np.uint8)
    )
    # exact size accounting: spec bytes == realized buffer bytes
    assert rec.nbytes == np.asarray(rec.buf).size * 4
    if 0 < support <= 100:
        assert rec.nbytes < rec.dense_nbytes
    if support == 256:
        assert rec.spec.kind == "dense"  # fallback, never worse than dense


def test_snapshot_diff_sees_signed_zero():
    """The support mask compares BIT PATTERNS: an entry that changed
    from +0.0 to -0.0 (float == can't see it) must still be captured,
    or the 'exact' record would restore the wrong sign bit."""
    base = jnp.zeros((2, 8))
    cur = base.at[0, 3].set(-0.0)
    rec = enc.snapshot_encode(cur, base=base)
    assert rec.exact
    out = enc.snapshot_decode(rec, base=base)
    assert np.array_equal(
        np.asarray(out).view(np.uint8), np.asarray(cur).view(np.uint8)
    )
    # and without a base, -0.0 counts as a set entry
    rec2 = enc.snapshot_encode(cur)
    out2 = enc.snapshot_decode(rec2)
    assert np.array_equal(
        np.asarray(out2).view(np.uint8), np.asarray(cur).view(np.uint8)
    )


def test_snapshot_lossy_topk_support_exact():
    m = jax.random.normal(jax.random.PRNGKey(2), (16, 256))
    rec = enc.snapshot_encode(m, k=16)
    assert not rec.exact and 0.0 < rec.dropped_frac < 1.0
    out = np.asarray(enc.snapshot_decode(rec))
    assert (np.count_nonzero(out, axis=1) <= 16).all()
    kept = out != 0
    assert np.array_equal(np.asarray(m)[kept], out[kept])
    assert rec.nbytes < rec.dense_nbytes / 4
    # a zero buffer compresses to the minimal 1-slot message, exactly
    z = enc.snapshot_encode(jnp.zeros((16, 256)), k=16)
    assert z.exact and z.spec.k == 1


# -- wire checkpoints --------------------------------------------------------


def test_checkpointer_wire_roundtrip_and_size():
    plan, dspec = _plan_and_spec()
    base = _init_params()
    trainer = _init_params()
    hub = FanoutHub(dspec, trainer, log_bound=8)
    trainer = _run_stream(hub, plan, dspec, trainer, 6)
    W = 4
    memory = tuple(
        jax.random.normal(jax.random.PRNGKey(7 + i), (W,) + s.shape) * 0.01
        for i, s in enumerate(plan.buckets)
    )
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, max_to_keep=2)
        path = ck.save_wire(6, trainer, memory, plan, base_params=base,
                            memory_ratio=0.1)
        params2, mem2, meta = ck.restore_wire(plan=plan, base_params=base)
        assert _bitwise(trainer, params2)
        w = meta["wire"]
        # measurably smaller than the dense f32 dump, accounting exact
        assert w["nbytes"] * 3 < w["dense_nbytes"]
        realized = sum(
            np.load(path)[k].size * 4 for k in np.load(path).files
        )
        assert w["nbytes"] == realized
        # memory: bitwise on the kept support, shapes/dtypes preserved
        for m, m2 in zip(memory, mem2):
            assert m.shape == m2.shape
            kept = np.asarray(m2) != 0
            assert np.array_equal(np.asarray(m)[kept], np.asarray(m2)[kept])
        # diff-encoded restore demands the base tree
        with pytest.raises(ValueError):
            ck.restore_wire(plan=plan)
        # no base -> dense-fallback params records, still exact
        ck.save_wire(7, trainer, memory, plan, memory_ratio=0.1)
        params3, _, meta3 = ck.restore_wire(7, plan=plan)
        assert _bitwise(trainer, params3)
        assert meta3["wire"]["nbytes"] > meta["wire"]["nbytes"]
        # gc keeps the newest max_to_keep wire checkpoints
        ck.save_wire(8, trainer, memory, plan, memory_ratio=0.1)
        assert ck.wire_steps() == [7, 8]


# -- donate_argnums at the serve boundary ------------------------------------


def test_replica_copy_survives_trainer_donation():
    """Stepping the (donating) train step must never invalidate a held
    replica: replica_copy makes fresh buffers, so the replica stays
    readable and bitwise-equal to the pre-step params."""
    from repro.configs import get_smoke_config
    from repro.data import token_batches
    from repro.data.pipeline import ShardedBatcher
    from repro.launch.serve import replica_copy
    from repro.launch.train import (TrainConfig, init_train_state,
                                    make_train_step, state_shardings)
    from repro.models import build_model
    from repro.utils.compat import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke_config("rwkv6-3b")
    model = build_model(cfg)
    tc = TrainConfig(optimizer="memsgd", eta=0.5,
                     sync=SyncConfig(ratio=0.02, bucketed=True))
    params, memory, opt, count = init_train_state(
        model, mesh, tc, rng=jax.random.PRNGKey(0))
    replica = replica_copy(params)
    before = [np.asarray(x).copy() for x in jax.tree.leaves(replica)]
    pshard, mshard, _, _ = state_shardings(model, mesh, tc)
    params = jax.device_put(params, pshard)
    memory = jax.device_put(memory, mshard)
    step = make_train_step(model, mesh, tc)
    batch = next(iter(ShardedBatcher(
        mesh, token_batches(cfg.vocab_size, 4, 32, seed=0), prefetch=0)))
    params, memory, opt, count, _ = step(params, memory, opt, count, batch)
    # the held replica is still alive and untouched after the donation
    after = jax.tree.leaves(replica)
    for b, a in zip(before, after):
        assert not a.is_deleted()
        assert np.array_equal(b, np.asarray(a))
    # and the trainer really moved away from it
    assert not _bitwise(params, replica)


# -- end-to-end with the real trainer (subprocess, 4 fake devices) -----------


def _run_subprocess(body: str) -> dict:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys, json
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        import numpy as np
        """
    ).format(src=SRC) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_fanout_replicas_track_real_trainer():
    """Acceptance: replicas subscribed at different steps — one steady,
    one joining mid-stream inside the log, one joining past the replay
    bound (forcing a wire-compressed snapshot resync) — all end
    bitwise-equal to the real Mem-SGD trainer on the f32 tier, while a
    bf16-tier replica stays within the hub's drift bound."""
    rec = _run_subprocess(
        """
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.train import (TrainConfig, make_train_step,
                                        init_train_state, state_shardings)
        from repro.launch.fanout import FanoutHub
        from repro.core.distributed import SyncConfig
        from repro.data import token_batches
        from repro.data.pipeline import ShardedBatcher

        mesh = make_debug_mesh(4, 1)
        cfg = get_smoke_config("rwkv6-3b")
        model = build_model(cfg)
        tc = TrainConfig(optimizer="memsgd", eta=0.5, emit_deltas=True,
                         sync=SyncConfig(ratio=0.02, bucketed=True,
                                         wire="packed"))
        params, memory, opt, count = init_train_state(
            model, mesh, tc, rng=jax.random.PRNGKey(0))
        step = make_train_step(model, mesh, tc)
        dspec = step.delta_spec
        hub = FanoutHub(dspec, params, log_bound=3, snapshot_every=2)
        steady = hub.join(); lossy = hub.join("bfloat16")
        pshard, mshard, _, _ = state_shardings(model, mesh, tc)
        params = jax.device_put(params, pshard)
        memory = jax.device_put(memory, mshard)
        it = ShardedBatcher(mesh, token_batches(cfg.vocab_size, 8, 32,
                            seed=1), prefetch=0)
        from repro.launch import delta_stream as dsm

        mid = None
        T = 6
        bound = 0.0  # accumulated per step: the log only spans 3 steps
        for i, batch in enumerate(it):
            if i >= T: break
            params, memory, opt, count, m, delta = step(
                params, memory, opt, count, batch)
            hub.publish(i, delta)
            exact_u = dsm.decode_delta(dspec, delta)
            lossy_u = dsm.decode_delta(
                dspec.with_value_dtype("bfloat16"),
                dsm.transcode_delta(dspec, delta, "bfloat16"))
            bound += max(
                float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(exact_u),
                                jax.tree.leaves(lossy_u)))
            hub.sync(steady); hub.sync(lossy)
            if i == 3:
                mid = hub.join(); hub.sync(mid)  # cursor 0 < log start
        late = hub.join()  # joins at T, log covers [T-3, T) -> snapshot
        hub.sync(late); hub.sync(mid)

        def bitwise(a, b):
            return all(
                np.array_equal(np.asarray(x).view(np.uint8),
                               np.asarray(y).view(np.uint8))
                for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

        drift = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(params),
                            jax.tree.leaves(lossy.params)))
        snap_step, snap_recs, snap_bytes = hub.snapshot()
        print(json.dumps({
            "steady": bitwise(params, steady.params),
            "mid": bitwise(params, mid.params),
            "late": bitwise(params, late.params),
            "late_resyncs": late.resyncs,
            "mid_resyncs": mid.resyncs,
            "drift_ok": bool(0 < drift),
            "drift_under_bound": bool(drift <= bound * 1.01 + 1e-6),
            "snap_bytes": snap_bytes,
            "snap_dense": sum(r.dense_nbytes for r in snap_recs),
            "stats": hub.stats(),
        }))
        """
    )
    assert rec["steady"] and rec["mid"] and rec["late"], rec
    assert rec["late_resyncs"] >= 1 and rec["mid_resyncs"] >= 1
    assert rec["drift_ok"] and rec["drift_under_bound"], rec
    # the wire-compressed snapshot beats the dense f32 params dump
    assert rec["snap_bytes"] < rec["snap_dense"], rec
    assert rec["stats"]["fanout_ratio"] > 1.0
