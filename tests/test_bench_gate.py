"""The CI bench regression gate (benchmarks/check_regression.py):
identical payloads pass; slowdowns beyond the budget, regressed byte
ratios, and flipped correctness flags fail; missing baselines skip."""
import copy
import importlib.util
import json
import os

_GATE = os.path.join(
    os.path.dirname(__file__), "..", "benchmarks", "check_regression.py"
)
spec = importlib.util.spec_from_file_location("check_regression", _GATE)
gate = importlib.util.module_from_spec(spec)
spec.loader.exec_module(gate)

TOPK = {
    "loop_us": 450.0, "singlepass_us": 100.0, "speedup": 4.5,
    "fused_loop_us": 1000.0, "fused_singlepass_us": 250.0,
    "bitwise_equal": True,
}
WIRE = {
    "float32": {"ratio_vs_unpacked": 1.52, "ratio_vs_dense": 10.0,
                "roundtrip_exact": True, "encode_us": 50.0,
                "decode_us": 40.0},
    "bfloat16": {"ratio_vs_unpacked": 2.46, "ratio_vs_dense": 16.0,
                 "roundtrip_exact": True, "encode_us": 45.0,
                 "decode_us": 42.0},
}
FANOUT = {
    "per_N": {"1": {"ratio_vs_dense": 8.2,
                    "publisher_ratio_vs_dense": 8.2},
              "16": {"ratio_vs_dense": 8.4,
                     "publisher_ratio_vs_dense": 130.0}},
    "snapshot": {"ratio_vs_dense": 1.8, "exact": True},
}
HIER = {
    "bit_identical": True, "conservation_ok": True,
    "accounting_exact": True, "conservation_max_err": 2.4e-7,
    "packed": {"two_level_cross": 100_000, "flat_cross": 400_000,
               "cross_reduction": 4.0},
    "unpacked": {"two_level_cross": 150_000, "flat_cross": 450_000,
                 "cross_reduction": 3.0},
}
REFRESH = {
    "drift": {
        "refresh_on": {"min_capture": 0.86},
        "refresh_off": {"min_capture": 0.33},
        "capture_advantage": 0.53,
        "byte_ratio_padded_vs_effective": 7.6,
    },
    "smoke": {"refreshes": 2, "zero_recompiles": True,
              "replay_bitwise": True, "dynamic_matches_static": True},
}
OVERLAP = {
    "pipeline": {"seq_ms": 76.0, "overlap_ms": 51.0, "speedup": 1.49,
                 "bitwise_equal": True},
    "smoke": {"flat_bitwise": True, "hierarchical_bitwise": True,
              "pod_dynamic_bitwise": True, "probe_bitwise": True},
    "bitwise_identical": True,
}
BUDGET = {
    "transport": {"byte_ratio_realized_vs_accounted": 1.0,
                  "padded_vs_realized": 7.63,
                  "roundtrip_bitwise": True},
    "allocation": {"within_budget": True, "mean_advantage": 1.08,
                   "final_advantage": 1.10},
    "smoke": {"repack_bitwise": True,
              "transport_roundtrip_bitwise": True,
              "transport_accounting_exact": True,
              "refresh_within_budget": True, "zero_recompiles": True},
}
LOCAL = {
    "accounting": {"scaling_exact_one_over_h": True,
                   "quant_value_compression": 2.81,
                   "amortized_bytes_per_step": {"1": 4224.0, "2": 2112.0,
                                                "4": 1056.0, "8": 528.0}},
    "smoke": {"h1_accum_bitwise": True, "quant_bit_identical": True,
              "quant_accounting_exact": True,
              "amortized_ratio_exact": True, "bytes_scaling_exact": True,
              "all_converge": True, "zero_recompiles": True,
              "quant_conservation_max_err": 3.1e-7,
              "runs": {"1": {"init_loss": 6.9, "final_loss": 4.1},
                       "8": {"init_loss": 6.9, "final_loss": 5.2}}},
}


def test_identical_payloads_pass():
    assert gate.check_topk(TOPK, copy.deepcopy(TOPK), 1.15) == []
    assert gate.check_wire(WIRE, copy.deepcopy(WIRE), 1.15) == []
    assert gate.check_fanout(FANOUT, copy.deepcopy(FANOUT), 1.15) == []
    assert gate.check_hierarchy(HIER, copy.deepcopy(HIER), 1.15) == []
    assert gate.check_refresh(REFRESH, copy.deepcopy(REFRESH), 1.15) == []
    assert gate.check_overlap(OVERLAP, copy.deepcopy(OVERLAP), 1.15) == []
    assert gate.check_budget(BUDGET, copy.deepcopy(BUDGET), 1.15) == []
    assert gate.check_local(LOCAL, copy.deepcopy(LOCAL), 1.15) == []


def test_refresh_regressions_fail():
    # a flipped correctness flag (recompiles appeared, replay diverged,
    # dynamic path no longer matches static) fails
    for flag in ("zero_recompiles", "replay_bitwise",
                 "dynamic_matches_static"):
        fresh = copy.deepcopy(REFRESH)
        fresh["smoke"][flag] = False
        assert any(flag in e
                   for e in gate.check_refresh(REFRESH, fresh, 1.15))
    # mass-capture floor: refresh-on dropping out of the band fails
    fresh2 = copy.deepcopy(REFRESH)
    fresh2["drift"]["refresh_on"]["min_capture"] = 0.5
    assert any("min_capture" in e
               for e in gate.check_refresh(REFRESH, fresh2, 1.15))
    # the live-k byte edge over the padded buffer shrinking fails
    fresh3 = copy.deepcopy(REFRESH)
    fresh3["drift"]["byte_ratio_padded_vs_effective"] = 2.0
    assert any("byte_ratio" in e
               for e in gate.check_refresh(REFRESH, fresh3, 1.15))
    # losing the on-vs-off capture advantage fails
    fresh4 = copy.deepcopy(REFRESH)
    fresh4["drift"]["capture_advantage"] = 0.01
    assert any("capture_advantage" in e
               for e in gate.check_refresh(REFRESH, fresh4, 1.15))


def test_overlap_regressions_fail():
    # any bitwise flag flipping fails — the feature's whole contract
    for path, flag in [("pipeline", "bitwise_equal"),
                       ("smoke", "flat_bitwise"),
                       ("smoke", "hierarchical_bitwise"),
                       ("smoke", "pod_dynamic_bitwise"),
                       ("smoke", "probe_bitwise")]:
        fresh = copy.deepcopy(OVERLAP)
        fresh[path][flag] = False
        assert any(flag in e
                   for e in gate.check_overlap(OVERLAP, fresh, 1.15))
    fresh = copy.deepcopy(OVERLAP)
    fresh["bitwise_identical"] = False
    assert any("bitwise_identical" in e
               for e in gate.check_overlap(OVERLAP, fresh, 1.15))
    # machine-normalized speedup: -33% is interpret-noise, halving fails
    fresh2 = copy.deepcopy(OVERLAP)
    fresh2["pipeline"]["speedup"] = 1.10
    assert gate.check_overlap(OVERLAP, fresh2, 1.15) == []
    fresh2["pipeline"]["speedup"] = 0.70
    errs = gate.check_overlap(OVERLAP, fresh2, 1.15)
    # ...and anything at/below break-even fails regardless of baseline
    assert any("speedup" in e for e in errs)
    assert any("<= 1.0" in e for e in errs)


def test_budget_regressions_fail():
    # realized bytes drifting above the live-k accounting fails BOTH
    # against the baseline and against the absolute 1.2x bound
    fresh = copy.deepcopy(BUDGET)
    fresh["transport"]["byte_ratio_realized_vs_accounted"] = 1.1
    errs = gate.check_budget(BUDGET, fresh, 1.15)
    assert len(errs) == 1 and "regressed" in errs[0]
    fresh["transport"]["byte_ratio_realized_vs_accounted"] = 1.5
    errs = gate.check_budget(BUDGET, fresh, 1.15)
    assert any("acceptance bound" in e for e in errs)
    # losing the padded-vs-realized byte edge fails
    fresh2 = copy.deepcopy(BUDGET)
    fresh2["transport"]["padded_vs_realized"] = 2.0
    assert any("padded_vs_realized" in e
               for e in gate.check_budget(BUDGET, fresh2, 1.15))
    # the water-filling advantage shrinking (or vanishing) fails
    fresh3 = copy.deepcopy(BUDGET)
    fresh3["allocation"]["mean_advantage"] = 1.02
    assert any("mean_advantage" in e
               for e in gate.check_budget(BUDGET, fresh3, 1.15))
    fresh3["allocation"]["mean_advantage"] = 0.98
    # baseline equal to fresh: only the absolute <= 1.0 check fires
    base3 = copy.deepcopy(BUDGET)
    base3["allocation"]["mean_advantage"] = 0.98
    assert any("<= 1.0" in e
               for e in gate.check_budget(base3, fresh3, 1.15))
    # every correctness bit is load-bearing
    for path, flag in [("transport", "roundtrip_bitwise"),
                       ("allocation", "within_budget"),
                       ("smoke", "repack_bitwise"),
                       ("smoke", "transport_roundtrip_bitwise"),
                       ("smoke", "transport_accounting_exact"),
                       ("smoke", "refresh_within_budget"),
                       ("smoke", "zero_recompiles")]:
        fresh4 = copy.deepcopy(BUDGET)
        fresh4[path][flag] = False
        assert any(flag in e
                   for e in gate.check_budget(BUDGET, fresh4, 1.15)), flag
    # a tracked key going missing fails
    fresh5 = copy.deepcopy(BUDGET)
    del fresh5["transport"]["byte_ratio_realized_vs_accounted"]
    assert any("missing" in e
               for e in gate.check_budget(BUDGET, fresh5, 1.15))


def test_local_regressions_fail():
    # every correctness bit is load-bearing
    for path, flag in [("accounting", "scaling_exact_one_over_h"),
                       ("smoke", "h1_accum_bitwise"),
                       ("smoke", "quant_bit_identical"),
                       ("smoke", "quant_accounting_exact"),
                       ("smoke", "amortized_ratio_exact"),
                       ("smoke", "bytes_scaling_exact"),
                       ("smoke", "all_converge"),
                       ("smoke", "zero_recompiles")]:
        fresh = copy.deepcopy(LOCAL)
        fresh[path][flag] = False
        assert any(flag in e
                   for e in gate.check_local(LOCAL, fresh, 1.15)), flag
    # the quantized wire's compression edge shrinking (or inverting)
    fresh2 = copy.deepcopy(LOCAL)
    fresh2["accounting"]["quant_value_compression"] = 2.0
    assert any("quant_value_compression" in e
               for e in gate.check_local(LOCAL, fresh2, 1.15))
    fresh2["accounting"]["quant_value_compression"] = 0.9
    base2 = copy.deepcopy(LOCAL)
    base2["accounting"]["quant_value_compression"] = 0.9
    assert any("<= 1.0" in e
               for e in gate.check_local(base2, fresh2, 1.15))
    # quantized mass conservation blowing past the float bound fails
    fresh3 = copy.deepcopy(LOCAL)
    fresh3["smoke"]["quant_conservation_max_err"] = 1e-3
    assert any("quant_conservation_max_err" in e
               for e in gate.check_local(LOCAL, fresh3, 1.15))
    # a tracked key going missing fails
    fresh4 = copy.deepcopy(LOCAL)
    del fresh4["smoke"]["quant_conservation_max_err"]
    assert any("missing" in e
               for e in gate.check_local(LOCAL, fresh4, 1.15))


def test_local_headline_in_summary(tmp_path):
    basedir, freshdir = tmp_path / "base", tmp_path / "fresh"
    basedir.mkdir(), freshdir.mkdir()
    (basedir / "BENCH_local.json").write_text(json.dumps(LOCAL))
    (freshdir / "BENCH_local.json").write_text(json.dumps(LOCAL))
    out = tmp_path / "summary.md"
    with open(out, "w") as fh:
        gate.write_summary(str(basedir), str(freshdir), [], fh)
    text = out.read_text()
    assert "**Qsparse-local-SGD:**" in text
    assert "4224B at H=1 -> 528B at H=8" in text
    assert "x2.81 smaller" in text


def test_budget_headline_in_summary(tmp_path):
    basedir, freshdir = tmp_path / "base", tmp_path / "fresh"
    basedir.mkdir(), freshdir.mkdir()
    (basedir / "BENCH_budget.json").write_text(json.dumps(BUDGET))
    (freshdir / "BENCH_budget.json").write_text(json.dumps(BUDGET))
    out = tmp_path / "summary.md"
    with open(out, "w") as fh:
        gate.write_summary(str(basedir), str(freshdir), [], fh)
    text = out.read_text()
    assert "**Budgeted transport:**" in text
    assert "x1.00 of the live-k accounting" in text
    assert "x7.63" in text


def test_topk_cutover_flag_gated():
    base = dict(TOPK, cutover={"cutover_k": 4, "auto_matches_faster": True})
    fresh = copy.deepcopy(base)
    assert gate.check_topk(base, fresh, 1.15) == []
    fresh["cutover"]["auto_matches_faster"] = False
    assert any("auto_matches_faster" in e
               for e in gate.check_topk(base, fresh, 1.15))
    # a baseline predating the cutover sweep must not block the gate
    assert gate.check_topk(TOPK, copy.deepcopy(TOPK), 1.15) == []


def test_unreadable_payload_fails_loudly(tmp_path):
    """An EXISTING but corrupt/unreadable BENCH_*.json must be a named
    gate failure, not a stack trace (and not a silent skip that would
    disable every gate in the file)."""
    basedir, freshdir = tmp_path / "base", tmp_path / "fresh"
    basedir.mkdir(), freshdir.mkdir()
    (basedir / "BENCH_topk.json").write_text(json.dumps(TOPK))
    (freshdir / "BENCH_topk.json").write_text(json.dumps(TOPK))
    # corrupt baseline
    (basedir / "BENCH_topk.json").write_text("{truncated")
    errs = gate.run(str(basedir), str(freshdir), 1.15)
    assert len(errs) == 1 and "unreadable baseline" in errs[0]
    assert "BENCH_topk.json" in errs[0]
    # corrupt fresh
    (basedir / "BENCH_topk.json").write_text(json.dumps(TOPK))
    (freshdir / "BENCH_topk.json").write_text("")
    errs = gate.run(str(basedir), str(freshdir), 1.15)
    assert len(errs) == 1 and "unreadable fresh" in errs[0]
    # the summary writer survives the corrupt payloads too
    out = tmp_path / "summary.md"
    with open(out, "w") as fh:
        gate.write_summary(str(basedir), str(freshdir), errs, fh)
    assert "unreadable fresh payload" in out.read_text()


def test_hierarchy_regressions_fail():
    # cross-pod reduction shrinking is a regression
    fresh = copy.deepcopy(HIER)
    fresh["packed"]["cross_reduction"] = 3.2
    errs = gate.check_hierarchy(HIER, fresh, 1.15)
    assert len(errs) == 1 and "packed" in errs[0]
    # flipped correctness flags fail
    for flag in ("bit_identical", "conservation_ok", "accounting_exact"):
        fresh2 = copy.deepcopy(HIER)
        fresh2[flag] = False
        assert any(flag in e for e in gate.check_hierarchy(HIER, fresh2, 1.15))
    # a tracked key going missing fails
    fresh3 = copy.deepcopy(HIER)
    del fresh3["unpacked"]["cross_reduction"]
    assert any("missing" in e for e in gate.check_hierarchy(HIER, fresh3, 1.15))


def test_summary_markdown(tmp_path):
    basedir, freshdir = tmp_path / "base", tmp_path / "fresh"
    basedir.mkdir(), freshdir.mkdir()
    fresh_hier = copy.deepcopy(HIER)
    fresh_hier["packed"]["cross_reduction"] = 4.2
    (basedir / "BENCH_hierarchy.json").write_text(json.dumps(HIER))
    (freshdir / "BENCH_hierarchy.json").write_text(json.dumps(fresh_hier))
    out = tmp_path / "summary.md"
    with open(out, "w") as fh:
        gate.write_summary(str(basedir), str(freshdir), [], fh)
    text = out.read_text()
    assert "Bench regression gate" in text and "**ok**" in text
    # nested metrics flatten to dotted rows with baseline/fresh/delta
    assert "| packed.cross_reduction | 4 | 4.2 | +5.0% |" in text
    assert "| bit_identical | true | true |" in text
    with open(out, "w") as fh:
        gate.write_summary(str(basedir), str(freshdir),
                           ["hierarchy[packed]: regressed"], fh)
    text = out.read_text()
    assert "**FAIL**" in text and "hierarchy[packed]: regressed" in text
    # the overlap speedup gets a headline row above the tables
    base_ovl = copy.deepcopy(OVERLAP)
    base_ovl["pipeline"]["speedup"] = 1.40
    (basedir / "BENCH_overlap.json").write_text(json.dumps(base_ovl))
    (freshdir / "BENCH_overlap.json").write_text(json.dumps(OVERLAP))
    with open(out, "w") as fh:
        gate.write_summary(str(basedir), str(freshdir), [], fh)
    text = out.read_text()
    assert ("**Overlap pipeline speedup:** x1.49 (baseline x1.40) — "
            "bitwise identical: true") in text


def test_throughput_drop_fails_but_budget_holds():
    # the kernel gate runs on machine-normalized same-run speedups with
    # a wide retention budget (interpret-mode variance is ~40%), not on
    # raw wall-clock (not comparable across baseline/CI machines)
    fresh = copy.deepcopy(TOPK)
    fresh["speedup"] = 3.0  # -33%: noise-level for interpret mode
    assert gate.check_topk(TOPK, fresh, 1.15) == []
    fresh["speedup"] = 2.0  # speedup halved: a real kernel regression
    errs = gate.check_topk(TOPK, fresh, 1.15)
    assert len(errs) == 1 and "speedup" in errs[0]
    fresh2 = copy.deepcopy(TOPK)
    fresh2["fused_singlepass_us"] = 600.0  # fused speedup 4.0 -> 1.67
    errs = gate.check_topk(TOPK, fresh2, 1.15)
    assert len(errs) == 1 and "fused_speedup" in errs[0]
    # raw-us gating at 15% still applies to the low-variance wire codec
    fresh3 = copy.deepcopy(WIRE)
    fresh3["float32"]["encode_us"] = 60.0
    assert any("encode_us" in e for e in gate.check_wire(WIRE, fresh3, 1.15))


def test_missing_tracked_key_fails():
    fresh = copy.deepcopy(TOPK)
    del fresh["speedup"]
    assert any("missing" in e for e in gate.check_topk(TOPK, fresh, 1.15))
    fresh2 = copy.deepcopy(WIRE)
    del fresh2["bfloat16"]["ratio_vs_unpacked"]
    assert any("missing" in e for e in gate.check_wire(WIRE, fresh2, 1.15))
    # correctness flags are tracked keys too: dropping one must fail
    fresh3 = copy.deepcopy(TOPK)
    del fresh3["bitwise_equal"]
    assert any("missing" in e for e in gate.check_topk(TOPK, fresh3, 1.15))


def test_byte_ratio_regression_fails():
    fresh = copy.deepcopy(WIRE)
    fresh["bfloat16"]["ratio_vs_unpacked"] = 2.0
    errs = gate.check_wire(WIRE, fresh, 1.15)
    assert len(errs) == 1 and "bfloat16" in errs[0]
    fresh2 = copy.deepcopy(FANOUT)
    fresh2["per_N"]["16"]["publisher_ratio_vs_dense"] = 100.0
    assert len(gate.check_fanout(FANOUT, fresh2, 1.15)) == 1


def test_correctness_flag_flip_fails():
    fresh = copy.deepcopy(TOPK)
    fresh["bitwise_equal"] = False
    assert any("bitwise_equal" in e for e in gate.check_topk(TOPK, fresh, 1.15))
    fresh2 = copy.deepcopy(FANOUT)
    fresh2["snapshot"]["exact"] = False
    assert any("exact" in e for e in gate.check_fanout(FANOUT, fresh2, 1.15))


def test_run_end_to_end(tmp_path):
    basedir, freshdir = tmp_path / "base", tmp_path / "fresh"
    basedir.mkdir(), freshdir.mkdir()
    for name, payload in [("BENCH_topk.json", TOPK),
                          ("BENCH_wire.json", WIRE),
                          ("BENCH_fanout.json", FANOUT)]:
        (basedir / name).write_text(json.dumps(payload))
        (freshdir / name).write_text(json.dumps(payload))
    assert gate.run(str(basedir), str(freshdir), 1.15) == []
    # a fresh file missing is a failure; a BASELINE missing is a skip
    os.remove(freshdir / "BENCH_fanout.json")
    errs = gate.run(str(basedir), str(freshdir), 1.15)
    assert len(errs) == 1 and "BENCH_fanout.json" in errs[0]
    os.remove(basedir / "BENCH_fanout.json")
    assert gate.run(str(basedir), str(freshdir), 1.15) == []


MATRIX = {
    "plan": "config-zoo-smoke", "mesh": "smoke_2pod", "steps": 24,
    "archs": ["rwkv6-3b", "qwen3-moe-30b-a3b"],
    "presets": ["topk", "qsparse_local"],
    "scenarios": {
        f"{a}/{p}": {
            "arch": a, "preset": p, "healthy": True,
            "median_decreased": True, "nonfinite": False, "spikes": 0,
            "loss_first_median": 6.9, "loss_last_median": 5.1,
            "stop_reason": None,
            "bytes_per_step": {"intra": 4000.0, "cross": 1000.0,
                               "total": 5000.0},
            "dense_bytes_per_step": 500000, "compression": 100.0,
            "compression_win": True,
        }
        for a in ("rwkv6-3b", "qwen3-moe-30b-a3b")
        for p in ("topk", "qsparse_local")
    },
}


def test_matrix_identical_payload_passes():
    assert gate.check_matrix(MATRIX, copy.deepcopy(MATRIX), 1.15) == []


def test_matrix_unhealthy_scenario_fails():
    # each health dimension flips to a named per-scenario failure
    fresh = copy.deepcopy(MATRIX)
    fresh["scenarios"]["rwkv6-3b/topk"].update(
        healthy=False, nonfinite=True,
        stop_reason="non-finite loss at step 7")
    errs = gate.check_matrix(MATRIX, fresh, 1.15)
    assert any("matrix[rwkv6-3b/topk]" in e and "non-finite loss" in e
               for e in errs)
    fresh2 = copy.deepcopy(MATRIX)
    fresh2["scenarios"]["qwen3-moe-30b-a3b/topk"]["median_decreased"] = False
    assert any("median no longer decreasing" in e
               for e in gate.check_matrix(MATRIX, fresh2, 1.15))
    fresh3 = copy.deepcopy(MATRIX)
    fresh3["scenarios"]["rwkv6-3b/qsparse_local"]["compression_win"] = False
    assert any("no compression win" in e
               for e in gate.check_matrix(MATRIX, fresh3, 1.15))


def test_matrix_compression_regression_fails():
    fresh = copy.deepcopy(MATRIX)
    fresh["scenarios"]["rwkv6-3b/topk"]["compression"] = 50.0
    assert any("compression" in e and "regressed" in e
               for e in gate.check_matrix(MATRIX, fresh, 1.15))


def test_matrix_non_numeric_compression_is_named_error():
    # run.py emits compression: null when the byte accounting lacks a
    # total — against a numeric baseline that is a NAMED failure, not a
    # TypeError stack trace out of the ratio check
    fresh = copy.deepcopy(MATRIX)
    fresh["scenarios"]["rwkv6-3b/topk"]["compression"] = None
    errs = gate.check_matrix(MATRIX, fresh, 1.15)
    assert any("matrix[rwkv6-3b/topk]" in e and "not numeric" in e
               for e in errs)
    # a null BASELINE value skips the ratio check (nothing to compare)
    base = copy.deepcopy(MATRIX)
    base["scenarios"]["rwkv6-3b/topk"]["compression"] = None
    assert gate.check_matrix(base, copy.deepcopy(MATRIX), 1.15) == []
    both = copy.deepcopy(MATRIX)
    both["scenarios"]["rwkv6-3b/topk"]["compression"] = None
    assert gate.check_matrix(base, both, 1.15) == []


def test_matrix_missing_scenario_fails_with_named_error():
    # a declared arch x preset cell missing from the payload is a loud
    # failure, not a silently skipped gate
    fresh = copy.deepcopy(MATRIX)
    del fresh["scenarios"]["qwen3-moe-30b-a3b/qsparse_local"]
    errs = gate.check_matrix(MATRIX, fresh, 1.15)
    assert any("matrix[qwen3-moe-30b-a3b/qsparse_local]" in e
               and "missing" in e for e in errs)


def test_matrix_subset_fresh_run_passes():
    # PR CI runs one arch: the fresh payload declares only what it ran,
    # and the full-zoo baseline's extra scenarios must NOT fail the gate
    fresh = copy.deepcopy(MATRIX)
    fresh["archs"] = ["rwkv6-3b"]
    fresh["scenarios"] = {k: v for k, v in fresh["scenarios"].items()
                          if v["arch"] == "rwkv6-3b"}
    assert gate.check_matrix(MATRIX, fresh, 1.15) == []


def test_matrix_corrupt_payload_fails_with_named_error():
    # structurally broken payloads: missing coverage declaration,
    # non-dict scenario record, record with missing tracked keys
    assert any("corrupt payload" in e
               for e in gate.check_matrix(MATRIX, {"scenarios": {}}, 1.15))
    fresh = copy.deepcopy(MATRIX)
    fresh["scenarios"]["rwkv6-3b/topk"] = "garbage"
    assert any("corrupt scenario record" in e
               for e in gate.check_matrix(MATRIX, fresh, 1.15))
    fresh2 = copy.deepcopy(MATRIX)
    del fresh2["scenarios"]["rwkv6-3b/topk"]["compression"]
    del fresh2["scenarios"]["rwkv6-3b/topk"]["healthy"]
    errs = gate.check_matrix(MATRIX, fresh2, 1.15)
    assert any("missing keys" in e and "compression" in e for e in errs)


def test_matrix_gate_without_baseline_scenarios():
    # a brand-new scenario (no baseline coverage) still self-validates
    assert gate.check_matrix({}, copy.deepcopy(MATRIX), 1.15) == []


def test_select_checks_subset_and_unknown():
    only = gate.select_checks("matrix")
    assert list(only) == ["BENCH_matrix.json"]
    both = gate.select_checks("topk,local")
    assert set(both) == {"BENCH_topk.json", "BENCH_local.json"}
    assert gate.select_checks(None) is gate.CHECKS
    try:
        gate.select_checks("nope")
    except SystemExit as e:
        assert "nope" in str(e)
    else:
        raise AssertionError("unknown --only stem did not raise")


def test_matrix_headline_in_summary(tmp_path):
    freshdir = tmp_path / "fresh"
    freshdir.mkdir()
    (freshdir / "BENCH_matrix.json").write_text(json.dumps(MATRIX))
    out = tmp_path / "summary.md"
    with open(out, "w") as fh:
        gate.write_summary(str(tmp_path / "nobase"), str(freshdir), [], fh)
    text = out.read_text()
    assert "Scenario matrix:" in text
    assert "4/4 scenarios healthy + converging" in text
    assert "rwkv6-3b/topk" in text
