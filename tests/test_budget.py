"""Global byte-budget controller tests (repro.core.budget).

The controller is the single allocator behind both pod-k sizing modes:
``mass_target`` must reproduce the historical ``autotune_pod_ratios``
sizing exactly, and ``byte_budget`` must water-fill a global cross-pod
byte budget — never overspending, monotone in the budget, preferring
the bucket with the denser marginal mass, and flooring at k=1 when the
budget is infeasible.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buckets as bk
from repro.core import encoding as enc
from repro.core.budget import BudgetController, _abs_capture
from repro.core.distributed import SyncConfig, autotune_pod_ratios

N_DATA = 4


def _plan_and_u(seed=0, heavy_bucket=None, cols=128):
    """The selfcheck tiny tree: bucket 0 dense ('b'), bucket 1 sparse
    ('w' -> 48 rows x 128 cols). ``heavy_bucket`` scales one bucket's
    buffer so mass-ordering tests have a known winner."""
    tree = {"w": jnp.zeros((16, 384)), "b": jnp.zeros((40,))}
    plan = bk.make_plan(tree, cols=cols, dense_below=64)
    rng = np.random.default_rng(seed)
    u_bufs = []
    for b, spec in enumerate(plan.buckets):
        u = rng.standard_normal((spec.rows, spec.cols)).astype(np.float32)
        if heavy_bucket == b:
            u = u * 100.0
        u_bufs.append(jnp.asarray(u))
    return plan, u_bufs


def _cfg(**kw):
    kw.setdefault("ratio", 0.05)
    kw.setdefault("wire", "packed")
    return SyncConfig(strategy="hierarchical", bucketed=True,
                      bucket_cols=128, pod_dynamic=True, **kw)


def test_mass_target_reproduces_autotune_sizing():
    """allocate_mass_target == the historical autotuner formula computed
    independently here (searchsorted over the support-relative curve,
    clamped to [k_min, support]) — and ``autotune_pod_ratios`` (which
    now delegates) emits exactly ``ratios_of`` of that allocation."""
    cfg = _cfg(k_min=2)
    plan, u_bufs = _plan_and_u(seed=1)
    ctl = BudgetController(cfg, plan, N_DATA)
    curves = ctl.measure(u_bufs)
    for target in (0.5, 0.9, 0.999):
        ks = ctl.allocate_mass_target(curves, target)
        for c, k in zip(curves, ks):
            if c.kind == "dense":
                assert k == 1
                continue
            k_row = cfg.k_for(c.cols)
            support = max(1, min(c.cols, N_DATA * k_row))
            rel = bk.support_relative_capture(u_bufs[c.bucket], support)
            want = int(np.searchsorted(rel, target, side="left")) + 1
            want = max(cfg.k_min, min(want, support))
            assert k == want, (target, c.bucket)
        assert autotune_pod_ratios(cfg, plan, u_bufs, N_DATA,
                                   mass_target=target) == ctl.ratios_of(ks)


def test_water_filling_never_overspends_and_is_monotone():
    cfg = _cfg()
    plan, u_bufs = _plan_and_u(seed=2)
    ctl = BudgetController(cfg, plan, N_DATA)
    curves = ctl.measure(u_bufs)
    floor_ks = tuple(1 for _ in curves)
    floor = ctl.cross_bytes_of(floor_ks)
    prev = None
    for budget in (floor, floor + 200, floor + 1000, floor + 10_000):
        ks = ctl.allocate_bytes(curves, budget)
        assert ctl.cross_bytes_of(ks) <= budget
        if prev is not None:
            assert all(a >= b for a, b in zip(ks, prev)), (ks, prev)
        prev = ks
    # a generous budget saturates every sparse bucket at its cap
    big = ctl.allocate_bytes(curves, floor + 10 ** 9)
    for c, k in zip(curves, big):
        if c.kind == "sparse":
            assert k == c.k_cap


def test_water_filling_infeasible_budget_floors_at_k1():
    """The codec cannot ship k=0; an impossible budget degrades to the
    mandatory allocation instead of failing."""
    cfg = _cfg()
    plan, u_bufs = _plan_and_u(seed=3)
    ctl = BudgetController(cfg, plan, N_DATA)
    curves = ctl.measure(u_bufs)
    for budget in (0, 1, ctl.cross_bytes_of(tuple(1 for _ in curves)) - 1):
        ks = ctl.allocate_bytes(curves, budget)
        assert all(k == 1 for c, k in zip(curves, ks)
                   if c.kind == "sparse"), (budget, ks)


def test_water_filling_prefers_the_heavier_bucket():
    """Two identically-shaped sparse buckets, one carrying 100x the
    mass: at a budget too small to saturate both, the heavy bucket must
    win more slots. (``make_plan`` merges same-dtype sparse leaves into
    one bucket, so the curves are built directly.)"""
    from repro.core.budget import BucketCurve

    rng = np.random.default_rng(4)
    rows, cols, cap = 8, 128, 24
    curves = []
    for b, scale in enumerate((100.0, 1.0)):
        u = jnp.asarray(
            rng.standard_normal((rows, cols)).astype(np.float32) * scale)
        curves.append(BucketCurve(
            bucket=b, kind="sparse", rows=rows, cols=cols, support=cap,
            k_cap=cap, abs_capture=_abs_capture(u, cap),
            rel_capture=bk.support_relative_capture(u, cap),
            min_nbytes=enc.message_nbytes(rows, cols, 1, "float32",
                                          "packed"),
        ))
    ctl = BudgetController(_cfg(), bk.make_plan(
        {"x": jnp.zeros((8, 256))}, cols=cols, dense_below=1), N_DATA)
    floor = sum(c.min_nbytes for c in curves)
    span = sum(enc.message_nbytes(rows, cols, cap, "float32", "packed")
               for _ in curves) - floor
    ks = ctl.allocate_bytes(curves, floor + span // 3)
    assert ks[0] > ks[1], ks


def test_k_caps_clamp_both_modes():
    cfg = _cfg()
    plan, u_bufs = _plan_and_u(seed=5)
    caps = tuple(3 for _ in plan.buckets)
    ctl = BudgetController(cfg, plan, N_DATA, k_caps=caps)
    curves = ctl.measure(u_bufs)
    assert all(c.k_cap <= 3 for c in curves if c.kind == "sparse")
    ks_mass = ctl.allocate_mass_target(curves, 0.9999)
    ks_byte = ctl.allocate_bytes(curves, 10 ** 9)
    for c, km, kb in zip(curves, ks_mass, ks_byte):
        if c.kind == "sparse":
            assert km <= 3 and kb == 3


def test_allocate_routes_on_cfg_byte_budget():
    """``allocate`` prefers the byte budget (argument, else config) over
    the mass target, and the emitted ratios round-trip to the ks."""
    plan, u_bufs = _plan_and_u(seed=6)
    floor_cfg = _cfg()
    floor = BudgetController(floor_cfg, plan, N_DATA).cross_bytes_of(
        tuple(1 for _ in plan.buckets))
    cfg = _cfg(byte_budget=floor)
    ctl = BudgetController(cfg, plan, N_DATA)
    ks = ctl.allocate(u_bufs)  # cfg.byte_budget: exactly the floor
    assert all(k == 1 for s, k in zip(plan.buckets, ks)
               if s.kind == "sparse")
    ks2 = ctl.allocate(u_bufs, byte_budget=floor + 10 ** 9)
    assert any(k > 1 for s, k in zip(plan.buckets, ks2)
               if s.kind == "sparse")
    # ratios round-trip through the runtime's int(round(r * cols))
    for spec, k, r in zip(plan.buckets, ks2, ctl.ratios_of(ks2)):
        if spec.kind == "sparse":
            assert int(round(r * spec.cols)) == k


def test_cross_bytes_match_codec_accounting():
    cfg = _cfg()
    plan, u_bufs = _plan_and_u(seed=7)
    ctl = BudgetController(cfg, plan, N_DATA)
    ks = ctl.allocate(u_bufs, byte_budget=10 ** 6)
    want = 0
    for spec, k in zip(plan.buckets, ks):
        if spec.kind == "dense":
            want += spec.rows * spec.cols * 4
        else:
            want += enc.message_nbytes(spec.rows, spec.cols, int(k),
                                       "float32", cfg.wire)
    assert ctl.cross_bytes_of(ks) == want


def test_abs_capture_is_concave_and_monotone():
    """Water-filling's optimality rests on concavity: the marginal gain
    of each additional slot is non-increasing."""
    u = jnp.asarray(np.random.default_rng(8).standard_normal(
        (6, 64)).astype(np.float32))
    cap = np.asarray(_abs_capture(u, 32))
    gains = np.diff(np.concatenate([[0.0], cap]))
    assert np.all(gains >= -1e-6)
    assert np.all(np.diff(gains) <= 1e-4)
