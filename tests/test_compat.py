"""Branch coverage for the ``repro.utils.compat`` version shims.

Only one jax version is installed, so the other arm of each shim can't
run natively; the legacy/modern arms are exercised by reloading the
module under monkeypatched ``jax`` attributes and asserting the wrapper
translates kwargs correctly (``axis_types`` dropped, ``check_vma`` ->
``check_rep`` + complementary ``auto=``, ``axis_size`` -> ``psum``).
"""
import importlib
import sys
import types

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.utils.compat as compat


@pytest.fixture
def reloaded_compat():
    """Yield (monkeypatch, module); whatever the test reloads, the
    teardown reload restores the real-jax branches.

    Owns its MonkeyPatch instead of using the fixture: the patches must
    be undone BEFORE the restoring reload (the builtin fixture tears
    down after this one, which would re-capture the fakes)."""
    mp = pytest.MonkeyPatch()
    yield mp, compat
    mp.undo()
    importlib.reload(compat)


# ---------------------------------------------------------------------------
# whichever branch is installed must actually work end to end


def test_axis_type_has_modes():
    for mode in ("Auto", "Explicit", "Manual"):
        assert hasattr(compat.AxisType, mode)


def test_make_mesh_builds_real_mesh():
    n = jax.device_count()
    mesh = compat.make_mesh((n,), ("data",))
    assert tuple(mesh.axis_names) == ("data",)
    mesh = compat.make_mesh((n,), ("data",),
                            axis_types=(compat.AxisType.Auto,))
    assert tuple(mesh.axis_names) == ("data",)


def test_make_mesh_devices_kwarg():
    devs = jax.devices()
    mesh = compat.make_mesh((len(devs),), ("data",), devices=devs)
    assert mesh.devices.size == len(devs)


def test_shard_map_executes():
    n = jax.device_count()
    mesh = compat.make_mesh((n,), ("data",))
    P = jax.sharding.PartitionSpec
    f = compat.shard_map(lambda x: x * 2.0, mesh=mesh,
                         in_specs=P("data"), out_specs=P("data"))
    x = jnp.arange(n * 2, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(f(x)), np.arange(n * 2) * 2.0)


def test_axis_size_inside_shard_map():
    n = jax.device_count()
    mesh = compat.make_mesh((n,), ("data",))
    P = jax.sharding.PartitionSpec
    f = compat.shard_map(lambda x: x * compat.axis_size("data"),
                         mesh=mesh, in_specs=P("data"),
                         out_specs=P("data"))
    x = jnp.ones((n,), jnp.float32)
    np.testing.assert_allclose(np.asarray(f(x)), np.full((n,), float(n)))


# ---------------------------------------------------------------------------
# legacy arms (jax without AxisType / axis_types kwarg / jax.shard_map /
# jax.lax.axis_size), simulated via reload under monkeypatched jax


def test_legacy_branches(reloaded_compat):
    monkeypatch, mod = reloaded_compat
    mesh_calls = []
    sm_calls = []
    psum_calls = []

    def old_make_mesh(axis_shapes, axis_names, *, devices=None):
        mesh_calls.append((axis_shapes, axis_names, devices))
        return "legacy-mesh"

    legacy_sm = types.ModuleType("jax.experimental.shard_map")

    def legacy_shard_map(f, *, mesh, in_specs, out_specs, check_rep, auto):
        sm_calls.append({"mesh": mesh, "in_specs": in_specs,
                         "out_specs": out_specs, "check_rep": check_rep,
                         "auto": auto})
        return f

    legacy_sm.shard_map = legacy_shard_map

    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    monkeypatch.setattr(jax, "make_mesh", old_make_mesh)
    monkeypatch.delattr(jax, "shard_map", raising=False)
    monkeypatch.setitem(sys.modules, "jax.experimental.shard_map", legacy_sm)
    monkeypatch.delattr(jax.lax, "axis_size", raising=False)
    monkeypatch.setattr(jax.lax, "psum",
                        lambda v, axis: psum_calls.append((v, axis)))

    importlib.reload(mod)

    # AxisType stand-in
    assert mod.AxisType.Auto == "auto"
    assert not mod._MAKE_MESH_AXIS_TYPES

    # make_mesh: axis_types silently dropped for the old signature
    assert mod.make_mesh((2,), ("data",),
                         axis_types=("auto",)) == "legacy-mesh"
    assert mesh_calls == [((2,), ("data",), None)]

    # shard_map: manual axes become the complementary auto= frozenset,
    # check_vma becomes check_rep
    fake_mesh = types.SimpleNamespace(axis_names=("pod", "data"))
    fn = lambda x: x  # noqa: E731
    out = mod.shard_map(fn, mesh=fake_mesh, in_specs="i", out_specs="o",
                        axis_names=("data",), check_vma=True)
    assert out is fn
    assert sm_calls[-1]["auto"] == frozenset({"pod"})
    assert sm_calls[-1]["check_rep"] is True

    # default: all mesh axes manual -> empty auto=
    mod.shard_map(fn, mesh=fake_mesh, in_specs="i", out_specs="o")
    assert sm_calls[-1]["auto"] == frozenset()
    assert sm_calls[-1]["check_rep"] is False

    # axis_size falls back to psum(1, axis)
    mod.axis_size("data")
    assert psum_calls == [(1, "data")]


def test_modern_branches_fill_defaults(reloaded_compat):
    monkeypatch, mod = reloaded_compat
    mesh_calls = []
    sm_calls = []

    def new_make_mesh(axis_shapes, axis_names, *, axis_types=None,
                      devices=None):
        mesh_calls.append((axis_shapes, axis_names, axis_types, devices))
        return "modern-mesh"

    def new_shard_map(f, *, mesh, in_specs, out_specs, check_vma,
                      axis_names=None):
        sm_calls.append({"check_vma": check_vma, "axis_names": axis_names})
        return f

    monkeypatch.setattr(jax, "make_mesh", new_make_mesh)
    monkeypatch.setattr(jax, "shard_map", new_shard_map, raising=False)

    importlib.reload(mod)

    assert mod._MAKE_MESH_AXIS_TYPES
    # axis_types=None expands to an all-Auto tuple, one per axis
    mod.make_mesh((1, 2), ("pod", "data"))
    assert mesh_calls[-1][2] == (mod.AxisType.Auto, mod.AxisType.Auto)

    fn = lambda x: x  # noqa: E731
    mod.shard_map(fn, mesh="m", in_specs="i", out_specs="o",
                  axis_names=("data",), check_vma=True)
    assert sm_calls[-1] == {"check_vma": True, "axis_names": {"data"}}
    # axis_names omitted entirely when None (jax fills every axis)
    mod.shard_map(fn, mesh="m", in_specs="i", out_specs="o")
    assert sm_calls[-1] == {"check_vma": False, "axis_names": None}
