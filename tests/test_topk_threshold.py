"""Single-pass threshold top-k kernel vs the k-loop oracle.

Contract under test (shared by every selection implementation):
top-|.|-k per row, emitted in decreasing-magnitude order, magnitude ties
broken by LOWEST index — bitwise-equal outputs in fp32.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import _row_topk_argmax, _row_topk_threshold
from repro.kernels import fused_memsgd_ref, fused_memsgd_update, row_topk
from repro.kernels.ref import row_topk_ref
from repro.kernels.topk_select import (
    row_topk_pallas,
    row_topk_tiled_pallas,
)

SHAPES = [(8, 64), (16, 128), (8, 1024), (24, 100), (3, 33), (1, 257)]


def _assert_pairs_equal(got, want):
    gv, gi = got
    wv, wi = want
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    # bitwise: compare the raw value patterns, not within a tolerance
    np.testing.assert_array_equal(
        np.asarray(gv).view(np.uint8), np.asarray(wv).view(np.uint8)
    )


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("k", [1, 4, 16, 64])
def test_threshold_matches_oracle_fp32(shape, k):
    R, C = shape
    if k > C:
        pytest.skip("k > C")
    x = jax.random.normal(jax.random.PRNGKey(R * C + k), shape)
    _assert_pairs_equal(
        row_topk(x, k, method="threshold"), row_topk_ref(x, k)
    )


@pytest.mark.parametrize("shape", [(8, 300), (16, 128), (5, 77)])
@pytest.mark.parametrize("k", [3, 12])
def test_threshold_matches_oracle_bf16(shape, k):
    x = jax.random.normal(
        jax.random.PRNGKey(sum(shape)), shape
    ).astype(jnp.bfloat16)
    _assert_pairs_equal(
        row_topk(x, k, method="threshold"), row_topk_ref(x, k)
    )


@pytest.mark.parametrize("col_block", [16, 64, 100, 512])
def test_tiled_column_blocks(col_block):
    """C not divisible by the column block: padded columns never win."""
    R, C, k = 8, 257, 16
    x = jax.random.normal(jax.random.PRNGKey(7), (R, C))
    got = row_topk_tiled_pallas(x, k, col_block=col_block)
    _assert_pairs_equal(got, row_topk_ref(x, k))


def test_tie_heavy_lowest_index_contract():
    """Quantized values force many exact magnitude ties; the tie must
    resolve to the LOWEST index, matching the iterative-argmax oracle."""
    x = jnp.round(jax.random.normal(jax.random.PRNGKey(0), (16, 256)) * 2) / 2
    for k in (1, 8, 32):
        _assert_pairs_equal(
            row_topk(x, k, method="threshold", col_block=64),
            row_topk_ref(x, k),
        )
    # crafted row: duplicates of the max magnitude, mixed signs
    row = jnp.array([[1.0, -2.0, 2.0, 0.5, -2.0, 2.0]])
    vals, idx = row_topk(row, 3, method="threshold")
    np.testing.assert_array_equal(np.asarray(idx[0]), [1, 2, 4])
    np.testing.assert_array_equal(np.asarray(vals[0]), [-2.0, 2.0, -2.0])


def test_zero_heavy_rows_select_lowest_index_zeros():
    """Rows with fewer than k nonzeros must fill with the lowest-index
    zeros even when the column padding adds more zeros."""
    x = jnp.zeros((8, 96)).at[:, 5].set(3.0).at[:, 90].set(-1.0)
    got = row_topk(x, 8, method="threshold", col_block=40)
    _assert_pairs_equal(got, row_topk_ref(x, 8))
    assert int(np.asarray(got[1]).max()) < 96  # no padded index leaks


def test_nondivisible_rows_pad_path():
    """R % row_block != 0 exercises ops._pad_rows for both methods."""
    for R in (3, 13, 17):
        x = jax.random.normal(jax.random.PRNGKey(R), (R, 128))
        _assert_pairs_equal(
            row_topk(x, 9, method="threshold"), row_topk_ref(x, 9)
        )
        _assert_pairs_equal(
            row_topk(x, 9, method="loop"), row_topk_ref(x, 9)
        )


def test_single_tile_threshold_kernel():
    """The whole-row kernel with selection="threshold" (no column grid)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 512))
    got = row_topk_pallas(x, 24, selection="threshold")
    _assert_pairs_equal(got, row_topk_ref(x, 24))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_threshold_matches_ref(dtype):
    """Selection (indices) is exact; values/memory compare within 1 ulp —
    the u = m + eta*g compute may be FMA-contracted differently between
    the kernel and the oracle compilations (same tolerance as the
    pre-existing loop-kernel sweep)."""
    R, C, k = 13, 200, 11
    key = jax.random.PRNGKey(5)
    m = jax.random.normal(key, (R, C)).astype(dtype)
    g = jax.random.normal(jax.random.fold_in(key, 1), (R, C)).astype(dtype)
    nm1, v1, i1 = fused_memsgd_update(m, g, 0.37, k, method="threshold")
    nm2, v2, i2 = fused_memsgd_ref(m, g, 0.37, k)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    atol = 1e-6 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(v1, np.float32), np.asarray(v2, np.float32), atol=atol
    )
    np.testing.assert_allclose(
        np.asarray(nm1, np.float32), np.asarray(nm2, np.float32), atol=atol
    )

    # with an identical u (eta=0 path: u == m), outputs are bitwise-equal
    nm1, v1, i1 = fused_memsgd_update(m, g, 0.0, k, method="threshold")
    nm2, v2, i2 = fused_memsgd_ref(m, g, 0.0, k)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(
        np.asarray(v1).view(np.uint8), np.asarray(v2).view(np.uint8)
    )
    np.testing.assert_array_equal(
        np.asarray(nm1).view(np.uint8), np.asarray(nm2).view(np.uint8)
    )


def test_auto_method_bitwise_consistent():
    """"auto" must stay bitwise-identical across the k cutover."""
    x = jax.random.normal(jax.random.PRNGKey(9), (8, 512))
    for k in (4, 8, 9, 32):  # straddles LOOP_MAX_K
        _assert_pairs_equal(row_topk(x, k), row_topk_ref(x, k))


def test_partition_safe_threshold_batched():
    """The jnp (GSPMD) threshold select matches the argmax loop on
    arbitrary leading dims, including tie-heavy inputs."""
    key = jax.random.PRNGKey(11)
    for shape, k in [((4, 7, 200), 16), ((2, 3, 4, 64), 10)]:
        # repro-lint: disable=RL003  (two implementations are compared
        # on the SAME deterministic inputs; stream reuse is the point)
        u = jax.random.normal(key, shape)
        _assert_pairs_equal(
            _row_topk_threshold(u, k), _row_topk_argmax(u, k)
        )
    # repro-lint: disable=RL003  (same deliberate fixed-input reuse)
    u = jnp.round(jax.random.normal(key, (4, 6, 96)) * 2) / 2
    _assert_pairs_equal(
        _row_topk_threshold(u, 12), _row_topk_argmax(u, 12)
    )
