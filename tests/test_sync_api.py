"""Grouped SyncConfig API tests: nested sub-configs, the legacy flat
keyword shim, presets and the cross-flag validate() matrix
(repro.core.distributed)."""
import contextlib
import dataclasses
import warnings

import pytest

from repro.core import buckets as bk
from repro.core.distributed import (
    PodConfig,
    SyncConfig,
    TransportConfig,
    WireConfig,
)


@contextlib.contextmanager
def _no_deprecation():
    """Context that turns any DeprecationWarning into a failure."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        yield


# ---------------------------------------------------------------------------
# grouped construction + compat properties
# ---------------------------------------------------------------------------


def test_grouped_construction_is_warning_free():
    with _no_deprecation():
        cfg = SyncConfig(
            strategy="hierarchical", ratio=0.01, bucketed=True,
            local_steps=4,
            pod=PodConfig(ratio=0.1, dynamic=True, axis="pod"),
            wire=WireConfig(wire="packed", quant=15),
            transport=TransportConfig(repack=True, byte_budget=4096),
        )
    assert cfg.pod.ratio == 0.1 and cfg.pod.dynamic and cfg.pod.axis == "pod"
    assert cfg.wire_cfg.wire == "packed" and cfg.wire_cfg.quant == 15
    assert cfg.transport.repack and cfg.transport.byte_budget == 4096
    assert cfg.local_steps == 4


def test_flat_read_properties_mirror_groups():
    cfg = SyncConfig(
        pod=PodConfig(ratio=0.2, ratios=(0.1, 0.3), mass_target=0.8,
                      dynamic=True, k_max_ratio=0.5, axis="pod"),
        wire=WireConfig(wire="packed", value_dtype="bfloat16", quant=None),
        transport=TransportConfig(repack=True, byte_budget=1024,
                                  overlap=True),
        strategy="hierarchical", bucketed=True,
    )
    assert cfg.pod_ratio == 0.2
    assert cfg.pod_ratios == (0.1, 0.3)
    assert cfg.pod_mass_target == 0.8
    assert cfg.pod_dynamic is True
    assert cfg.pod_k_max_ratio == 0.5
    assert cfg.pod_axis == "pod"
    assert cfg.wire == "packed"
    assert cfg.value_dtype == "bfloat16"
    assert cfg.quant is None
    assert cfg.repack is True
    assert cfg.byte_budget == 1024
    assert cfg.overlap is True


def test_legacy_flat_kwargs_warn_and_land_in_groups():
    with pytest.warns(DeprecationWarning, match="grouped"):
        cfg = SyncConfig(ratio=0.01, bucketed=True, wire="packed",
                         pod_ratio=0.1, repack=False, byte_budget=None)
    assert cfg.wire_cfg.wire == "packed"
    assert cfg.pod.ratio == 0.1


def test_unknown_kwarg_raises_typeerror():
    with pytest.raises(TypeError):
        SyncConfig(ratio=0.01, not_a_field=3)


def test_replace_roundtrips_groups():
    cfg = SyncConfig(strategy="hierarchical", bucketed=True,
                     pod=PodConfig(ratio=0.1, axis="pod"),
                     wire=WireConfig(wire="packed"))
    with _no_deprecation():
        cfg2 = dataclasses.replace(cfg, ratio=0.5)
    assert cfg2.ratio == 0.5
    assert cfg2.pod == cfg.pod
    assert cfg2.wire_cfg == cfg.wire_cfg
    assert cfg2.transport == cfg.transport


def test_with_helpers_are_warning_free():
    cfg = SyncConfig(strategy="hierarchical", bucketed=True)
    with _no_deprecation():
        cfg = cfg.with_pod(axis="pod", dynamic=True)
        cfg = cfg.with_wire(wire="packed")
        cfg = cfg.with_transport(repack=True)
    assert cfg.pod_axis == "pod" and cfg.pod_dynamic
    assert cfg.wire == "packed" and cfg.repack


def test_wire_keyword_double_duty_conflict_raises():
    with pytest.raises(TypeError):
        SyncConfig(wire=WireConfig(wire="packed"),
                   wire_cfg=WireConfig(wire="packed"))


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------


def test_presets_exist_and_validate():
    with _no_deprecation():
        assert SyncConfig.preset("dense").strategy == "dense"
        topk = SyncConfig.preset("topk")
        assert topk.bucketed and topk.wire == "packed"
        q = SyncConfig.preset("qsparse_local")
        assert q.local_steps > 1 and q.quant is not None
        q.validate()
        pb = SyncConfig.preset("pod_budgeted")
        assert pb.strategy == "hierarchical" and pb.pod_dynamic
        assert pb.repack
        # the launcher fills the pod axis in from the mesh
        pb.with_pod(axis="pod").validate()


def test_preset_flat_overrides_are_warning_free():
    with _no_deprecation():
        cfg = SyncConfig.preset("qsparse_local", quant=7, local_steps=2,
                                ratio=0.05)
    assert cfg.quant == 7 and cfg.local_steps == 2 and cfg.ratio == 0.05


def test_unknown_preset_raises():
    with pytest.raises(ValueError, match="unknown SyncConfig preset"):
        SyncConfig.preset("nope")


# ---------------------------------------------------------------------------
# validate() named-error matrix
# ---------------------------------------------------------------------------


def _valid_quant():
    return SyncConfig(bucketed=True, wire=WireConfig(wire="packed",
                                                     quant=15))


@pytest.mark.parametrize(
    "cfg_kw, match",
    [
        (dict(strategy="ring"), "unknown sync strategy"),
        (dict(local_steps=0), "local_steps must be >= 1"),
        (dict(local_steps=2), "local_steps > 1 requires the bucketed"),
        (dict(bucketed=True, wire=WireConfig(quant=0)),
         "quant must be >= 1"),
        (dict(strategy="dense", bucketed=True, wire=WireConfig(quant=15)),
         "dense all-reduce strategy has no quantize stage"),
        (dict(wire=WireConfig(quant=15)),
         "quant requires the bucketed engine"),
        (dict(bucketed=True,
              wire=WireConfig(value_dtype="bfloat16", quant=15)),
         "already-rounded values"),
        (dict(pod=PodConfig(dynamic=True)),
         "PodConfig.dynamic .* requires the bucketed"),
        (dict(strategy="hierarchical", bucketed=True,
              pod=PodConfig(axis="pod"),
              transport=TransportConfig(repack=True)),
         "repack requires PodConfig.dynamic"),
        (dict(transport=TransportConfig(byte_budget=1024)),
         "byte_budget requires the bucketed hierarchical"),
    ],
)
def test_validate_rejects_illegal_combo(cfg_kw, match):
    with pytest.raises(ValueError, match=match):
        SyncConfig(**cfg_kw).validate()


def test_validate_passes_and_chains_on_good_configs():
    cfg = _valid_quant()
    assert cfg.validate() is cfg
    assert SyncConfig().validate().strategy == "sparse_allgather"


def test_validate_checks_pod_ratios_against_plan():
    import jax
    import jax.numpy as jnp

    plan = bk.make_plan(
        {"w": jax.ShapeDtypeStruct((16, 384), jnp.float32),
         "b": jax.ShapeDtypeStruct((40,), jnp.float32)},
        cols=128, dense_below=64,
    )
    cfg = SyncConfig(strategy="hierarchical", bucketed=True,
                     pod=PodConfig(ratios=(0.5,), axis="pod"))
    with pytest.raises(ValueError, match="pod_ratios"):
        cfg.validate(plan)
    ok = cfg.with_pod(ratios=tuple(0.5 for _ in plan.buckets))
    assert ok.validate(plan) is ok


def test_sync_entry_points_validate():
    """The sync entry points call validate(): an illegal combo fails
    with the named error, not a shape error deep in the stack."""
    from repro.core.distributed import bucketed_message_bytes

    import jax
    import jax.numpy as jnp

    plan = bk.make_plan(
        {"w": jax.ShapeDtypeStruct((16, 384), jnp.float32)}, cols=128
    )
    bad = SyncConfig(wire=WireConfig(quant=15))  # quant w/o bucketed
    with pytest.raises(ValueError, match="quant requires the bucketed"):
        bucketed_message_bytes(bad, plan)
