"""Runtime pod-k (k-padded wire) + live-refresh tests.

Fast tier: the masking/accounting machinery (no devices, or a tiny
in-process (1, 1) pod mesh), the live-k wire header, the autotune k
caps, and the delta-spec k_max support bound (the upward-refresh
regression). Slow tier: the dynamic==static / conservation / accounting
probe on a REAL 8-device 2-pod mesh
(``repro.core.selfcheck.dynamic_k_selfcheck``).
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buckets as bk
from repro.core import encoding as enc
from repro.core.distributed import (
    SyncConfig,
    autotune_pod_ratios,
    bucketed_message_bytes,
    bucketed_sync_gradients,
)
from repro.core.selfcheck import bitwise_equal
from repro.kernels.topk_select import mask_live_k
from repro.launch import delta_stream as ds

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# -- mask_live_k: padded static selection == smaller static selection --------


def test_mask_live_k_prefix_equals_smaller_topk():
    """The first k_live slots of a contract-ordered top-k_max ARE the
    top-k_live selection; the masked tail is (0.0, 0)."""
    from repro.kernels.ref import row_topk_ref

    x = jax.random.normal(jax.random.PRNGKey(0), (6, 96))
    k_max, k_live = 16, 5
    v_max, i_max = row_topk_ref(x, k_max)
    v_small, i_small = row_topk_ref(x, k_live)
    vm, im = mask_live_k(v_max, i_max, jnp.int32(k_live))
    np.testing.assert_array_equal(np.asarray(vm[:, :k_live]),
                                  np.asarray(v_small))
    np.testing.assert_array_equal(np.asarray(im[:, :k_live]),
                                  np.asarray(i_small))
    assert np.all(np.asarray(vm[:, k_live:]) == 0.0)
    assert np.all(np.asarray(im[:, k_live:]) == 0)


def test_mask_live_k_jits_over_traced_k():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    _, idx = jax.lax.top_k(jnp.abs(x), 8)
    vals = jnp.take_along_axis(x, idx, axis=-1)

    @jax.jit
    def f(k):
        return mask_live_k(vals, idx.astype(jnp.int32), k)

    v3, _ = f(jnp.int32(3))
    v8, _ = f(jnp.int32(8))  # same trace, different live k
    assert np.all(np.asarray(v3[:, 3:]) == 0.0)
    np.testing.assert_array_equal(np.asarray(v8), np.asarray(vals))


# -- live-k wire header ------------------------------------------------------


def test_encode_live_n_header_word():
    """The live count rides in header word LIVE_N_WORD without touching
    the static layout; decode of the padded message is unchanged and the
    masked tail scatters as no-ops."""
    spec = enc.WireSpec(3, 100, 8, "float32")
    vals = jax.random.normal(jax.random.PRNGKey(2), (3, 8))
    idx = jnp.tile(jnp.arange(8, dtype=jnp.int32), (3, 1))
    vals_m, idx_m = mask_live_k(vals, idx, jnp.int32(5))
    buf = jax.jit(
        lambda v, i, n: enc.encode(spec, v, i, live_n=n)
    )(vals_m, idx_m, jnp.int32(5))
    assert buf.shape == (spec.words,)
    assert int(buf[enc.LIVE_N_WORD]) == 5
    assert enc.live_n_of(buf) == 5
    # layout words untouched: the header still round-trips the spec
    assert enc.WireSpec.from_header(np.asarray(buf)) == spec
    v2, i2 = enc.decode(spec, buf)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(idx_m))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(vals_m))
    # a message without live_n reads back None (word 7 == 0, historical)
    plain = enc.encode(spec, vals, idx)
    assert enc.live_n_of(plain) is None


# -- pod_k_max / autotune caps ----------------------------------------------


def _plan2():
    tree = {"w": jax.ShapeDtypeStruct((64 * 256,), jnp.float32),
            "b": jax.ShapeDtypeStruct((40,), jnp.float32)}
    return bk.make_plan(tree, cols=256, dense_below=64)


def test_pod_k_max_for_bucket_bounds():
    plan = _plan2()
    cfg = SyncConfig(ratio=0.02, strategy="hierarchical", pod_axis="pod",
                     pod_ratios=(1.0, 0.02), bucketed=True, pod_dynamic=True)
    b = 1  # the sparse bucket ("b" packs first: dict key order)
    cols = plan.buckets[b].cols
    # support bound: n_data * k_row (k_row = 5 at ratio 0.02, cols 256)
    assert cfg.pod_k_max_for_bucket(b, cols, n_data=4) == min(
        cols, 4 * cfg.k_for(cols))
    # never below the statically configured pod k
    big = dataclasses.replace(cfg, pod_ratios=(1.0, 0.5))
    assert cfg.pod_k_for_bucket(b, cols) <= cfg.pod_k_max_for_bucket(
        b, cols, n_data=4)
    assert big.pod_k_max_for_bucket(b, cols, n_data=4) == \
        big.pod_k_for_bucket(b, cols)
    # pod_k_max_ratio tightens the cap (but not below the static k)
    capped = dataclasses.replace(cfg, pod_k_max_ratio=8 / cols)
    assert capped.pod_k_max_for_bucket(b, cols, n_data=4) == 8


def test_autotune_k_caps_clamp():
    plan = _plan2()
    cfg = SyncConfig(ratio=0.02, strategy="hierarchical", pod_axis="pod",
                     pod_mass_target=0.999)
    # flat buffers at a 0.999 target want (nearly) the full support
    u = [jnp.ones(s.shape, jnp.float32) for s in plan.buckets]
    free = autotune_pod_ratios(cfg, plan, u, n_data=4)
    capped = autotune_pod_ratios(cfg, plan, u, n_data=4, k_caps=[1, 3])
    b = 1
    assert int(round(free[b] * plan.buckets[b].cols)) > 3
    assert int(round(capped[b] * plan.buckets[b].cols)) == 3


def test_dynamic_accounting_padded_vs_effective():
    plan = _plan2()
    dyn = SyncConfig(ratio=0.02, strategy="hierarchical", pod_axis="pod",
                     pod_ratios=(1.0, 0.02), bucketed=True,
                     pod_dynamic=True, wire="packed")
    with pytest.raises(ValueError, match="n_data"):
        bucketed_message_bytes(dyn, plan)  # padded size needs n_data
    padded = bucketed_message_bytes(dyn, plan, by_level=True, n_data=4)
    live = bucketed_message_bytes(dyn, plan, by_level=True, n_data=4,
                                  pod_ks=(1, 2))
    assert live["cross"] < padded["cross"]
    assert live["intra"] == padded["intra"]  # level 1 is not padded
    # effective accounting equals a static config at the same k
    static = dataclasses.replace(
        dyn, pod_dynamic=False,
        pod_ratios=(1.0, 2 / plan.buckets[1].cols))
    assert live["cross"] == bucketed_message_bytes(
        static, plan, by_level=True)["cross"]


# -- dynamic == static on a tiny in-process pod mesh -------------------------


def test_dynamic_pod_k_matches_static_single_device():
    """(pod=1, data=1) mesh fits in-process: the k-padded dynamic path
    fed a constant live k is bitwise identical to the static path —
    compared on the APPLIED update (params - update) and the memory, the
    state that actually persists (the raw update's all-zero columns may
    differ in zero SIGN at k_live=1: XLA's no-reduce special case; see
    ``mask_live_k``) — for several live ks through one computation."""
    from jax.sharding import PartitionSpec as P

    from repro.utils.compat import make_mesh, shard_map

    if jax.device_count() < 1:
        pytest.skip("no devices")
    mesh = make_mesh((1, 1), ("pod", "data"))
    tree = {"w": jax.random.normal(jax.random.PRNGKey(3), (8, 192))}
    plan = bk.make_plan(tree, cols=64, dense_below=32)
    mem = tuple(jnp.zeros((1,) + s.shape, jnp.float32)
                for s in plan.buckets)
    gs = jax.tree.map(lambda x: x[None], tree)

    def run(cfg, pod_ks=None):
        def sync(mem_, g_):
            kw = {"pod_ks": pod_ks} if pod_ks is not None else {}
            upd, new_mem, _ = bucketed_sync_gradients(
                cfg, plan, jax.tree.map(lambda m: m[0], mem_),
                jax.tree.map(lambda x: x[0], g_), jnp.float32(0.4), **kw)
            return upd, jax.tree.map(lambda m: m[None], new_mem)

        spec = jax.tree.map(lambda _: P(("pod", "data")), mem)
        gspec = jax.tree.map(lambda _: P(("pod", "data")), gs)
        return shard_map(
            sync, mesh=mesh, in_specs=(spec, gspec),
            out_specs=(jax.tree.map(lambda _: P(), tree), spec))(mem, gs)

    for wire in ("unpacked", "packed"):
        dyn = SyncConfig(ratio=0.1, strategy="hierarchical",
                         data_axes=("data",), pod_axis="pod",
                         bucketed=True, bucket_cols=64, wire=wire,
                         pod_ratios=(0.05,), pod_dynamic=True)
        for k_live in (1, 3, 6):
            static = dataclasses.replace(
                dyn, pod_dynamic=False, pod_ratios=(k_live / 64,))
            upd_s, mem_s = run(static)
            upd_d, mem_d = run(dyn, pod_ks=jnp.asarray([k_live], jnp.int32))
            applied_s = jax.tree.map(lambda t, u: t - u, tree, upd_s)
            applied_d = jax.tree.map(lambda t, u: t - u, tree, upd_d)
            assert bitwise_equal((applied_s, mem_s), (applied_d, mem_d)), \
                (wire, k_live)


def test_pod_dynamic_requires_pod_ks():
    plan = _plan2()
    cfg = SyncConfig(ratio=0.02, strategy="hierarchical",
                     data_axes=("data",), pod_axis="pod", bucketed=True,
                     pod_dynamic=True)
    mem = tuple(jnp.zeros(s.shape, jnp.float32) for s in plan.buckets)
    tree = {"w": jnp.zeros((64 * 256,), jnp.float32),
            "b": jnp.zeros((40,), jnp.float32)}
    with pytest.raises(ValueError, match="pod_ks"):
        bucketed_sync_gradients(cfg, plan, mem, tree, jnp.float32(0.1))
    # the converse misconfiguration is loud too: pod_dynamic on a flat/
    # pod-less sync would silently drop the k schedule
    for bad in (dataclasses.replace(cfg, strategy="sparse_allgather"),
                dataclasses.replace(cfg, pod_axis=None)):
        with pytest.raises(ValueError, match="silently ignore"):
            bucketed_sync_gradients(
                bad, plan, mem, tree, jnp.float32(0.1),
                pod_ks=jnp.asarray([1, 2], jnp.int32))


# -- delta spec follows k_max (the upward-refresh regression) ----------------


def test_delta_spec_survives_upward_k_refresh():
    """make_delta_spec sized from the step-0 pod k would overflow after
    a refresh RAISES k; with pod_dynamic it is sized at the bucket's
    k_max, so an update whose support reflects any live k <= k_max
    round-trips exactly."""
    plan = _plan2()
    n_pods, n_data = 2, 4
    cols = plan.buckets[1].cols
    k0, k_hi = 2, 12  # step-0 autotuned k, refreshed-upward k
    dyn = SyncConfig(ratio=0.02, strategy="hierarchical", pod_axis="pod",
                     pod_ratios=(1.0, k0 / cols), bucketed=True,
                     pod_dynamic=True)
    k_max = dyn.pod_k_max_for_bucket(1, cols, n_data)
    assert k0 < k_hi <= k_max
    dspec = ds.make_delta_spec(plan, dyn, workers=n_pods * n_data,
                               n_pods=n_pods)
    assert dspec.wires[1].k == min(cols, n_pods * k_max)
    # the OLD sizing (current pod k) could not carry the k_hi support
    static = dataclasses.replace(dyn, pod_dynamic=False)
    old = ds.make_delta_spec(plan, static, workers=n_pods * n_data,
                             n_pods=n_pods)
    assert old.wires[1].k == n_pods * k0 < n_pods * k_hi

    # simulate the post-refresh update: n_pods * k_hi nonzeros per row
    rng = np.random.default_rng(0)
    buf = np.zeros(plan.buckets[1].shape, np.float32)
    for r in range(buf.shape[0]):
        pos = rng.choice(cols, size=n_pods * k_hi, replace=False)
        buf[r, pos] = rng.standard_normal(n_pods * k_hi)
    bufs = [jnp.zeros(plan.buckets[0].shape, jnp.float32),
            jnp.asarray(buf)]
    msgs = ds.encode_delta_bufs(dspec, bufs)
    dec = ds.decode_delta(dspec, msgs)
    rec = bk.pack(plan, dec, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(rec[1]), buf)
    # the old spec drops mass for the same update (the regression)
    old_rec = bk.pack(
        plan, ds.decode_delta(old, ds.encode_delta_bufs(old, bufs)),
        dtype=jnp.float32)
    assert not np.array_equal(np.asarray(old_rec[1]), buf)


# -- slow: real 2-pod mesh probe ---------------------------------------------


@pytest.mark.slow
def test_dynamic_k_selfcheck_on_2pod_mesh():
    """dynamic==static bitwise, conservation under a switched live k,
    and padded accounting, on a REAL 8-device 2-pod mesh (shared probe:
    ``repro.core.selfcheck.dynamic_k_selfcheck`` — the same harness the
    refresh bench runs)."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        from repro.core.selfcheck import dynamic_k_selfcheck
        from repro.utils.compat import make_mesh

        rec = dynamic_k_selfcheck(make_mesh((2, 4), ("pod", "data")))
        print(json.dumps(rec))
        """
    ).format(src=SRC)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["dynamic_matches_static"], rec
    assert rec["conservation_max_err"] < 1e-5, rec
    assert rec["accounting_exact"], rec
