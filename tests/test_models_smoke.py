"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step + one decode step on CPU, asserting output
shapes and absence of NaNs. Full configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke_config
from repro.models import build_model


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    b = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }
    if cfg.n_prefix_embeddings:
        b["prefix_embeds"] = jnp.zeros(
            (B, cfg.n_prefix_embeddings, cfg.d_model), jnp.bfloat16
        )
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch, key):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(cfg)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_no_nans(arch, key):
    """One full gradient step with the paper's optimizer on the reduced
    arch; params stay finite and the loss is differentiable."""
    from repro.core import leaf_compressor_from_ratio, memsgd, constant_eta
    from repro.optim import apply_updates

    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(cfg)
    tx = memsgd(leaf_compressor_from_ratio(0.05), constant_eta(0.05))
    s = tx.init(params)

    @jax.jit
    def step(params, s):
        grads, metrics = jax.grad(model.loss, has_aux=True)(params, batch)
        u, s = tx.update(grads, s)
        return apply_updates(params, u), s, metrics

    params, s, metrics = step(params, s)
    leaves = jax.tree.leaves(params)
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32)))) for l in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch, key):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(key)
    cache = model.init_cache(2, 64)
    logits, cache2 = jax.jit(model.decode_step)(
        params, cache, jnp.zeros((2,), jnp.int32)
    )
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache index advanced
    idx = cache2["index"] if "index" in cache2 else None
    if idx is not None:
        assert int(idx) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The production configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "rwkv6-3b": (32, 2560, 8960, 65536),
        "qwen1.5-4b": (40, 2560, 6912, 151936),
        "yi-9b": (48, 4096, 11008, 64000),
        "musicgen-medium": (48, 1536, 6144, 2048),
        "qwen3-moe-30b-a3b": (48, 2048, 768, 151936),
        "qwen3-4b": (36, 2560, 9728, 151936),
        "internvl2-26b": (48, 6144, 16384, 92553),
        "granite-3-8b": (40, 4096, 12800, 49155),
        "recurrentgemma-9b": (38, 4096, 12288, 256000),
        "granite-moe-3b-a800m": (32, 1536, 512, 49155),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == expect
    assert cfg.source
    if arch == "qwen3-moe-30b-a3b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 8
    if arch == "granite-moe-3b-a800m":
        assert cfg.moe.n_experts == 40 and cfg.moe.top_k == 8
    if arch == "qwen1.5-4b":
        assert cfg.qkv_bias
    if arch == "qwen3-4b":
        assert cfg.qk_norm
    if arch == "recurrentgemma-9b":
        assert cfg.hybrid.pattern == ("rec", "rec", "attn")
        assert cfg.n_kv_heads == 1
    if arch == "yi-9b":
        assert cfg.n_kv_heads == 4


def test_shape_configs_match_assignment():
    s = SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)
