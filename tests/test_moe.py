"""MoE layer tests: capacity dispatch vs dense-dispatch oracle, load
balancing, capacity semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import moe as M


@pytest.fixture()
def cfg():
    return get_smoke_config("qwen3-moe-30b-a3b")


def _dense_dispatch_oracle(p, cfg, x):
    """All-experts-for-all-tokens reference (exact, no capacity drops)."""
    m = cfg.moe
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    xc = x.astype(p["w_gate"].dtype)
    h = jnp.einsum("bsd,edf->ebsf", xc, p["w_gate"])
    u = jnp.einsum("bsd,edf->ebsf", xc, p["w_up"])
    y = jnp.einsum("ebsf,efd->ebsd", jax.nn.silu(h) * u, p["w_down"])
    B, S, D = x.shape
    out = jnp.zeros((B, S, D), y.dtype)
    for kk in range(m.top_k):
        sel = jnp.take_along_axis(
            jnp.moveaxis(y, 0, -2),  # (B,S,E,D)
            top_i[:, :, kk][..., None, None], axis=2
        )[:, :, 0]
        out = out + sel * top_w[:, :, kk][..., None].astype(y.dtype)
    return out


def test_capacity_dispatch_matches_oracle_at_high_capacity(cfg):
    """With capacity_factor high enough that nothing is dropped, the
    scatter-based dispatch must equal the dense-dispatch oracle."""
    p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    got, _ = M.moe_ffn(p, cfg, x, capacity_factor=8.0)
    want = _dense_dispatch_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_capacity_drops_bounded(cfg):
    """At capacity_factor=1.0 total output energy is close to oracle (only
    overflow tokens differ)."""
    p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
    got, _ = M.moe_ffn(p, cfg, x, capacity_factor=1.0)
    want = _dense_dispatch_oracle(p, cfg, x)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.5  # most tokens unaffected


def test_decode_batch_grouping(cfg):
    """S=1 decode groups over the batch: output finite, correct shape."""
    p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 1, cfg.d_model))
    out, aux = M.moe_ffn(p, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_aux_loss_uniform_router_is_one_times_weight(cfg):
    """With a perfectly uniform router, E * sum f_e p_e = 1 (times the
    aux weight) — the minimum of the load-balance loss."""
    p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, cfg.d_model))
    _, aux = M.moe_ffn(p, cfg, x)
    # frac_tokens concentrates on argmax ties -> still ~uniform with zeros
    # router all logits equal: top_k picks first experts; p_e uniform
    # => aux = weight * E * sum_e f_e * (1/E) = weight * sum f_e = weight
    np.testing.assert_allclose(float(aux), cfg.moe.aux_loss_weight, rtol=1e-3)


def test_moe_grad_flows_to_router(cfg):
    p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, cfg.d_model))

    def loss(p):
        out, aux = M.moe_ffn(p, cfg, x)
        return jnp.sum(out**2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.linalg.norm(g["router"])) > 0
    assert float(jnp.linalg.norm(g["w_gate"])) > 0
