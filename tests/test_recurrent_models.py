"""RWKV6 and Griffin recurrence equivalence tests (chunked/parallel vs
exact sequential) and decode/forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model, griffin, rwkv


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_wkv_chunked_matches_sequential(chunk):
    key = jax.random.PRNGKey(42)
    B, T, H, n = 2, 64, 3, 8
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, n))
    k = jax.random.normal(ks[1], (B, T, H, n))
    v = jax.random.normal(ks[2], (B, T, H, n))
    log_w = -2.0 * jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, n)))
    bonus = jax.random.normal(ks[4], (H, n)) * 0.1
    S0 = jnp.zeros((B, H, n, n))
    o_c, S_c = rwkv.wkv_chunked(r, k, v, log_w, bonus, S0, chunk)
    S = S0
    outs = []
    for t in range(T):
        o, S = rwkv.wkv_step(r[:, t], k[:, t], v[:, t], log_w[:, t], bonus, S)
        outs.append(o)
    o_s = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_s),
                               atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S),
                               atol=5e-5, rtol=5e-5)


def test_wkv_chunked_nonzero_initial_state():
    key = jax.random.PRNGKey(7)
    B, T, H, n = 1, 32, 2, 4
    ks = jax.random.split(key, 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, n)) for i in range(3))
    log_w = -1.0 * jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, n)))
    bonus = jnp.zeros((H, n))
    S0 = jax.random.normal(ks[4], (B, H, n, n))
    o_c, S_c = rwkv.wkv_chunked(r, k, v, log_w, bonus, S0, 8)
    S = S0
    outs = []
    for t in range(T):
        o, S = rwkv.wkv_step(r[:, t], k[:, t], v[:, t], log_w[:, t], bonus, S)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(jnp.stack(outs, 1)),
                               atol=5e-5, rtol=5e-5)


def test_rwkv_decode_matches_forward():
    cfg = get_smoke_config("rwkv6-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits_full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t])
        outs.append(lg)
    logits_dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(
        np.asarray(logits_full, np.float32),
        np.asarray(logits_dec, np.float32),
        atol=0.2, rtol=0.1,
    )


def test_rwkv_decay_bounded():
    """Data-dependent log-decay stays in (-DECAY_CLAMP, 0) — the fp32
    safety envelope of the chunked scan."""
    cfg = get_smoke_config("rwkv6-3b")
    p = rwkv.init_time_mix(jax.random.PRNGKey(0), cfg, jnp.float32)
    xw = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 10
    lw = rwkv._decay_log(p, xw)
    assert float(jnp.max(lw)) < 0.0
    assert float(jnp.min(lw)) > -rwkv.DECAY_CLAMP


# ---------------------------------------------------------------------------
# Griffin / RG-LRU
# ---------------------------------------------------------------------------


def test_rg_lru_assoc_scan_matches_sequential():
    cfg = get_smoke_config("recurrentgemma-9b")
    p = griffin.init_recurrent_block(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T = 2, 24
    R = cfg.hybrid.lru_width
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, R))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (B, R))
    y_par, h_par = griffin.rg_lru(p, x, h0)
    h = h0
    outs = []
    for t in range(T):
        y, h = griffin.rg_lru_step(p, x[:, t], h)
        outs.append(y)
    y_seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h),
                               atol=1e-4, rtol=1e-4)


def test_causal_conv_matches_step():
    cfg = get_smoke_config("recurrentgemma-9b")
    p = griffin.init_recurrent_block(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T, R = 1, 10, cfg.hybrid.lru_width
    x = jax.random.normal(jax.random.PRNGKey(3), (B, T, R))
    W = cfg.hybrid.conv_width
    out_full, _ = griffin.causal_conv(p, x, jnp.zeros((B, W - 1, R)))
    carry = jnp.zeros((B, W - 1, R))
    outs = []
    for t in range(T):
        window = jnp.concatenate([carry, x[:, t:t + 1]], axis=1)
        o = jnp.sum(window * p["conv_w"][None], axis=1) + p["conv_b"]
        outs.append(o)
        carry = window[:, 1:]
    np.testing.assert_allclose(np.asarray(out_full),
                               np.asarray(jnp.stack(outs, 1)),
                               atol=1e-5, rtol=1e-5)


def test_griffin_decode_matches_forward():
    cfg = get_smoke_config("recurrentgemma-9b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits_full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t])
        outs.append(lg)
    logits_dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(
        np.asarray(logits_full, np.float32),
        np.asarray(logits_dec, np.float32),
        atol=0.2, rtol=0.1,
    )


def test_griffin_pattern():
    cfg = get_smoke_config("recurrentgemma-9b").replace(n_layers=7)
    kinds = griffin.layer_kinds(cfg)
    assert kinds == ("rec", "rec", "attn", "rec", "rec", "attn", "rec")


def test_lru_decay_magnitude():
    """a_t in (0,1): state cannot blow up."""
    cfg = get_smoke_config("recurrentgemma-9b")
    p = griffin.init_recurrent_block(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 50, cfg.hybrid.lru_width)) * 5
    h0 = jnp.zeros((1, cfg.hybrid.lru_width))
    y, h = griffin.rg_lru(p, x, h0)
    assert bool(jnp.all(jnp.isfinite(y)))
