"""Mem-SGD (Algorithm 1) semantics and convergence tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as C
from repro.core import theory
from repro.core.memsgd import (
    constant_eta,
    leaf_compressor_from_ratio,
    memsgd,
    memsgd_flat,
)
from repro.optim import apply_updates, sgd


def _quad_grad(w, target):
    return w - target


def test_memsgd_equals_sgd_when_k_is_d():
    """With the identity compressor (k=d) Mem-SGD IS vanilla SGD."""
    d, eta = 16, 0.1
    target = jnp.linspace(-1, 1, d)
    tx_mem = memsgd_flat(C.identity(), constant_eta(eta), d)
    tx_sgd = sgd(eta)
    w1 = jnp.zeros(d)
    w2 = jnp.zeros(d)
    s1, s2 = tx_mem.init(w1), tx_sgd.init(w2)
    for _ in range(25):
        u1, s1 = tx_mem.update(_quad_grad(w1, target), s1)
        u2, s2 = tx_sgd.update(_quad_grad(w2, target), s2)
        w1, w2 = apply_updates(w1, u1), apply_updates(w2, u2)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-6)
    # memory stays exactly zero with lossless compression
    assert float(jnp.max(jnp.abs(s1.memory))) == 0.0


def test_memsgd_converges_on_quadratic_topk():
    """Stepsize must respect the d/k delay (Remark 2.5): eta ~ O(k/d).
    (With eta >> k/d the scheme oscillates — the paper's 'without delay'
    failure mode, exercised in test_large_eta_without_delay_diverges.)"""
    d, k = 64, 4
    target = jnp.ones(d)
    tx = memsgd_flat(C.top_k(k), constant_eta(0.5 * k / d), d)
    w = jnp.zeros(d)
    s = tx.init(w)
    for _ in range(1500):
        u, s = tx.update(_quad_grad(w, target), s)
        w = apply_updates(w, u)
    assert float(jnp.linalg.norm(w - target)) < 1e-3


def test_memsgd_converges_on_quadratic_randk():
    d, k = 64, 4
    target = jnp.ones(d)
    tx = memsgd_flat(C.rand_k(k), constant_eta(0.5 * k / d), d, seed=3)
    w = jnp.zeros(d)
    s = tx.init(w)
    for _ in range(3000):
        u, s = tx.update(_quad_grad(w, target), s)
        w = apply_updates(w, u)
    assert float(jnp.linalg.norm(w - target)) < 1e-2


def test_large_eta_without_delay_diverges_then_theorem_shift_fixes_it():
    """Reproduces the paper's Fig. 2 'without delay' observation in
    miniature: constant eta >> k/d oscillates; the Theorem 2.4 schedule
    with shift a = (alpha+2) d/k converges from the same start."""
    d, k = 64, 4
    target = jnp.ones(d)
    # big constant eta: diverges (norm grows)
    tx_bad = memsgd_flat(C.top_k(k), constant_eta(0.25), d)
    w = jnp.zeros(d)
    s = tx_bad.init(w)
    for _ in range(200):
        u, s = tx_bad.update(_quad_grad(w, target), s)
        w = apply_updates(w, u)
    assert float(jnp.linalg.norm(w - target)) > 10.0
    # theorem schedule: converges (mu = 1 quadratic)
    a = theory.theoretical_shift(d, k, alpha=5.0)
    tx_ok = memsgd_flat(C.top_k(k), theory.theorem_stepsize(1.0, a), d)
    w = jnp.zeros(d)
    s = tx_ok.init(w)
    for _ in range(3000):
        u, s = tx_ok.update(_quad_grad(w, target), s)
        w = apply_updates(w, u)
    assert float(jnp.linalg.norm(w - target)) < 0.05


def test_no_coordinate_starvation():
    """Error feedback guarantees every coordinate is eventually applied —
    the motivating property (Section 1): without memory, top-1 on this
    gradient would never touch the small coordinates."""
    d = 8
    # gradient with one dominant coordinate
    g = jnp.array([10.0, 1, 1, 1, 1, 1, 1, 1])
    tx = memsgd_flat(C.top_k(1), constant_eta(0.1), d)
    w = jnp.zeros(d)
    s = tx.init(w)
    for _ in range(50):
        u, s = tx.update(g, s)
        w = apply_updates(w, u)
    assert float(jnp.min(jnp.abs(w))) > 0.0, "a coordinate was starved"


def test_eta_applied_at_insertion_time():
    """Paper: gradients are scaled by eta_t when they ENTER memory. With a
    decaying schedule the retrieved value must carry the OLD eta."""
    d = 2
    etas = [1.0, 0.0]  # second step: eta=0 — only memory can move w
    sched = lambda t: jnp.where(t == 0, 1.0, 0.0)
    tx = memsgd_flat(C.top_k(1), sched, d)
    w = jnp.zeros(d)
    s = tx.init(w)
    g = jnp.array([2.0, 1.0])
    u, s = tx.update(g, s)  # applies coordinate 0 (value 2), memory [0, 1]
    w = apply_updates(w, u)
    np.testing.assert_allclose(np.asarray(w), [-2.0, 0.0])
    u, s = tx.update(g, s)  # eta=0: u = m = [0,1] -> applies old eta*g_1
    w = apply_updates(w, u)
    np.testing.assert_allclose(np.asarray(w), [-2.0, -1.0])


def test_memory_invariant_sum_preserved():
    """x_t + (-applied cumsum) identity: x_t - x_0 + m_t = -sum eta_j g_j
    (equation (12): virtual sequence)."""
    d = 16
    key = jax.random.PRNGKey(0)
    tx = memsgd_flat(C.top_k(2), constant_eta(0.3), d)
    w = jnp.zeros(d)
    s = tx.init(w)
    acc = jnp.zeros(d)
    for i in range(30):
        g = jax.random.normal(jax.random.fold_in(key, i), (d,))
        acc = acc + 0.3 * g
        u, s = tx.update(g, s)
        w = apply_updates(w, u)
    np.testing.assert_allclose(
        np.asarray(w - s.memory), np.asarray(-acc), rtol=1e-4, atol=1e-5
    )


def test_tree_memsgd_on_pytree_params():
    params = {"a": jnp.zeros((4, 4)), "b": jnp.zeros((7,))}
    target = {"a": jnp.ones((4, 4)), "b": -jnp.ones((7,))}
    tx = memsgd(leaf_compressor_from_ratio(0.2), constant_eta(0.3))
    s = tx.init(params)
    for _ in range(400):
        grads = jax.tree.map(lambda w, t: w - t, params, target)
        u, s = tx.update(grads, s)
        params = apply_updates(params, u)
    err = max(
        float(jnp.max(jnp.abs(params[k] - target[k]))) for k in params
    )
    assert err < 5e-3


def test_memory_norm_bounded_lemma32():
    """Lemma 3.2 (spirit): with eta_t = 8/(mu(a+t)), a = alpha*d/k, the
    memory norm stays O(eta_t * d/k * G)."""
    d, k = 64, 4
    mu, G = 1.0, 8.0  # quadratic f = 0.5||w - t||^2 has mu = L = 1
    a = theory.theoretical_shift(d, k, alpha=5.0)
    sched = theory.theorem_stepsize(mu, a)
    tx = memsgd_flat(C.top_k(k), sched, d)
    target = jnp.ones(d) * 2
    w = jnp.zeros(d)
    s = tx.init(w)
    c_alpha = np.sqrt(4 * 5.0 / (5.0 - 4.0) * 2)  # sqrt(4a/(a-4)) slack x2
    for t in range(300):
        g = w - target
        u, s = tx.update(g, s)
        w = apply_updates(w, u)
        eta_t = float(sched(jnp.asarray(t)))
        bound = c_alpha * eta_t * (d / k) * G
        assert float(jnp.linalg.norm(s.memory)) <= bound, f"t={t}"
