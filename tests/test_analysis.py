"""Tests for the ``repro.analysis`` invariant linter.

Deliberately jax-free (stdlib + pytest only): the CI lint job runs this
file without the jax toolchain, the same way it runs the linter itself.

The fixture corpora under ``tests/fixtures/analysis/`` are self-
describing: every line a rule must flag carries ``# EXPECT: RL00x``, and
the per-fixture test asserts the finding set EQUALS the expectation set
— a fixture false positive fails just as loudly as a miss.
"""
import json
import os
import re
import subprocess
import sys

import pytest

from repro.analysis import analyze_paths, analyze_source, all_rules
from repro.analysis import baseline as bl
from repro.analysis import walker

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "analysis")
EXPECT_RE = re.compile(r"#\s*EXPECT:\s*(RL\d{3}(?:\s*,\s*RL\d{3})*)\s*$")

FIXTURE_FILES = sorted(
    f for f in os.listdir(FIXTURES) if f.endswith(".py"))


def _expected_findings(path):
    out = set()
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, start=1):
            m = EXPECT_RE.search(line)
            if m:
                for rule in m.group(1).split(","):
                    out.add((rule.strip(), lineno))
    return out


def _analyze_file(path):
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    rel = os.path.relpath(path, REPO)
    return analyze_source(path, rel, text, all_rules())


# ---------------------------------------------------------------------------
# fixture corpora: findings == EXPECT annotations, exactly


@pytest.mark.parametrize("name", FIXTURE_FILES)
def test_fixture_corpus(name):
    path = os.path.join(FIXTURES, name)
    expected = _expected_findings(path)
    assert expected, f"fixture {name} has no EXPECT annotations"
    got = {(f.rule, f.line) for f in _analyze_file(path)}
    missing = expected - got
    unexpected = got - expected
    assert not missing, f"{name}: rules missed {sorted(missing)}"
    assert not unexpected, f"{name}: false positives {sorted(unexpected)}"


def test_every_rule_has_fixture_coverage():
    covered = set()
    for name in FIXTURE_FILES:
        covered.update(
            r for r, _ in _expected_findings(os.path.join(FIXTURES, name)))
    assert {r.id for r in all_rules()} <= covered


def test_fixtures_carry_skip_marker():
    # default directory walks must never see the corpora
    assert list(walker.iter_py_files([FIXTURES])) == []
    visible = list(walker.iter_py_files([FIXTURES], honor_markers=False))
    assert len(visible) == len(FIXTURE_FILES)


# ---------------------------------------------------------------------------
# suppression directives


def test_trailing_suppression():
    src = (
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (2,))\n"
        "    b = jax.random.normal(key, (2,))  # repro-lint: disable=RL003\n"
        "    return a, b\n"
    )
    assert analyze_source("x.py", "x.py", src, all_rules()) == []


def test_standalone_suppression_skips_comment_lines():
    src = (
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (2,))\n"
        "    # repro-lint: disable=RL003  (reason line one\n"
        "    # continues over a second comment line)\n"
        "    b = jax.random.normal(key, (2,))\n"
        "    return a, b\n"
    )
    assert analyze_source("x.py", "x.py", src, all_rules()) == []


def test_suppression_is_per_rule():
    src = (
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (2,))\n"
        "    b = jax.random.normal(key, (2,))  # repro-lint: disable=RL001\n"
        "    return a, b\n"
    )
    findings = analyze_source("x.py", "x.py", src, all_rules())
    assert [f.rule for f in findings] == ["RL003"]


def test_multiline_statement_trailing_suppression():
    src = (
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (2,))\n"
        "    b = jax.random.normal(\n"
        "        key, (2,))  # repro-lint: disable=RL003\n"
        "    return a, b\n"
    )
    assert analyze_source("x.py", "x.py", src, all_rules()) == []


def test_malformed_directive_reported():
    src = "x = 1  # repro-lint: disable=RL01\n"
    problems = walker.directive_problems(src)
    assert len(problems) == 1 and problems[0][0] == 1

    # format-valid but unregistered ids are typos too
    problems = walker.directive_problems(
        "x = 1  # repro-lint: disable=RL999\n")
    assert len(problems) == 1

    assert walker.directive_problems(
        "x = 1  # repro-lint: disable=RL001,RL003  (reason)\n") == []
    assert walker.directive_problems(
        "# repro-lint: skip-file\n") == []


def test_unknown_verb_reported():
    problems = walker.directive_problems("# repro-lint: disalbe=RL001\n")
    assert len(problems) == 1


def test_skip_file_marker_must_be_near_top():
    late = "\n" * 30 + "# repro-lint: skip-file\n"
    _, skip = walker.parse_directives(late)
    assert not skip
    _, skip = walker.parse_directives("# repro-lint: skip-file\nx = 1\n")
    assert skip


def test_syntax_error_becomes_rl000():
    findings = analyze_source("x.py", "x.py", "def f(:\n", all_rules())
    assert [f.rule for f in findings] == ["RL000"]


# ---------------------------------------------------------------------------
# baseline


def _rl003_findings():
    src = (
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (2,))\n"
        "    b = jax.random.normal(key, (2,))\n"
        "    return a, b\n"
    )
    return analyze_source("x.py", "x.py", src, all_rules())


def test_baseline_round_trip(tmp_path):
    findings = _rl003_findings()
    assert findings
    path = str(tmp_path / "baseline.json")
    n = bl.write_baseline(findings, path)
    assert n == len(findings)
    new, old, stale = bl.split_by_baseline(findings, bl.load_baseline(path))
    assert new == [] and len(old) == len(findings) and stale == []


def test_baseline_is_line_number_independent(tmp_path):
    findings = _rl003_findings()
    path = str(tmp_path / "baseline.json")
    bl.write_baseline(findings, path)
    # unrelated edits shift line numbers but not the offending text
    shifted = [type(f)(f.rule, f.path, f.line + 7, f.col, f.message, f.text)
               for f in findings]
    new, old, stale = bl.split_by_baseline(shifted, bl.load_baseline(path))
    assert new == [] and stale == []


def test_baseline_resurfaces_on_text_change(tmp_path):
    findings = _rl003_findings()
    path = str(tmp_path / "baseline.json")
    bl.write_baseline(findings, path)
    edited = [type(f)(f.rule, f.path, f.line, f.col, f.message,
                      f.text + "  # touched")
              for f in findings]
    new, old, stale = bl.split_by_baseline(edited, bl.load_baseline(path))
    assert len(new) == len(findings)
    assert len(stale) == len(findings)  # the old fingerprints are gone


def test_corrupt_baseline_raises_named_error(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text("{not json", encoding="utf-8")
    with pytest.raises(ValueError, match="corrupt baseline"):
        bl.load_baseline(str(path))
    path.write_text('{"version": 1}', encoding="utf-8")
    with pytest.raises(ValueError, match="no 'findings' key"):
        bl.load_baseline(str(path))


# ---------------------------------------------------------------------------
# CLI (the exact CI-invoked entry point, driven via subprocess)


def _run_cli(*args, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd, env=env, timeout=300)


def test_cli_list_rules():
    proc = _run_cli("--list-rules")
    assert proc.returncode == 0
    for rule in all_rules():
        assert rule.id in proc.stdout


def test_cli_json_on_fixture():
    path = os.path.join("tests", "fixtures", "analysis",
                        "rl005_wire_header.py")
    proc = _run_cli(path, "--include-skipped", "--no-baseline",
                    "--format=json")
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    got = {(f["rule"], f["line"]) for f in payload["findings"]}
    assert got == _expected_findings(os.path.join(REPO, path))


def test_cli_github_format():
    path = os.path.join("tests", "fixtures", "analysis",
                        "rl006_silent_fallback.py")
    proc = _run_cli(path, "--include-skipped", "--no-baseline",
                    "--format=github")
    assert proc.returncode == 1
    lines = [l for l in proc.stdout.splitlines() if l]
    assert lines and all(l.startswith("::error file=") for l in lines)
    assert any("RL006" in l for l in lines)


def test_cli_clean_repo_with_baseline():
    """The committed baseline makes the default CI invocation pass —
    zero NON-baselined findings on src/ benchmarks/ tests/."""
    proc = _run_cli("--format=github")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_unknown_rule_is_usage_error():
    proc = _run_cli("--rules", "RL999")
    assert proc.returncode == 2


# ---------------------------------------------------------------------------
# seeded-defect drills: mutate a scratch copy of launch/train.py and
# prove the CI-invoked command catches the regression


TRAIN = os.path.join(REPO, "src", "repro", "launch", "train.py")
# the line right after the donating step call and BEFORE the state
# unpack: params/memory/opt are donated-and-not-yet-rebound here
ANCHOR = "cache = _cache_sizes(step, H)"


def _seed_train(tmp_path, inserted_line):
    with open(TRAIN, encoding="utf-8") as fh:
        src = fh.read()
    assert ANCHOR in src, "train.py drain anchor moved; update the drill"
    indent = " " * 8
    src = src.replace(ANCHOR, f"{inserted_line}\n{indent}{ANCHOR}", 1)
    scratch = tmp_path / "train_scratch.py"
    scratch.write_text(src, encoding="utf-8")
    return str(scratch)


def test_seeded_rl001_is_caught(tmp_path):
    scratch = _seed_train(
        tmp_path, 'print(float(metrics["loss"]))')
    proc = _run_cli(scratch, "--no-baseline")
    assert proc.returncode == 1
    assert "RL001" in proc.stdout


def test_seeded_rl002_is_caught(tmp_path):
    scratch = _seed_train(tmp_path, "lint_canary = [params]")
    proc = _run_cli(scratch, "--no-baseline")
    assert proc.returncode == 1
    assert "RL002" in proc.stdout


def test_unseeded_train_is_clean():
    proc = _run_cli(os.path.join("src", "repro", "launch", "train.py"),
                    "--no-baseline")
    assert proc.returncode == 0, proc.stdout


# ---------------------------------------------------------------------------
# registry


def test_registry_is_complete_and_documented():
    rules = all_rules()
    assert [r.id for r in rules] == sorted(r.id for r in rules)
    assert len(rules) >= 6
    for r in rules:
        assert r.name and r.invariant and r.doc


def test_analyze_paths_relative_output(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "import jax\n"
        "def g(key):\n"
        "    a = jax.random.normal(key, (2,))\n"
        "    b = jax.random.normal(key, (2,))\n",
        encoding="utf-8")
    findings = analyze_paths([str(f)], root=str(tmp_path))
    assert [f_.rule for f_ in findings] == ["RL003"]
    assert findings[0].path == "mod.py"
