"""Infinite-iterator guards (repro.data): ``token_batches`` and
``ShardedBatcher`` wrap streams that never terminate — ``len()`` and
``list()`` misuse must fail fast instead of hanging forever (this has
burned real CPU time). ``take()`` is the sanctioned bound."""
import itertools

import numpy as np
import pytest

from repro.data import InfiniteStream, ShardedBatcher, take, token_batches


def test_token_batches_len_raises():
    it = token_batches(50, 2, 8, seed=0)
    assert isinstance(it, InfiniteStream)
    with pytest.raises(TypeError, match="take"):
        len(it)


def test_streams_stay_truthy():
    """bool() must not fall back to the raising __len__ — `if stream:`
    guards keep working."""
    assert bool(token_batches(50, 2, 8, seed=0))
    it = token_batches(50, 2, 8, seed=0)
    assert (it or None) is it


def test_token_batches_list_fails_fast():
    it = token_batches(50, 2, 8, seed=0)
    with pytest.raises(RuntimeError, match="never terminate"):
        list(it)
    with pytest.raises(RuntimeError, match="never terminate"):
        tuple(token_batches(50, 2, 8, seed=0))


def test_take_and_islice_still_work():
    it = token_batches(50, 2, 8, seed=3)
    got = list(take(it, 3))
    assert len(got) == 3
    assert got[0]["tokens"].shape == (2, 8)
    # islice wraps with its own iterator, so list() of it is fine too
    more = list(itertools.islice(it, 2))
    assert len(more) == 2
    # the stream is shared state and take() consumes EXACTLY its bound:
    # take pulled 3, islice pulled 2 — the next item is the 6th
    nxt = next(it)
    raw = list(itertools.islice(
        iter(token_batches(50, 2, 8, seed=3)), 6))
    np.testing.assert_array_equal(nxt["tokens"], raw[5]["tokens"])


def test_token_batches_determinism_preserved():
    """Wrapping in InfiniteStream must not change the stream contents."""
    a = list(take(token_batches(97, 3, 5, seed=11), 4))
    b = list(take(token_batches(97, 3, 5, seed=11), 4))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        np.testing.assert_array_equal(x["labels"], y["labels"])


def test_sharded_batcher_guards():
    import jax

    from repro.utils.compat import make_mesh

    mesh = make_mesh((jax.device_count(), 1), ("data", "model"))
    b = ShardedBatcher(mesh, token_batches(50, 8, 4), prefetch=0)
    with pytest.raises(TypeError, match="take"):
        len(b)
    with pytest.raises(RuntimeError, match="never terminate"):
        list(b)
    # bounded consumption through iter() works as before
    one = next(iter(take(iter(b), 1)))
    assert one["tokens"].shape == (8, 4)
