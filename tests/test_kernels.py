"""Pallas kernel tests: sweep shapes/dtypes/k against the pure-jnp oracle
(interpret mode on CPU), plus hypothesis property checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import (
    fused_memsgd_ref,
    fused_memsgd_update,
    row_topk,
    row_topk_ref,
)

SHAPES = [(8, 64), (16, 128), (8, 1024), (24, 100), (3, 33), (1, 257)]
DTYPES = [jnp.float32, jnp.bfloat16]
KS = [1, 4, 16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("k", KS)
def test_row_topk_sweep(shape, dtype, k):
    R, C = shape
    if k > C:
        pytest.skip("k > C")
    x = jax.random.normal(jax.random.PRNGKey(R * C + k), shape).astype(dtype)
    v1, i1 = row_topk(x, k)
    v2, i2 = row_topk_ref(x, k)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(
        np.asarray(v1, np.float32), np.asarray(v2, np.float32), atol=0
    )


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("k", KS)
def test_fused_memsgd_sweep(shape, dtype, k):
    R, C = shape
    if k > C:
        pytest.skip("k > C")
    key = jax.random.PRNGKey(R + C + k)
    m = jax.random.normal(key, shape).astype(dtype)
    g = jax.random.normal(jax.random.fold_in(key, 1), shape).astype(dtype)
    nm1, v1, i1 = fused_memsgd_update(m, g, 0.37, k)
    nm2, v2, i2 = fused_memsgd_ref(m, g, 0.37, k)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    atol = 1e-6 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(nm1, np.float32), np.asarray(nm2, np.float32), atol=atol
    )


@settings(max_examples=25, deadline=None)
@given(
    R=st.integers(1, 32),
    C=st.integers(2, 200),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_row_topk_property(R, C, k, seed):
    k = min(k, C)
    x = jax.random.normal(jax.random.PRNGKey(seed), (R, C))
    vals, idx = row_topk(x, k)
    xn = np.asarray(x)
    vn, inn = np.asarray(vals), np.asarray(idx)
    for r in range(R):
        # selected values are genuinely the k largest magnitudes
        thresh = np.sort(np.abs(xn[r]))[-k]
        assert np.all(np.abs(vn[r]) >= thresh - 1e-6)
        # indices point at the right values
        np.testing.assert_allclose(xn[r][inn[r]], vn[r], atol=0)
        # indices unique
        assert len(set(inn[r].tolist())) == k


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fused_memory_residual_invariant(seed):
    """new_m + scatter(vals) == m + eta*g exactly (the error-feedback
    conservation law the whole method rests on)."""
    key = jax.random.PRNGKey(seed)
    R, C, k = 8, 64, 5
    m = jax.random.normal(key, (R, C))
    g = jax.random.normal(jax.random.fold_in(key, 1), (R, C))
    eta = 0.21
    nm, vals, idx = fused_memsgd_update(m, g, eta, k)
    rebuilt = np.asarray(nm).copy()
    vn, inn = np.asarray(vals), np.asarray(idx)
    for r in range(R):
        rebuilt[r, inn[r]] += vn[r]
    np.testing.assert_allclose(rebuilt, np.asarray(m + eta * g), atol=1e-5)


def test_kernel_is_contraction():
    """Row-top-k (the kernel's operator) satisfies Definition 2.1 per row."""
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 200))
    k = 10
    vals, idx = row_topk(x, k)
    dense = jnp.zeros_like(x).at[jnp.arange(16)[:, None], idx].set(vals)
    resid = jnp.sum((x - dense) ** 2, axis=1)
    bound = (1 - k / 200) * jnp.sum(x**2, axis=1)
    assert bool(jnp.all(resid <= bound + 1e-5))
