"""End-to-end system tests on a 1x1 mesh (single real CPU device):
train -> checkpoint -> restore -> serve."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_smoke_config
from repro.core.distributed import SyncConfig
from repro.data import token_batches
from repro.data.pipeline import ShardedBatcher, take
from repro.launch.train import (
    TrainConfig,
    init_train_state,
    make_train_step,
    state_shardings,
    train,
)
from repro.models import build_model
from repro.utils.compat import make_mesh


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def test_end_to_end_train_checkpoint_serve():
    mesh = _mesh11()
    cfg = get_smoke_config("granite-3-8b")
    model = build_model(cfg)
    tc = TrainConfig(optimizer="memsgd", eta=0.5, sync=SyncConfig(ratio=0.02))
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(tmp, max_to_keep=2)
        batches = ShardedBatcher(
            mesh, token_batches(cfg.vocab_size, 4, 64, seed=0), prefetch=0
        )
        params, memory, opt, count, history = train(
            model, mesh, tc, batches, n_steps=25, checkpointer=ck,
            ckpt_every=10, log_every=0,
        )
        # loss decreased vs fresh init
        batch = next(iter(ShardedBatcher(
            mesh, token_batches(cfg.vocab_size, 4, 64, seed=0), prefetch=0)))
        final_loss = float(model.loss(params, batch)[0])
        init_params = model.init(jax.random.PRNGKey(0))
        init_loss = float(model.loss(init_params, batch)[0])
        assert final_loss < init_loss
        # checkpoints written during training and restorable
        assert ck.latest_step() == 20
        # save the final state and round-trip it exactly
        ck.save(25, {"params": params})
        restored, meta = ck.restore(like={"params": params})
        assert meta["step"] == 25
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(restored["params"])[0]),
            np.asarray(jax.tree.leaves(params)[0]),
        )
        # serving with the trained params
        from repro.launch.serve import decode_loop

        prompts = jnp.zeros((2, 4), jnp.int32)
        toks = decode_loop(model, mesh, params, prompts, n_tokens=5,
                           max_len=32)
        assert toks.shape == (2, 5)
        assert int(jnp.max(toks)) < cfg.vocab_size


def test_structured_stream_is_learnable():
    """The synthetic token stream has next-token structure; a short run
    with the compressed-Adam mode must show clear improvement."""
    mesh = _mesh11()
    cfg = get_smoke_config("musicgen-medium").replace(n_prefix_embeddings=0)
    model = build_model(cfg)
    tc = TrainConfig(optimizer="adam_compressed", eta=3e-3,
                     sync=SyncConfig(ratio=0.05))
    batches = ShardedBatcher(
        mesh, token_batches(cfg.vocab_size, 4, 64, seed=3), prefetch=0
    )
    params, memory, opt, count = init_train_state(
        model, mesh, tc, rng=jax.random.PRNGKey(1))
    pshard, mshard, oshard, _ = state_shardings(model, mesh, tc)
    params = jax.device_put(params, pshard)
    memory = jax.device_put(memory, mshard)
    if oshard != ():
        opt = jax.device_put(opt, oshard)
    step = make_train_step(model, mesh, tc)
    losses = []
    for batch in take(iter(batches), 30):
        params, memory, opt, count, m = step(params, memory, opt, count, batch)
        # repro-lint: disable=RL001  (convergence smoke: per-step sync
        # keeps the assertion simple; throughput is irrelevant here)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_prefill_logits_match_forward_tail():
    cfg = get_smoke_config("qwen1.5-4b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    full, _ = model.forward(params, batch)
    last = model.prefill_logits(params, batch)
    np.testing.assert_allclose(
        np.asarray(full[:, -1], np.float32), np.asarray(last, np.float32),
        atol=1e-2, rtol=1e-2,
    )
