"""Blocked (flash-style) attention vs direct softmax oracle; decode cache
consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import layers as L


def _qkv(key, B, S, H, KV, hd):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    return q, k, v


@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
def test_flash_full_matches_direct(H, KV):
    B, S, hd = 2, 256, 16
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, KV, hd)
    direct = L._sdpa(q, k, v, L.causal_mask(S), H // KV)
    flash = L._flash_full(q, k, v, H // KV, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(flash),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_windowed_matches_direct(window):
    B, S, H, KV, hd = 2, 256, 4, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(1), B, S, H, KV, hd)
    direct = L._sdpa(q, k, v, L.causal_mask(S, window), H // KV)
    flash = L._flash_windowed(q, k, v, H // KV, window, q_block=64)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(flash),
                               atol=2e-5, rtol=2e-5)


def test_flash_unrolled_matches_scan_form():
    B, S, H, KV, hd = 1, 128, 2, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(2), B, S, H, KV, hd)
    a = L._flash_full(q, k, v, 1, 32, 32)
    try:
        L.set_unroll_blocks(True)
        b = L._flash_full(q, k, v, 1, 32, 32)
    finally:
        L.set_unroll_blocks(False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_decode_cache_matches_forward():
    """Token-by-token decode through the KV cache reproduces the full
    forward pass logits."""
    cfg = get_smoke_config("yi-9b")
    from repro.models import build_model

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    logits_full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, S + 4)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t])
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_full, np.float32),
        np.asarray(logits_dec, np.float32),
        atol=0.15, rtol=0.1,  # bf16 accumulation differences
    )


def test_sliding_window_decode_ring_buffer():
    """With a ring-buffered window cache, decode matches a windowed
    forward pass."""
    cfg = get_smoke_config("qwen3-4b").replace(sliding_window=8)
    from repro.models import build_model

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab_size)
    logits_full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, S)
    assert cache["k"].shape[2] == 8  # ring buffer of window size
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t])
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_full, np.float32),
        np.asarray(logits_dec, np.float32),
        atol=0.15, rtol=0.1,
    )


def test_rope_relative_shift_invariance():
    """RoPE scores depend only on relative distance."""
    hd = 16
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 1, hd))
    p1 = jnp.array([[3, 7]], jnp.int32)
    p2 = jnp.array([[103, 107]], jnp.int32)
    r1 = L.apply_rope(x, p1, 10000.0)
    r2 = L.apply_rope(x, p2, 10000.0)
    s1 = float(jnp.sum(r1[0, 0, 0] * r1[0, 1, 0]))
    s2 = float(jnp.sum(r2[0, 0, 0] * r2[0, 1, 0]))
    assert abs(s1 - s2) < 1e-4
