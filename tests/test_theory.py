"""Theorem 2.4 machinery tests."""
import jax.numpy as jnp
import numpy as np

from repro.core import theory


def test_S_T_closed_form():
    for T in (1, 5, 50):
        for a in (1.0, 7.0, 500.0):
            direct = sum((a + t) ** 2 for t in range(T))
            np.testing.assert_allclose(theory.S_T(T, a), direct, rtol=1e-9)
            assert theory.S_T(T, a) >= T**3 / 3 - 1e-9


def test_weighted_average_streaming_matches_direct():
    a = 3.0
    wavg = theory.WeightedAverage(a)
    xs = [jnp.array([float(t), -float(t) ** 2]) for t in range(10)]
    st = wavg.init(xs[0])
    for t, x in enumerate(xs):
        st = wavg.update(st, x, jnp.asarray(t))
    got = np.asarray(wavg.value(st))
    ws = np.array([(a + t) ** 2 for t in range(10)])
    want = (ws[:, None] * np.stack([np.asarray(x) for x in xs])).sum(0) / ws.sum()
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_stepsize_families():
    eta = theory.paper_stepsize(gamma=2.0, lam=0.5, a=10.0)
    np.testing.assert_allclose(float(eta(jnp.asarray(0))), 0.4, rtol=1e-6)
    eta_th = theory.theorem_stepsize(mu=2.0, a=4.0)
    np.testing.assert_allclose(float(eta_th(jnp.asarray(0))), 1.0, rtol=1e-6)
    eta_b = theory.bottou_stepsize(0.5, 0.1)
    np.testing.assert_allclose(float(eta_b(jnp.asarray(0))), 0.5, rtol=1e-6)
    # decreasing
    for sched in (eta, eta_th, eta_b):
        v = [float(sched(jnp.asarray(t))) for t in range(5)]
        assert all(v[i] > v[i + 1] for i in range(4))


def test_shifts():
    assert theory.theoretical_shift(100, 1, alpha=5.0) == 700.0
    assert theory.practical_shift(100, 10) == 10.0


def test_theorem_bound_decreases_in_T():
    b = [
        theory.theorem_bound(T, d=100, k=1, mu=0.01, L=1.0, G2=1.0,
                             x0_dist2=1.0)
        for T in (10_000, 100_000, 1_000_000)
    ]
    assert b[0] > b[1] > b[2] > 0


def test_theorem_bound_rate_is_1_over_T_asymptotically():
    """For large T the first term O(G^2/(mu T)) dominates: doubling T must
    roughly halve the bound."""
    kw = dict(d=100, k=1, mu=0.1, L=1.0, G2=1.0, x0_dist2=1.0)
    # with d/k = 100 and kappa = 10 the O(1/T^2) term needs T >> d/k * k^.5
    # times its large constant; by T ~ 1e10 the 1/T term clearly dominates
    b1 = theory.theorem_bound(10**10, **kw)
    b2 = theory.theorem_bound(2 * 10**10, **kw)
    assert 0.45 < b2 / b1 < 0.55
