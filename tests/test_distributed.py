"""Distributed PARALLEL-MEM-SGD tests.

Multi-device cases run in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps the single real CPU device (per the dry-run contract).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# jax < 0.5 (no jax.shard_map) routes through the legacy
# experimental.shard_map whose partial-auto mode crashes XLA's SPMD
# partitioner (Check failed: sharding.IsManualSubgroup()) whenever the
# auto "model" axis has size > 1. Single-axis and model=1 meshes work.
# strict: on a fixed jax the condition is False and the mark inert; on
# legacy jax an unexpected PASS must surface as a failure (XPASS), not
# rot silently after a container upgrade.
legacy_partial_auto = pytest.mark.xfail(
    not hasattr(jax, "shard_map"),
    reason="legacy shard_map partial-auto + sharded model axis crashes XLA",
    strict=True,
)


def _run_subprocess(body: str) -> dict:
    """Run `body` with 8 fake devices; it must print one JSON line."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        import numpy as np
        """
    ).format(src=SRC) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_message_bytes_accounting():
    from repro.core.distributed import SyncConfig, message_bytes

    params = {"w": jnp.zeros((128, 256)), "b": jnp.zeros((8,))}
    cfg = SyncConfig(ratio=0.01, dense_below=16)
    # w: 128 rows (col axis last, len 256) -> k_row = max(1, 2.56) = 3
    # b: small but above dense_below(16)? 8 < 16 -> dense: 8*4 bytes
    got = message_bytes(cfg, params)
    assert got == 128 * 3 * 8 + 8 * 4


def test_sync_col_axes_rules():
    from repro.launch.sharding import sync_col_axes, param_specs
    from jax.sharding import PartitionSpec as P

    params = {
        "embed": jnp.zeros((64, 32)),
        "blocks": {
            "attn": {"wq": jnp.zeros((2, 32, 64)), "wo": jnp.zeros((2, 64, 32))},
            "mlp": {"w_down": jnp.zeros((2, 128, 32))},
        },
    }
    cols = sync_col_axes(params)
    # embed is vocab-parallel; selection runs along d_model per vocab row
    # (the D-sharded alternative measured worse: EXPERIMENTS.md §Perf A2a)
    assert cols["embed"] == 1
    assert cols["blocks"]["attn"]["wq"] == 1  # (L, D, heads): cols = D
    assert cols["blocks"]["attn"]["wo"] == 2  # (L, heads, D): cols = D
    specs = param_specs(params)
    assert specs["embed"] == P("model", None)
    assert specs["blocks"]["attn"]["wq"] == P(None, None, "model")
    assert specs["blocks"]["mlp"]["w_down"] == P(None, "model", None)


@pytest.mark.slow
@legacy_partial_auto
def test_distributed_memsgd_loss_decreases():
    rec = _run_subprocess(
        """
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.train import (TrainConfig, make_train_step,
                                        init_train_state, state_shardings)
        from repro.core.distributed import SyncConfig
        from repro.data import token_batches
        from repro.data.pipeline import ShardedBatcher

        mesh = make_debug_mesh(4, 2)
        cfg = get_smoke_config("qwen3-4b")
        model = build_model(cfg)
        tc = TrainConfig(optimizer="memsgd", eta=0.5,
                         sync=SyncConfig(ratio=0.01))
        params, memory, opt, count = init_train_state(
            model, mesh, tc, rng=jax.random.PRNGKey(0))
        pshard, mshard, oshard, _ = state_shardings(model, mesh, tc)
        params = jax.device_put(params, pshard)
        memory = jax.device_put(memory, mshard)
        step = make_train_step(model, mesh, tc)
        it = ShardedBatcher(mesh, token_batches(cfg.vocab_size, 8, 64, seed=1),
                            prefetch=0)
        losses = []
        for i, batch in enumerate(it):
            if i >= 15: break
            params, memory, opt, count, m = step(params, memory, opt, count,
                                                 batch)
            losses.append(float(m["loss"]))
        print(json.dumps({"first": losses[0], "last": losses[-1]}))
        """
    )
    assert rec["last"] < rec["first"]


@pytest.mark.slow
def test_distributed_sparse_sync_no_dense_allreduce():
    """The compiled train step must NOT contain a dense gradient
    all-reduce: the biggest all-reduce operand must be far smaller than
    the largest parameter (the paper's communication claim, verified on
    the compiled HLO)."""
    rec = _run_subprocess(
        """
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.train import (TrainConfig, make_train_step,
                                        init_train_state, state_shardings)
        from repro.core.distributed import SyncConfig
        from repro.roofline.analysis import parse_collectives
        import re
        from repro.utils.shapes import parse_hlo_shape_bytes

        mesh = make_debug_mesh(4, 1)  # pure data-parallel: no model axis use
        cfg = get_smoke_config("qwen3-4b")
        model = build_model(cfg)
        tc = TrainConfig(optimizer="memsgd", eta=0.1,
                         sync=SyncConfig(ratio=0.001))
        st = init_train_state(model, mesh, tc, abstract=True)
        pshard, mshard, oshard, cshard = state_shardings(model, mesh, tc)
        def abst(tree, sh):
            return jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=s), tree, sh)
        params, memory, opt, count = st
        from jax.sharding import NamedSharding, PartitionSpec as P
        batch = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                     sharding=NamedSharding(mesh, P("data")))
                 for k, v in {
                    "tokens": jnp.zeros((8, 64), jnp.int32),
                    "labels": jnp.zeros((8, 64), jnp.int32)}.items()}
        step = make_train_step(model, mesh, tc)
        lowered = step.lower(abst(params, pshard), abst(memory, mshard), (),
                             jax.ShapeDtypeStruct((), jnp.int32,
                                                  sharding=cshard), batch)
        hlo = lowered.compile().as_text()
        # largest all-reduce operand
        biggest_ar = 0
        for line in hlo.splitlines():
            m = re.search(r"= ([a-z0-9\\[\\],{}]+) all-reduce", line)
            if m:
                biggest_ar = max(biggest_ar, parse_hlo_shape_bytes(m.group(1)))
        biggest_param = max(
            int(np.prod(l.shape)) * 4 for l in jax.tree.leaves(params))
        print(json.dumps({"biggest_ar": biggest_ar,
                          "biggest_param": biggest_param}))
        """
    )
    # dense sync would all-reduce the largest param (>= MBs); the sparse
    # scheme's all-reduces are only scalar metrics / norm reductions.
    assert rec["biggest_ar"] < rec["biggest_param"] / 50


@pytest.mark.slow
@legacy_partial_auto
def test_hierarchical_matches_flat_when_pod_ratio_full():
    """With pod re-compression disabled (pod_ratio=1.0 => k_pod = full
    row), hierarchical == flat sparse_allgather updates after one step."""
    rec = _run_subprocess(
        """
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.launch.train import (TrainConfig, make_train_step,
                                        init_train_state, state_shardings)
        from repro.core.distributed import SyncConfig
        from repro.data import token_batches
        from repro.data.pipeline import ShardedBatcher
        from repro.utils.compat import make_mesh

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_smoke_config("yi-9b")
        model = build_model(cfg)
        def one_step(strategy, pod_ratio):
            tc = TrainConfig(optimizer="memsgd", eta=0.3,
                             sync=SyncConfig(ratio=0.02, strategy=strategy,
                                             pod_ratio=pod_ratio))
            params, memory, opt, count = init_train_state(
                model, mesh, tc, rng=jax.random.PRNGKey(0))
            pshard, mshard, oshard, _ = state_shardings(model, mesh, tc)
            params = jax.device_put(params, pshard)
            memory = jax.device_put(memory, mshard)
            step = make_train_step(model, mesh, tc)
            it = ShardedBatcher(mesh, token_batches(cfg.vocab_size, 8, 32,
                                seed=5), batch_axes=("pod", "data"),
                                prefetch=0)
            batch = next(iter(it))
            params, *_ = step(params, memory, opt, count, batch)
            return params
        p_flat = one_step("sparse_allgather", None)
        p_hier = one_step("hierarchical", 1.0)
        diff = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(p_flat),
                                   jax.tree.leaves(p_hier)))
        print(json.dumps({"maxdiff": diff}))
        """
    )
    assert rec["maxdiff"] < 1e-5
