"""Two-level pod-aware bucketed sync tests (repro.core.distributed).

Fast tier: per-bucket pod-k resolution, per-level byte accounting, and
the mass-capture autotuner — pure accounting, no devices. Slow tier:
the property the scheme lives or dies by, checked on a REAL 8-device
2-pod mesh in a subprocess (pattern from tests/test_distributed.py):
exact mass conservation across BOTH residual levels and packed ==
unpacked bit-identity.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buckets as bk
from repro.core.distributed import (
    SyncConfig,
    autotune_pod_ratios,
    bucketed_message_bytes,
)

from tests._hypothesis_compat import given, settings, st

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _tree(key=0, heavy=1.0):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    w = jax.random.normal(ks[0], (64, 2048))
    w = jnp.sign(w) * jnp.abs(w) ** heavy  # heavy > 1: fatter tails
    return {"w": w, "b": jax.random.normal(ks[1], (48,))}


def _plan(tree):
    return bk.make_plan(tree, cols=1024, dense_below=1024)


def test_pod_k_for_bucket_overrides_global_ratio():
    cfg = SyncConfig(ratio=0.02, strategy="hierarchical", pod_axis="pod",
                     pod_ratio=0.01, pod_ratios=(1.0, 0.05))
    # bucket 1 uses its own ratio: 0.05 * 1024 ~ 51
    assert cfg.pod_k_for_bucket(1, 1024) == 51
    # beyond the tuple RAISES — the old silent fallback to the global
    # pod_ratio quietly desynced byte accounting from the wire layout
    with pytest.raises(ValueError, match="index-aligned"):
        cfg.pod_k_for_bucket(7, 1024)
    # without per-bucket ratios everything falls back to the global ratio
    cfg2 = dataclasses.replace(cfg, pod_ratios=None)
    assert cfg2.pod_k_for_bucket(1, 1024) == cfg2.pod_k_for(1024) == 10


def test_pod_ratios_must_align_with_plan():
    """A pod_ratios tuple that is not index-aligned with the bucket plan
    is rejected at every accounting/sync/delta entry point."""
    from repro.core.distributed import validate_pod_ratios
    from repro.launch.delta_stream import make_delta_spec

    plan = _plan(_tree())  # 2 buckets
    short = SyncConfig(ratio=0.02, strategy="hierarchical", pod_axis="pod",
                       pod_ratios=(1.0,), bucketed=True)
    with pytest.raises(ValueError, match="2-bucket plan"):
        validate_pod_ratios(short, plan)
    with pytest.raises(ValueError, match="2-bucket plan"):
        bucketed_message_bytes(short, plan)
    with pytest.raises(ValueError, match="2-bucket plan"):
        make_delta_spec(plan, short, workers=8, n_pods=2)
    # aligned ratios pass
    ok = dataclasses.replace(short, pod_ratios=(1.0, 0.05))
    validate_pod_ratios(ok, plan)
    assert bucketed_message_bytes(ok, plan) > 0


def test_by_level_accounting_sums_and_beats_flat():
    plan = _plan(_tree())
    dense_nb = sum(
        s.rows * s.cols * 4 for s in plan.buckets if s.kind == "dense"
    )
    for wire in ("packed", "unpacked"):
        two_cfg = SyncConfig(ratio=0.02, strategy="hierarchical",
                             pod_axis="pod", pod_ratios=(1.0, 0.02),
                             wire=wire, bucketed=True)
        lv = bucketed_message_bytes(two_cfg, plan, by_level=True)
        # dense buckets move ~size bytes at BOTH levels; sparse levels
        # split exactly
        assert lv["intra"] + lv["cross"] == lv["total"] + dense_nb
        # the scalar form keeps its historical meaning
        assert bucketed_message_bytes(two_cfg, plan) == lv["total"]
        flat_cfg = SyncConfig(ratio=0.02, strategy="sparse_allgather",
                              pod_axis="pod", wire=wire, bucketed=True)
        flat = bucketed_message_bytes(flat_cfg, plan, by_level=True,
                                      n_data=4)
        # flat re-ships the concatenated data-axis buffer across pods;
        # the two-level summary (k_pod == k_row here) is strictly smaller
        assert lv["cross"] < flat["cross"]
        # per-worker emitted message is identical at level 1
        assert lv["intra"] == flat["intra"]


def test_by_level_flat_needs_n_data():
    plan = _plan(_tree())
    cfg = SyncConfig(ratio=0.02, strategy="sparse_allgather",
                     pod_axis="pod", bucketed=True)
    with pytest.raises(ValueError, match="n_data"):
        bucketed_message_bytes(cfg, plan, by_level=True)
    # dense strategy never consults n_data: the all-reduce moves
    # ~buffer-size bytes at each level
    dense = SyncConfig(strategy="dense", pod_axis="pod", bucketed=True)
    lv = bucketed_message_bytes(dense, plan, by_level=True)
    total = sum(s.rows * s.cols * 4 for s in plan.buckets)
    assert lv["intra"] == lv["cross"] == lv["total"] == total


@settings(max_examples=5, deadline=None)
@given(heavy=st.sampled_from([3.0, 1.0]),
       target=st.floats(min_value=0.5, max_value=0.99))
def test_autotune_within_bounds_and_tail_sensitive(heavy, target):
    """Autotuned pod k always lands in [k_min, support bound], and a
    heavier-tailed bucket never needs MORE slots than a flatter one at
    the same target."""
    cfg = SyncConfig(ratio=0.02, strategy="hierarchical", pod_axis="pod",
                     pod_mass_target=float(target))
    n_data = 4
    plan = _plan(_tree())
    for h, label in ((heavy, "sampled"), (1.0, "flat")):
        bufs = bk.pack(plan, _tree(heavy=h), dtype=jnp.float32)
        ratios = autotune_pod_ratios(cfg, plan, bufs, n_data=n_data)
        assert len(ratios) == len(plan.buckets)
        for spec, r in zip(plan.buckets, ratios):
            if spec.kind == "dense":
                assert r == 1.0
                continue
            k = int(round(r * spec.cols))
            support = min(spec.cols, n_data * cfg.k_for(spec.cols))
            assert cfg.k_min <= k <= support, (label, k, support)
        if h == heavy:
            sampled = ratios
    flat_bufs = bk.pack(plan, _tree(heavy=1.0), dtype=jnp.float32)
    flat_ratios = autotune_pod_ratios(cfg, plan, flat_bufs, n_data=n_data)
    assert sampled[1] <= flat_ratios[1] + 1e-9


def test_autotune_shard_simulation_sees_overlap():
    """With per-shard buffers the autotuner simulates the pod stage.
    Perfectly correlated shards -> the pod mean's support collapses to
    k_row, so the tuned k never exceeds it (a 4x smaller wire than the
    support bound at n_data=4)."""
    cfg = SyncConfig(ratio=0.02, strategy="hierarchical", pod_axis="pod",
                     pod_mass_target=0.99)
    plan = _plan(_tree())
    buf = bk.pack(plan, _tree(), dtype=jnp.float32)
    identical = [jnp.stack([b] * 4) for b in buf]  # 4 identical shards
    ratios = autotune_pod_ratios(cfg, plan, identical, n_data=4)
    k_row = cfg.k_for(plan.buckets[1].cols)
    assert int(round(ratios[1] * plan.buckets[1].cols)) <= k_row
    # decorrelated shards need more slots than perfectly aligned ones
    mixed = [jnp.stack([bk.pack(plan, _tree(key=i), dtype=jnp.float32)[b]
                        for i in range(4)])
             for b in range(len(plan.buckets))]
    mixed_ratios = autotune_pod_ratios(cfg, plan, mixed, n_data=4)
    assert mixed_ratios[1] >= ratios[1]


def test_mass_capture_monotone_and_complete():
    buf = bk.pack(_plan(_tree()), _tree(), dtype=jnp.float32)[1]
    frac = np.asarray(bk.bucket_mass_capture(buf, buf.shape[1]))
    assert frac.shape == (buf.shape[1],)
    assert np.all(np.diff(frac) >= -1e-6)
    np.testing.assert_allclose(frac[-1], 1.0, atol=1e-5)
    # all-zero rows count as fully captured, not as 0/0
    z = jnp.zeros_like(buf)
    np.testing.assert_allclose(
        np.asarray(bk.bucket_mass_capture(z, 4)), 1.0
    )


_SUBPROCESS_CACHE: dict = {}


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(case=st.sampled_from([(0.05, 0.1), (0.02, 0.05), (0.05, 1.0)]))
def test_two_level_conservation_and_wire_bit_identity(case):
    """On a real 2-pod x 4-worker mesh (shared probe:
    ``repro.core.selfcheck.two_level_selfcheck``): (1) the two-level
    mass-conservation invariant mean_w(u) == update + mean_w(new_memory)
    holds exactly (both residual levels fold back into bucket memory),
    (2) packed and unpacked wires produce BITWISE identical updates and
    memories, (3) the bytes the sync realizes equal the static
    ``bucketed_message_bytes`` accounting. Each (ratio, pod_ratio) case
    costs two shard_map compiles in a fresh subprocess, so results are
    memoized across the sweep's repeated draws."""
    ratio, pod_ratio = case
    body = """
        from repro.core.selfcheck import two_level_selfcheck
        from repro.utils.compat import make_mesh

        rec = two_level_selfcheck(
            make_mesh((2, 4), ("pod", "data")),
            ratio={ratio}, pod_ratio={pod_ratio})
        print(json.dumps(rec))
        """
    if case not in _SUBPROCESS_CACHE:
        _SUBPROCESS_CACHE[case] = _run_subprocess(
            body.format(ratio=ratio, pod_ratio=pod_ratio)
        )
    rec = _SUBPROCESS_CACHE[case]
    assert rec["bit_identical"]
    assert rec["conservation_max_err"] < 1e-5, rec
    assert rec["accounting_exact"], rec
    assert rec["accounted_bytes"]["packed"] < rec["accounted_bytes"]["unpacked"]


def _run_subprocess(body: str) -> dict:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        import numpy as np
        """
    ).format(src=SRC) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])
