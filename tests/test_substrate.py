"""Substrate tests: data pipeline, checkpointing, encoding accounting,
roofline HLO parsing."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core import encoding
from repro.data import (
    logreg_grad_np,
    logreg_loss_np,
    make_epsilon_like,
    make_rcv1_like,
    token_batches,
)
from repro.roofline.analysis import parse_collectives
from repro.utils.shapes import parse_hlo_shape_bytes


# -- data --------------------------------------------------------------------


def test_token_batches_shapes_and_structure():
    it = token_batches(100, 4, 16, seed=0)
    b = next(it)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    assert b["tokens"].dtype == np.int32
    # labels are the shifted tokens
    b2 = next(it)
    assert not np.array_equal(b["tokens"], b2["tokens"])


def test_logreg_datasets_match_paper_regimes():
    eps = make_epsilon_like(n=500, d=100)
    assert eps.A.shape == (500, 100)
    assert eps.lam == 1 / 500
    assert set(np.unique(eps.b)) <= {-1.0, 1.0}
    rcv = make_rcv1_like(n=100, d=1000, density=0.01)
    nnz_frac = (rcv.A != 0).mean()
    assert 0.005 < nnz_frac < 0.02  # sparse as configured


def test_logreg_grad_is_descent_direction():
    data = make_epsilon_like(n=400, d=50, seed=1)
    x = np.zeros(50)
    g = logreg_grad_np(data, x, np.arange(400))  # full gradient
    f0 = logreg_loss_np(data, x)
    f1 = logreg_loss_np(data, x - 0.5 * g)
    assert f1 < f0


def test_logreg_grad_finite_difference():
    data = make_epsilon_like(n=50, d=10, seed=2)
    x = np.random.default_rng(0).standard_normal(10) * 0.1
    g = logreg_grad_np(data, x, np.arange(50))
    eps = 1e-6
    for i in range(10):
        e = np.zeros(10)
        e[i] = eps
        fd = (logreg_loss_np(data, x + e) - logreg_loss_np(data, x - e)) / (2 * eps)
        np.testing.assert_allclose(fd, g[i], rtol=1e-4, atol=1e-7)


# -- checkpoint ---------------------------------------------------------------


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(tmp, max_to_keep=2)
        tree = {"a": {"b": jnp.arange(6).reshape(2, 3)}, "c": jnp.ones(4)}
        for s in (1, 2, 3):
            ck.save(s, tree, {"tag": s})
        assert ck.steps() == [2, 3]  # gc kept last 2
        got, meta = ck.restore(like=tree)
        np.testing.assert_array_equal(np.asarray(got["a"]["b"]),
                                      np.asarray(tree["a"]["b"]))
        assert meta["step"] == 3


def test_checkpoint_mismatch_raises():
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(tmp)
        ck.save(1, {"a": jnp.ones(3)})
        with pytest.raises(ValueError):
            ck.restore(like={"a": jnp.ones(3), "extra": jnp.ones(2)})


# -- encoding (paper Appendix B) ----------------------------------------------


def test_sparse_vs_dense_reduction_factor():
    # paper: top_1 on epsilon (d=2000) improves communication by ~1e3
    f = encoding.reduction_factor(2000, 1)
    assert 1000 < f < 2000


def test_qsgd_bits_formula():
    # min(naive, elias)
    d, s = 2000, 16
    naive = (np.log2(s) + 1) * d
    elias = 3 * s * (s + np.sqrt(d)) + 32
    assert encoding.qsgd_bits(d, s) == min(naive, elias)


def test_index_bits():
    assert encoding.index_bits(2**10) == 10
    assert encoding.index_bits(47_236) == 16


# -- roofline HLO parsing -------------------------------------------------------


def test_parse_hlo_shape_bytes():
    assert parse_hlo_shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert parse_hlo_shape_bytes("bf16[8]{0}") == 16
    assert parse_hlo_shape_bytes("(f32[4,2], s32[4,2])") == 32 + 32
    assert parse_hlo_shape_bytes("pred[7]") == 7
    assert parse_hlo_shape_bytes("token[]") == 0


def test_parse_collectives():
    hlo = """
      %ag = f32[16,128]{1,0} all-gather(%x), replica_groups={}
      %ar.1 = bf16[64]{0} all-reduce(%y), to_apply=%add
      %cp = f32[8]{0} collective-permute(%z)
      %a2a.s = f32[4,4]{1,0} all-to-all(%w)
      ignored = f32[9]{0} add(%a, %b)
    """
    st = parse_collectives(hlo)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "collective-permute": 1, "all-to-all": 1}
    assert st.bytes_by_kind["all-gather"] == 16 * 128 * 4
    assert st.bytes_by_kind["all-reduce"] == 64 * 2 * 2  # 2x for RS+AG
    assert st.total_bytes > 0


def test_parse_collectives_start_done_not_double_counted():
    hlo = """
      %ags = f32[128]{0} all-gather-start(%x)
      %agd = f32[128]{0} all-gather-done(%ags)
    """
    st = parse_collectives(hlo)
    assert st.counts == {"all-gather": 1}


# -- real compiled module ------------------------------------------------------


def test_collectives_from_real_compiled_psum():
    """Parse a genuinely compiled XLA module (single device: no collective
    => empty; sanity for the parser's false-positive rate)."""
    f = jax.jit(lambda x: x * 2 + 1)
    hlo = f.lower(jnp.ones((8, 8))).compile().as_text()
    st = parse_collectives(hlo)
    assert st.total_bytes == 0
