"""Packed sparse wire codec + delta stream tests (repro.core.encoding,
repro.launch.delta_stream).

Multi-worker cases run in a subprocess with 8 fake CPU devices (same
contract as test_distributed.py)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import buckets as bk
from repro.core import encoding as enc
from repro.core.distributed import SyncConfig, bucketed_message_bytes

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _pairs(rows, cols, k, value_dtype, seed=0):
    """Random (vals, idx) in the shapes the codec expects (idx need not
    be distinct — the codec is agnostic)."""
    kv, ki = jax.random.split(jax.random.PRNGKey(seed))
    vals = jax.random.normal(kv, (rows, k)).astype(jnp.dtype(value_dtype))
    idx = jax.random.randint(ki, (rows, k), 0, cols).astype(jnp.int32)
    return vals, idx


def _assert_roundtrip(spec, vals, idx):
    buf = jax.jit(lambda v, i: enc.encode(spec, v, i))(vals, idx)
    assert buf.dtype == jnp.uint32
    assert buf.shape == (spec.words,)
    v2, i2 = jax.jit(lambda b: enc.decode(spec, b))(buf)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(idx))
    # values round-trip BITWISE in the wire dtype
    want = np.asarray(vals.astype(jnp.dtype(spec.value_dtype)))
    got = np.asarray(v2)
    assert got.dtype == want.dtype
    assert np.array_equal(
        got.view(np.uint8), want.view(np.uint8)
    ), "wire values not bitwise-identical"
    return buf


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=7),
    # the no-hypothesis fallback sweep takes SPREAD samples — indices
    # {0, 2, 4, 5, 7} of this 8-element list — so the must-cover shapes
    # (pow2, cols=1 with its 0-bit index packing, tiny, cols=2,
    # non-pow2) sit at those positions; the others only run under real
    # hypothesis
    cols=st.sampled_from([1024, 17, 1, 100, 3, 2, 1000, 700]),
    k_mode=st.sampled_from(["one", "interior", "full"]),
    value_dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_roundtrip_property(rows, cols, k_mode, value_dtype):
    """decode(encode(v, i)) == (v, i) for non-power-of-two cols and the
    k=1 / k=cols edges, f32 and bf16 values."""
    k = {"one": 1, "interior": max(1, cols // 3), "full": cols}[k_mode]
    spec = enc.WireSpec(rows, cols, k, value_dtype)
    vals, idx = _pairs(rows, cols, k, value_dtype, seed=rows * cols + k)
    _assert_roundtrip(spec, vals, idx)


def test_roundtrip_tie_heavy_topk_selection():
    """Tie-heavy input through a real per-row top-k: the selected pairs
    survive the wire bitwise (including repeated magnitudes and signs)."""
    from repro.kernels.ref import row_topk_ref

    R, C, k = 6, 257, 16
    u = jnp.round(jax.random.normal(jax.random.PRNGKey(3), (R, C)) * 2) / 2
    vals, idx = row_topk_ref(u, k)
    spec = enc.WireSpec(R, C, k, "float32")
    _assert_roundtrip(spec, vals, idx)


def test_roundtrip_special_values():
    """Denormals, zeros, infs and extreme indices survive the wire."""
    C = 1000
    vals = jnp.array(
        [[0.0, -0.0, 1e-40, -1e-40, jnp.inf, -jnp.inf, 3.14, -2.5]],
        jnp.float32,
    )
    idx = jnp.array([[0, C - 1, 1, C - 2, 511, 512, 3, 999]], jnp.int32)
    spec = enc.WireSpec(1, C, 8, "float32")
    _assert_roundtrip(spec, vals, idx)


def test_header_is_self_describing():
    spec = enc.WireSpec(5, 300, 7, "bfloat16")
    vals, idx = _pairs(5, 300, 7, "bfloat16")
    buf = enc.encode(spec, vals, idx)
    assert enc.WireSpec.from_header(np.asarray(buf)) == spec


def test_dense_kind_roundtrip():
    spec = enc.WireSpec(2, 77, 77, "float32", kind="dense")
    vals = jax.random.normal(jax.random.PRNGKey(1), (2, 77))
    buf = enc.encode(spec, vals)
    v2, i2 = enc.decode(spec, buf)
    assert i2 is None
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(vals))


# -- header-aware repack transport --------------------------------------------


def _padded_message(rows, cols, k_max, live_n, value_dtype, seed=0):
    """A contract-ordered ``k_max``-padded message: per-row top-``k_max``
    of a random buffer with the tail past ``live_n`` masked to the
    (-0.0, 0) identity — exactly what the dynamic pod stage ships."""
    from repro.kernels.topk_select import mask_live_k

    u = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols))
    _, idx = jax.lax.top_k(jnp.abs(u), k_max)
    vals = jnp.take_along_axis(u, idx, axis=-1).astype(jnp.dtype(value_dtype))
    vals, idx = mask_live_k(vals, idx.astype(jnp.int32), live_n)
    spec = enc.WireSpec(rows, cols, k_max, value_dtype)
    return spec, enc.encode(spec, vals, idx, live_n=live_n)


def test_decode_raises_on_corrupt_live_n_header():
    """A header claiming more live slots than the message lays out is
    corruption (a decoder honoring it would read past the value
    section) — both ``decode`` and ``live_n_of`` must refuse it."""
    spec = enc.WireSpec(3, 100, 5, "float32")
    vals, idx = _pairs(3, 100, 5, "float32")
    buf = enc.encode(spec, vals, idx, live_n=2)
    bad = buf.at[enc.LIVE_N_WORD].set(spec.n_sel + 1)
    with pytest.raises(ValueError, match="live_n"):
        enc.decode(spec, bad)
    with pytest.raises(ValueError, match="live_n"):
        enc.live_n_of(bad)
    # the uncorrupted message still decodes and reports its live count
    enc.decode(spec, buf)
    assert enc.live_n_of(buf) == 2


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=6),
    # <= 5 elements: the no-hypothesis fallback sweep cycles ALL of them,
    # so every must-cover shape (non-pow2, pow2, cols=1, tiny) runs
    cols=st.sampled_from([100, 64, 1, 700, 5]),
    live_mode=st.sampled_from(["zero", "one", "interior", "full"]),
    value_dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_repack_roundtrip_property(rows, cols, live_mode, value_dtype):
    """repack/repad round the padded buffer BITWISE for every live_n
    edge (0, 1, interior, k_max), non-pow2 cols and both value tiers;
    the repacked message decodes to exactly the live prefix of the
    padded decode."""
    k_max = max(1, (cols + 1) // 2)
    live = {
        "zero": 0,
        "one": min(1, k_max),
        "interior": max(1, k_max // 2),
        "full": k_max,
    }[live_mode]
    spec, buf = _padded_message(
        rows, cols, k_max, live, value_dtype, seed=rows * cols + live
    )
    # live_n=0 must be passed explicitly: the header stamps 0, which the
    # wire convention reads as "all slots live" (auto-detect no-ops)
    small_spec, small_buf = enc.repack(spec, buf, live_n=live)
    if 0 < live < k_max:
        # header auto-detection agrees with the explicit argument
        auto_spec, auto_buf = enc.repack(spec, buf)
        assert auto_spec == small_spec
        assert np.array_equal(np.asarray(auto_buf), np.asarray(small_buf))
    # the wire shrinks to the live payload (k=max(1, live)), never grows
    assert small_spec.k == (max(1, live) if live < k_max else k_max)
    assert small_spec.nbytes <= spec.nbytes
    # repad restores the padded buffer bitwise (invariant 10's currency)
    repadded = enc.repad(spec, small_spec, small_buf)
    assert np.array_equal(np.asarray(repadded), np.asarray(buf))
    # decode(repack(buf)) == the live prefix of decode(buf), bitwise
    v_small, i_small = enc.decode(small_spec, small_buf)
    v_pad, i_pad = enc.decode(spec, buf)
    n = small_spec.k
    assert np.array_equal(
        np.asarray(v_small).view(np.uint8),
        np.asarray(v_pad[:, :n]).view(np.uint8),
    )
    np.testing.assert_array_equal(
        np.asarray(i_small), np.asarray(i_pad[:, :n])
    )
    # live_n survives the round trip: repack stamps the small header
    assert enc.live_n_of(repadded) == enc.live_n_of(buf)


# -- accounting == what the codec actually emits ------------------------------


def test_wirespec_accounting_matches_encoded_bytes():
    for rows, cols, k, vd in [
        (64, 1024, 64, "float32"),
        (64, 1024, 64, "bfloat16"),
        (3, 700, 5, "bfloat16"),
        (1, 1, 1, "float32"),
    ]:
        spec = enc.WireSpec(rows, cols, k, vd)
        vals, idx = _pairs(rows, cols, k, vd)
        buf = enc.encode(spec, vals, idx)
        encoded_bits = buf.size * buf.dtype.itemsize * 8
        assert spec.nbits == encoded_bits
        assert spec.nbytes * 8 == encoded_bits


def test_bucketed_message_bytes_matches_encoded_buffers():
    """The static accounting equals the realized bytes of the buffers the
    packed sync would all-gather (per-bucket row-local index_bits)."""
    tree = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (100, 300)),
        "h": jax.random.normal(jax.random.PRNGKey(1), (220, 90)).astype(
            jnp.bfloat16
        ),
        "b": jax.random.normal(jax.random.PRNGKey(2), (40,)),
    }
    plan = bk.make_plan(tree, cols=512)
    for vd in ("float32", "bfloat16"):
        cfg = SyncConfig(ratio=0.02, wire="packed", value_dtype=vd,
                         bucketed=True, bucket_cols=512)
        realized = 0
        for spec in plan.buckets:
            if spec.kind == "dense":
                realized += spec.rows * spec.cols * 4
                continue
            k = cfg.k_for(spec.cols)
            wspec = enc.WireSpec(spec.rows, spec.cols, k, vd)
            vals, idx = _pairs(spec.rows, spec.cols, k, vd)
            realized += enc.encode(wspec, vals, idx).size * 4
        assert bucketed_message_bytes(cfg, plan) == realized
        # packed accounting uses the bucket's ceil(log2 cols), not 32
        unpacked = bucketed_message_bytes(
            SyncConfig(ratio=0.02, value_dtype=vd, bucketed=True,
                       bucket_cols=512), plan)
        assert bucketed_message_bytes(cfg, plan) < unpacked


def test_sparse_bits_accounts_value_dtype():
    assert enc.value_bits("bfloat16") == 16
    assert enc.value_bits(jnp.float32) == 32
    assert enc.sparse_bits(2**16, 10, enc.value_bits("bfloat16")) == 10 * (
        16 + 16
    )
    assert enc.memsgd_message_bits(2**16, 10, "bfloat16") == 10 * (16 + 16)
    assert enc.memsgd_message_bits(2**16, 10) == 10 * (32 + 16)


def test_message_bytes_packed_smaller_than_unpacked():
    from repro.core.distributed import message_bytes

    params = {"w": jnp.zeros((128, 1024))}
    base = SyncConfig(ratio=64 / 1024)
    packed = message_bytes(
        SyncConfig(ratio=64 / 1024, wire="packed",
                   value_dtype="bfloat16"), params)
    assert packed * 1.8 <= message_bytes(base, params)


# -- packed sync == unpacked sync, end to end ---------------------------------


def _run_subprocess(body: str) -> dict:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        import numpy as np
        """
    ).format(src=SRC) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_packed_sync_identical_to_unpacked():
    """Packed-wire sync is bit-identical to the unpacked path on an
    8-worker mesh: bucketed for both value dtypes, plus the leaf-wise
    path (flat + hierarchical share the same leaf sync functions)."""
    rec = _run_subprocess(
        """
        import dataclasses
        from repro.core import buckets as bk
        from repro.core.distributed import (SyncConfig,
                                            bucketed_sync_gradients,
                                            sparse_sync_gradients)
        from repro.core.selfcheck import bitwise_equal
        from repro.utils.compat import make_mesh, shard_map
        from jax.sharding import PartitionSpec as P

        tree = {
            "w1": jax.random.normal(jax.random.PRNGKey(0), (8, 100, 300)),
            "w2": jax.random.normal(jax.random.PRNGKey(1), (8, 450, 40)),
            "b": jax.random.normal(jax.random.PRNGKey(2), (8, 64)),
        }
        plan = bk.make_plan(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree),
            cols=512)

        def run(cfg, mesh, axes):
            W = 8
            mem = tuple(jnp.zeros((W,) + s.shape, jnp.float32)
                        for s in plan.buckets)
            def body(mem, tree):
                mem = jax.tree.map(lambda m: m[0], mem)
                tree = jax.tree.map(lambda t: t[0], tree)
                upd, new_mem, _ = bucketed_sync_gradients(
                    cfg, plan, mem, tree, jnp.float32(0.3))
                return upd, jax.tree.map(lambda m: m[None], new_mem)
            spec_w = jax.tree.map(lambda _: P(axes), mem)
            return shard_map(
                body, mesh=mesh,
                in_specs=(spec_w, jax.tree.map(lambda _: P(axes), tree)),
                out_specs=(jax.tree.map(lambda _: P(), {k: 0 for k in tree}),
                           spec_w),
                axis_names=set(mesh.axis_names))(mem, tree)

        results = {}
        flat_mesh = make_mesh((8,), ("data",))
        pod_mesh = make_mesh((2, 4), ("pod", "data"))
        for vd in ("float32", "bfloat16"):
            base = SyncConfig(ratio=0.02, bucketed=True, bucket_cols=512,
                              value_dtype=vd)
            for label, cfg, mesh, axes in (
                ("flat", base, flat_mesh, "data"),
                ("hier", dataclasses.replace(
                    base, strategy="hierarchical", pod_axis="pod",
                    pod_ratio=0.01), pod_mesh, ("pod", "data")),
            ):
                u1, m1 = run(cfg, mesh, axes)
                u2, m2 = run(dataclasses.replace(cfg, wire="packed"),
                             mesh, axes)
                results[f"{label}_{vd}"] = bool(
                    bitwise_equal(u1, u2) and bitwise_equal(m1, m2))

        # leaf-wise path (no buckets): batched layout, flat strategy
        def run_leaf(cfg):
            mem0 = jax.tree.map(
                lambda t: jnp.zeros(t.shape[1:], jnp.float32), tree)
            def body(tree):
                tree = jax.tree.map(lambda t: t[0], tree)
                return sparse_sync_gradients(
                    cfg, mem0, tree, jnp.float32(0.3))[:2]
            return shard_map(
                body, mesh=flat_mesh,
                in_specs=(jax.tree.map(lambda _: P("data"), tree),),
                out_specs=(jax.tree.map(lambda _: P(), tree),) * 2,
                axis_names={"data"})(tree)

        leaf_cfg = SyncConfig(ratio=0.02, dense_below=256)
        u1, m1 = run_leaf(leaf_cfg)
        u2, m2 = run_leaf(dataclasses.replace(leaf_cfg, wire="packed"))
        results["leafwise_float32"] = bool(bitwise_equal(u1, u2)
                                           and bitwise_equal(m1, m2))
        print(json.dumps(results))
        """
    )
    assert all(rec.values()), rec


@pytest.mark.slow
def test_delta_stream_replica_tracks_trainer_bitwise():
    """3 trainer steps with emit_deltas; streaming the packed deltas to a
    fresh replica reproduces the trainer's params bitwise."""
    rec = _run_subprocess(
        """
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.train import (TrainConfig, make_train_step,
                                        init_train_state, state_shardings)
        from repro.launch.serve import apply_delta
        from repro.core.distributed import SyncConfig
        from repro.core.selfcheck import bitwise_equal
        from repro.data import token_batches
        from repro.data.pipeline import ShardedBatcher

        mesh = make_debug_mesh(4, 1)
        cfg = get_smoke_config("rwkv6-3b")
        model = build_model(cfg)
        tc = TrainConfig(optimizer="memsgd", eta=0.5, emit_deltas=True,
                         sync=SyncConfig(ratio=0.02, bucketed=True,
                                         wire="packed",
                                         selection="threshold_onehot"))
        params, memory, opt, count = init_train_state(
            model, mesh, tc, rng=jax.random.PRNGKey(0))
        replica = jax.tree.map(lambda x: jnp.array(np.asarray(x)), params)
        pshard, mshard, _, _ = state_shardings(model, mesh, tc)
        params = jax.device_put(params, pshard)
        memory = jax.device_put(memory, mshard)
        step = make_train_step(model, mesh, tc)
        dspec = step.delta_spec
        it = ShardedBatcher(mesh, token_batches(cfg.vocab_size, 8, 32,
                            seed=1), prefetch=0)
        streamed = 0
        for i, batch in enumerate(it):
            if i >= 3: break
            params, memory, opt, count, m, delta = step(
                params, memory, opt, count, batch)
            assert sum(b.size * 4 for b in delta) == dspec.nbytes
            streamed += dspec.nbytes
            replica = apply_delta(replica, dspec, delta)
        print(json.dumps({"bitwise": bool(bitwise_equal(params, replica)),
                          "streamed": streamed,
                          "dense": dspec.dense_nbytes * 3}))
        """
    )
    assert rec["bitwise"]
    assert rec["streamed"] * 4 < rec["dense"]
