"""Fallback shim for ``hypothesis`` so the tier-1 suite collects without it.

When hypothesis is installed (see requirements-dev.txt) the real library is
re-exported unchanged. Otherwise ``@given`` degrades to a deterministic
sweep: each strategy contributes a small fixed set of samples (endpoints +
interior points) and the test body runs once per zipped sample tuple. That
keeps the property tests meaningful as example-based tests instead of
killing collection for the whole suite.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is present
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    HAVE_HYPOTHESIS = False

    _N_SAMPLES = 5

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _StrategiesShim:
        @staticmethod
        def integers(min_value, max_value):
            span = max_value - min_value
            pts = sorted(
                {
                    min_value,
                    min_value + span // 4,
                    min_value + span // 2,
                    min_value + (3 * span) // 4,
                    max_value,
                }
            )
            return _Strategy(pts)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            mid = (min_value + max_value) / 2.0
            return _Strategy(
                [min_value, (min_value + mid) / 2, mid, (mid + max_value) / 2,
                 max_value]
            )

        @staticmethod
        def sampled_from(elements):
            """<= _N_SAMPLES elements: cycle them (full coverage). More:
            SPREAD picks (first/last + evenly spaced interior) so long
            lists exercise their tail — the old first-N slice meant the
            tail of a long ``sampled_from`` list was effectively dead
            code under the fallback sweep."""
            elements = list(elements)
            n = len(elements)
            if n <= _N_SAMPLES:
                reps = -(-_N_SAMPLES // n)
                return _Strategy((elements * reps)[:_N_SAMPLES])
            idxs = sorted(
                {round(i * (n - 1) / (_N_SAMPLES - 1))
                 for i in range(_N_SAMPLES)}
            )
            return _Strategy([elements[i] for i in idxs])

    st = _StrategiesShim()

    def settings(**_kw):
        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # NB: deliberately no functools.wraps — copying the wrapped
            # signature would make pytest treat the parameters as fixtures.
            def wrapper():
                n = max(len(s.samples) for s in strategies.values())
                for i in range(n):
                    kwargs = {
                        name: s.samples[i % len(s.samples)]
                        for name, s in strategies.items()
                    }
                    fn(**kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
