"""Qsparse-local-SGD tests: composed contraction theory, the
amortized byte accounting, the H local-steps accumulator, and (slow
tier) the 8-device selfcheck of the H=1 bitwise identity + quantized
mass conservation (``repro.core.selfcheck.local_quant_selfcheck``)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buckets as bk
from repro.core import theory
from repro.core.distributed import (
    SyncConfig,
    WireConfig,
    amortized_bytes_per_step,
    bucketed_message_bytes,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# -- theory: the composed Q_s ∘ top_k contraction ---------------------------


def test_composed_contraction_reduces_to_topk():
    assert theory.composed_contraction(1000, 10) == 10 / 1000


def test_composed_contraction_empirical_bound():
    """Measured E||Q_s(top_k(x)) - x||^2 over random draws stays within
    the (1 - delta) ||x||^2 bound of ``composed_contraction``."""
    from repro.kernels.ref import row_topk_ref
    from repro.optim.qsgd import quantize_rows
    from repro.core.encoding import dequantize_rows

    d, k = 256, 16
    # beta_k = min(k/s^2, sqrt(k)/s) >= 1 at s=1: the bound is vacuous
    # (delta = 0) — the composition only contracts once s beats sqrt(k)
    assert theory.composed_contraction(d, k, 1) == 0.0
    for s in (5, 15):
        delta = theory.composed_contraction(d, k, s)
        assert 0.0 < delta <= k / d
        errs, norms2 = [], []
        for i in range(30):
            x = jax.random.normal(jax.random.PRNGKey(i), (1, d))
            vals, idx = row_topk_ref(x, k)
            n, c = quantize_rows(vals, s, jax.random.PRNGKey(1000 + i))
            q = dequantize_rows(n, c, s)
            recon = jnp.zeros((1, d)).at[0, idx[0]].add(q[0])
            errs.append(float(jnp.sum((recon - x) ** 2)))
            norms2.append(float(jnp.sum(x**2)))
        measured = sum(errs) / sum(norms2)
        assert measured <= (1.0 - delta) + 1e-6, (s, measured, delta)


def test_local_steps_residual_factor():
    assert theory.local_steps_residual_factor(1) == 1.0
    assert theory.local_steps_residual_factor(4) == 16.0
    with pytest.raises(ValueError):
        theory.local_steps_residual_factor(0)


# -- amortized byte accounting ----------------------------------------------


def _plan():
    return bk.make_plan(
        {"w": jax.ShapeDtypeStruct((16, 384), jnp.float32),
         "b": jax.ShapeDtypeStruct((40,), jnp.float32)},
        cols=128, dense_below=64,
    )


def test_amortized_bytes_scale_one_over_h():
    plan = _plan()
    base = SyncConfig(ratio=0.05, bucketed=True, bucket_cols=128,
                      wire=WireConfig(wire="packed", quant=15))
    full = bucketed_message_bytes(base, plan)
    for h in (1, 2, 4, 8):
        cfg = SyncConfig.preset("qsparse_local", ratio=0.05,
                                bucket_cols=128, local_steps=h)
        assert amortized_bytes_per_step(cfg, plan) == full / h


def test_amortized_bytes_by_level_dict():
    plan = _plan()
    cfg = SyncConfig(strategy="hierarchical", ratio=0.05, bucketed=True,
                     bucket_cols=128, local_steps=4,
                     pod=SyncConfig.preset("pod_budgeted").pod,
                     wire=WireConfig(wire="packed"))
    cfg = cfg.with_pod(axis="pod", dynamic=False)
    lv_full = bucketed_message_bytes(cfg, plan, by_level=True, n_data=4)
    lv = amortized_bytes_per_step(cfg, plan, by_level=True, n_data=4)
    assert set(lv) == set(lv_full)
    for key in lv:
        assert lv[key] == lv_full[key] / 4


def test_quant_shrinks_accounted_bytes():
    plan = _plan()
    exact = SyncConfig(ratio=0.05, bucketed=True, bucket_cols=128,
                       wire=WireConfig(wire="packed"))
    quant = exact.with_wire(quant=15)
    assert bucketed_message_bytes(quant, plan) < \
        bucketed_message_bytes(exact, plan)


# -- the bucket-space local accumulator -------------------------------------


def test_accumulate_local_matches_pack_sum():
    """acc after H accumulations == sum_h eta_h * pack(g_h), exactly
    (pack is elementwise-linear placement, no arithmetic)."""
    plan = _plan()
    tree = lambda i: {
        "w": jax.random.normal(jax.random.PRNGKey(i), (16, 384)),
        "b": jax.random.normal(jax.random.PRNGKey(100 + i), (40,)),
    }
    acc = bk.init_local_accum(plan)
    etas = [0.3, 0.1, 0.25]
    for h, eta in enumerate(etas):
        acc = bk.accumulate_local(plan, acc, tree(h),
                                  jnp.float32(eta))
    want = [jnp.zeros(s.shape, jnp.float32) for s in plan.buckets]
    for h, eta in enumerate(etas):
        bufs = bk.pack(plan, tree(h), dtype=jnp.float32)
        want = [w + jnp.float32(eta) * b for w, b in zip(want, bufs)]
    for a, w in zip(acc, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                   rtol=0, atol=1e-6)


def test_make_train_step_rejects_local_steps_without_buckets():
    from repro.configs import get_smoke_config
    from repro.launch.train import TrainConfig, make_train_step
    from repro.models import build_model
    from repro.utils.compat import make_mesh

    model = build_model(get_smoke_config("granite-3-8b"))
    mesh = make_mesh((1, 1), ("data", "model"))
    tc = TrainConfig(sync=SyncConfig(ratio=0.02, local_steps=2))
    with pytest.raises(ValueError, match="local_steps"):
        make_train_step(model, mesh, tc)


# -- slow tier: 8-device selfcheck ------------------------------------------


def _run_subprocess(body: str) -> dict:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp
        import numpy as np
        """
    ).format(src=SRC) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_local_quant_selfcheck():
    """On a real 2-pod x 4-worker mesh: (1) the H=1 accumulator path
    (init_local_accum + accumulate_local + sync(grad_bufs, eta=1)) is
    BITWISE identical to the direct per-step sync on all three
    strategies, (2) quantized mass conservation mean_w(u) == update +
    mean_w(new_mem) holds exactly, (3) packed and unpacked quantized
    wires agree bitwise, (4) realized bytes == the quant-aware
    accounting, (5) amortized bytes scale exactly 1/H."""
    rec = _run_subprocess(
        """
        from repro.core.selfcheck import local_quant_selfcheck
        from repro.utils.compat import make_mesh

        rec = local_quant_selfcheck(make_mesh((2, 4), ("pod", "data")))
        print(json.dumps(rec))
        """
    )
    assert rec["h1_accum_bitwise"], rec
    assert rec["quant_conservation_max_err"] < 1e-5, rec
    assert rec["quant_bit_identical"], rec
    assert rec["quant_accounting_exact"], rec
    assert rec["amortized_ratio_exact"], rec
